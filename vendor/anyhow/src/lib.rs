//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network registry, so this workspace
//! vendors the subset of `anyhow` it actually uses (see DESIGN.md
//! §Offline build): [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Semantics mirror the real crate closely enough that swapping the path
//! dependency for the registry crate is a no-op for this codebase.

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error: a message plus an optional chain of causes.
///
/// `{}` prints the outermost message, `{:#}` the whole chain separated by
/// `": "`, and `{:?}` an `anyhow`-style "Caused by:" listing.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap this error in an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Self {
        Self { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }

    /// The root (innermost) message.
    pub fn root_cause_msg(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(cause) = self.source.as_deref() {
            f.write_str("\n\nCaused by:")?;
            let mut cur = Some(cause);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        fn build(err: &(dyn StdError + 'static)) -> Error {
            Error {
                msg: err.to_string(),
                source: err.source().map(|s| Box::new(build(s))),
            }
        }
        build(&err)
    }
}

mod private {
    use super::{Error, StdError};

    /// Anything `.context()` can upgrade into an [`Error`] — every std
    /// error type, plus [`Error`] itself (so contexts stack).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`,
/// mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (captures work, like
/// `format!`) or any displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
    }

    #[test]
    fn contexts_stack_on_anyhow_errors() {
        let base: Result<()> = Err(anyhow!("inner {}", 42));
        let e = base.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.root_cause_msg(), "inner 42");
    }

    #[test]
    fn option_context_and_bail() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged: {flag}");
            }
            None::<u32>.with_context(|| "empty option")
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flagged: true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "empty option");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }
}
