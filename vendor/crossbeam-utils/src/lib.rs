//! Vendored offline stand-in for `crossbeam-utils` (see DESIGN.md
//! §Offline build). Only [`CachePadded`] is provided — the one item this
//! workspace uses. API-compatible with the real crate's root re-export.

use std::ops::{Deref, DerefMut};

/// Pads and aligns a value to 128 bytes so adjacent values never share a
/// cache line. 128 (not 64) covers the adjacent-line spatial prefetcher
/// pairing on modern x86 and the 128-byte lines of some ARM parts — the
/// same constant the real crossbeam uses on those targets.
#[derive(Default, Clone, Copy)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap a value in cache-line padding.
    pub const fn new(value: T) -> CachePadded<T> {
        CachePadded { value }
    }

    /// Unwrap, discarding the padding.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for CachePadded<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CachePadded").field("value", &self.value).finish()
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        CachePadded::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_and_deref() {
        let p = CachePadded::new(7u64);
        assert_eq!(*p, 7);
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 128);
        assert!(std::mem::size_of::<CachePadded<u8>>() >= 128);
        let addr = &p as *const _ as usize;
        assert_eq!(addr % 128, 0);
    }

    #[test]
    fn deref_mut_and_into_inner() {
        let mut p = CachePadded::new(vec![1, 2]);
        p.push(3);
        assert_eq!(p.into_inner(), vec![1, 2, 3]);
    }
}
