//! Offline stub of the `xla` crate (the xla-rs PJRT bindings).
//!
//! The real crate links `xla_extension` (PJRT + XLA compiler); this
//! environment ships neither, so the stub provides the exact type surface
//! `kway::runtime` compiles against while [`PjRtClient::cpu`] — the first
//! call every runtime path makes — fails with a clear message. Replacing
//! this vendored path dependency with a real xla-rs build (and running
//! `make artifacts`) enables the full Layers 1–2 pipeline and the
//! `pjrt`-gated parity tests. See DESIGN.md §Offline build.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `?` and `.context()`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` with the stub [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: this build uses the vendored `xla` stub \
         (no xla_extension in this environment); swap vendor/xla for a real \
         xla-rs build to enable it"
            .to_string(),
    ))
}

/// Element types a [`Literal`] can hold.
pub trait ArrayElement: Copy {}

impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u32 {}
impl ArrayElement for u64 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// Host-side tensor stand-in. Construction succeeds (so argument-building
/// code is exercised); anything that would need device data errors.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: ArrayElement>(_data: &[T]) -> Literal {
        Literal
    }

    /// Scalar literal.
    pub fn scalar<T: ArrayElement>(_value: T) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Copy out as a host vector — needs a real backend.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    /// Decompose a tuple literal — needs a real backend.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text file — needs a real backend.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to the host — needs a real backend.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments — needs a real backend.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A PJRT client. In the stub, construction always fails — callers see a
/// clean `Err` before touching any other API.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — needs a real backend.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_building_is_infallible() {
        let lit = Literal::vec1(&[1i32, 2, 3]).reshape(&[3, 1]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }
}
