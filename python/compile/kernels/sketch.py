"""Layer-1 Pallas kernel: TinyLFU count-min sketch estimate.

The admission filter's read path — ``min over D rows of row[d][h_d(key)]``
— is a gather plus a lane reduction. The batch dimension maps onto the
grid; each grid step gathers `BLOCK_B × D` counters from the sketch rows
held in VMEM.

The sketch *update* (saturating increment) stays in Layer 2 (`model.py`)
as a scatter, where XLA's native scatter lowering is already optimal; the
estimate is the per-access hot spot the paper cares about.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _estimate_kernel(rows_ref, idx_ref, out_ref):
    rows = rows_ref[...]            # [D, W]
    idx = idx_ref[...]              # [BLOCK_B, D]
    d = rows.shape[0]
    gathered = jnp.stack([rows[j][idx[:, j]] for j in range(d)], axis=-1)
    out_ref[...] = jnp.min(gathered, axis=-1).astype(jnp.int32)


def estimate(rows, indices):
    """Count-min estimate: i32[D, W], i32[B, D] -> i32[B].

    The whole sketch (`D × W` i32) rides in VMEM per grid step; with the
    default W = 8192 and D = 4 that is 128 KiB — within a TPU core's VMEM
    alongside the index tile.
    """
    d, w = rows.shape
    b, d2 = indices.shape
    assert d == d2, f"depth mismatch {d} vs {d2}"
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    return pl.pallas_call(
        _estimate_kernel,
        grid=(b // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((d, w), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(rows, indices)


def increment(rows, indices, cap=15):
    """Saturating count-min increment (Layer-2 scatter, not a kernel):
    i32[D, W], i32[B, D] -> i32[D, W]. Every (row d, column idx[b, d])
    pair is bumped by the number of occurrences, clipped to `cap`."""
    d, w = rows.shape
    b, _ = indices.shape

    def body(j, rows):
        row = rows[j]
        bumped = row.at[indices[:, j]].add(1)
        return rows.at[j].set(jnp.minimum(bumped, cap))

    return jax.lax.fori_loop(0, d, body, rows)
