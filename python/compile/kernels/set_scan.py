"""Layer-1 Pallas kernels: the set-scan hot spots of the k-way cache.

The paper's §3 observation is that every policy reduces to short
contiguous scans over a set's fingerprints and counters. On TPU hardware
those scans map onto the VPU lanes: a set's K ways occupy the minor (lane)
dimension of a ``[sets_per_block, K]`` VMEM tile, so the probe is a
lane-wise compare + reduce and victim selection is a lane-wise argmin —
the vector analogue of the thread-per-set parallelism the paper exploits
on CPUs (DESIGN.md §Hardware-Adaptation).

All kernels are lowered with ``interpret=True``: that makes ``pallas_call``
trace to portable HLO that the CPU PJRT client (and the rust runtime) can
execute; on a real TPU the same BlockSpecs drive the Mosaic lowering.

Shapes are static at lowering time; `aot.py` emits one artifact per
(kernel, K, batch) combination listed in the manifest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch rows per grid step; 128 keeps a (128, K<=128) i32 tile well under
# VMEM limits (128*128*4 = 64 KiB) while filling the 8x128 VPU.
BLOCK_B = 128


def _victim_kernel(counters_ref, out_ref):
    """Per-row argmin over the K (lane) dimension."""
    out_ref[...] = jnp.argmin(counters_ref[...], axis=-1).astype(jnp.int32)


def victim_select(counters):
    """Victim way per set: i32[B, K] -> i32[B] (LRU/LFU/FIFO semantics:
    evict the minimal counter, ties to the lowest way)."""
    b, k = counters.shape
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    return pl.pallas_call(
        _victim_kernel,
        grid=(b // BLOCK_B,),
        in_specs=[pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(counters)


def _victim_hyperbolic_kernel(counts_ref, t0s_ref, now_ref, out_ref):
    """Per-row argmin of count / max(now - t0, 1)."""
    now = now_ref[0]
    age = jnp.maximum(now - t0s_ref[...], 1).astype(jnp.float32)
    priority = counts_ref[...].astype(jnp.float32) / age
    out_ref[...] = jnp.argmin(priority, axis=-1).astype(jnp.int32)


def victim_select_hyperbolic(counts, t0s, now):
    """Hyperbolic victim way per set: i32[B,K], i32[B,K], i32[] -> i32[B]."""
    b, k = counts.shape
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    now_arr = jnp.reshape(now.astype(jnp.int32), (1,))
    return pl.pallas_call(
        _victim_hyperbolic_kernel,
        grid=(b // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(counts, t0s, now_arr)


def _probe_kernel(fps_ref, probes_ref, out_ref):
    """Per-row fingerprint match: way index or -1."""
    match = fps_ref[...] == probes_ref[...][:, None]
    idx = jnp.argmax(match, axis=-1).astype(jnp.int32)
    found = jnp.any(match, axis=-1)
    out_ref[...] = jnp.where(found, idx, jnp.int32(-1))


def set_probe(fps, probes):
    """Probe each set's fingerprints: i32[B,K], i32[B] -> i32[B]."""
    b, k = fps.shape
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    return pl.pallas_call(
        _probe_kernel,
        grid=(b // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(fps, probes)


def _step_kernel(fps_ref, counters_ref, fp_ref, time_ref, valid_ref,
                 out_fps_ref, out_counters_ref, hit_ref):
    """One sequential access against one set (the cache_sim scan body):
    probe the fingerprint lane-vector, refresh on hit, replace the argmin
    victim on miss. This is the paper's entire per-operation cache logic
    in one VPU-friendly kernel."""
    row_f = fps_ref[...]
    row_c = counters_ref[...]
    fp = fp_ref[0]
    time = time_ref[0]
    valid = valid_ref[0] != 0
    match = row_f == fp
    hit = jnp.any(match) & valid
    victim = jnp.argmin(row_c)
    pos = jnp.where(hit, jnp.argmax(match), victim)
    oh = jax.nn.one_hot(pos, row_f.shape[-1], dtype=jnp.bool_)
    write = oh & valid
    out_fps_ref[...] = jnp.where(write, fp, row_f)
    out_counters_ref[...] = jnp.where(write, time, row_c)
    hit_ref[...] = hit.astype(jnp.int32).reshape(1)


def set_step(row_fps, row_counters, fp, time, valid):
    """Single-set access step: i32[K], i32[K], i32[], i32[], i32[] ->
    (i32[K], i32[K], i32[1])."""
    k = row_fps.shape[0]
    return pl.pallas_call(
        _step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=True,
    )(
        row_fps,
        row_counters,
        jnp.reshape(fp, (1,)),
        jnp.reshape(time, (1,)),
        jnp.reshape(valid, (1,)),
    )


def _batch_step_kernel(fps_ref, counters_ref, fp_ref, valid_ref, time_ref,
                       out_fps_ref, out_counters_ref, hits_ref):
    """One access against EVERY set simultaneously — the set-parallel
    formulation of the paper's independence argument. Each row of the
    [sets_per_block, K] tile is one set; the lane dimension holds the K
    ways; rows proceed in lock-step on the VPU."""
    fps = fps_ref[...]            # [B, K]
    counters = counters_ref[...]  # [B, K]
    fp = fp_ref[...]              # [B]
    valid = valid_ref[...] != 0   # [B]
    time = time_ref[0]
    match = fps == fp[:, None]
    hit = jnp.any(match, axis=-1) & valid
    victim = jnp.argmin(counters, axis=-1)
    pos = jnp.where(hit, jnp.argmax(match, axis=-1).astype(victim.dtype), victim)
    oh = jax.nn.one_hot(pos, fps.shape[-1], dtype=jnp.bool_)
    write = oh & valid[:, None]
    out_fps_ref[...] = jnp.where(write, fp[:, None], fps)
    out_counters_ref[...] = jnp.where(write, time, counters)
    hits_ref[...] = hit.astype(jnp.int32)


def batch_step(fps, counters, fp, valid, time):
    """One access per set, across all sets: i32[S,K], i32[S,K], i32[S],
    i32[S], i32[] -> (i32[S,K], i32[S,K], i32[S] hit-mask)."""
    s, k = fps.shape
    block = min(BLOCK_B, s)
    assert s % block == 0, f"sets {s} must be a multiple of {block}"
    time_arr = jnp.reshape(time.astype(jnp.int32), (1,))
    return pl.pallas_call(
        _batch_step_kernel,
        grid=(s // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((s, k), jnp.int32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ),
        interpret=True,
    )(fps, counters, fp, valid, time_arr)


@functools.lru_cache(maxsize=None)
def _noop():  # pragma: no cover - import-time sanity hook
    return True
