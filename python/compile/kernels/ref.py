"""Pure-jnp oracles for the Pallas kernels (Layer 1 correctness spec).

Every kernel in this package must agree exactly with the corresponding
function here; ``python/tests/test_kernels.py`` sweeps shapes and values
with hypothesis. These references are also the executable specification of
the semantics the rust native simulator mirrors (ties break to the lowest
way index, empty ways carry counter 0, fingerprint 0 means empty).
"""

import jax.numpy as jnp


def victim_select_ref(counters):
    """LRU/LFU/FIFO victim: per-set argmin over counters.

    counters: i32[B, K] -> i32[B] (first minimal index wins).
    """
    return jnp.argmin(counters, axis=-1).astype(jnp.int32)


def victim_select_hyperbolic_ref(counts, t0s, now):
    """Hyperbolic victim: per-set argmin of count / max(now - t0, 1).

    counts, t0s: i32[B, K]; now: i32 scalar -> i32[B].
    """
    age = jnp.maximum(now - t0s, 1).astype(jnp.float32)
    priority = counts.astype(jnp.float32) / age
    return jnp.argmin(priority, axis=-1).astype(jnp.int32)


def set_probe_ref(fps, probes):
    """Fingerprint probe: index of the way whose fingerprint matches, or -1.

    fps: i32[B, K]; probes: i32[B] -> i32[B].
    """
    match = fps == probes[:, None]
    idx = jnp.argmax(match, axis=-1).astype(jnp.int32)
    found = jnp.any(match, axis=-1)
    return jnp.where(found, idx, jnp.int32(-1))


def sketch_estimate_ref(rows, indices):
    """Count-min estimate: min over depth of rows[d, indices[b, d]].

    rows: i32[D, W]; indices: i32[B, D] -> i32[B].
    """
    d = rows.shape[0]
    gathered = jnp.stack([rows[j][indices[:, j]] for j in range(d)], axis=-1)
    return jnp.min(gathered, axis=-1).astype(jnp.int32)


def set_step_ref(row_fps, row_counters, fp, time, valid):
    """One sequential cache access against a single set (the scan body of
    the cache simulator): probe; on hit refresh the counter, on miss
    replace the victim (min counter; empty ways are 0 and therefore
    preferred). Returns (new_fps, new_counters, hit).

    row_fps, row_counters: i32[K]; fp, time: i32 scalars; valid: bool.
    """
    match = row_fps == fp
    hit = jnp.any(match) & valid
    victim = jnp.argmin(row_counters)
    pos = jnp.where(hit, jnp.argmax(match), victim)
    new_fps = row_fps.at[pos].set(fp)
    new_counters = row_counters.at[pos].set(time)
    new_fps = jnp.where(valid, new_fps, row_fps)
    new_counters = jnp.where(valid, new_counters, row_counters)
    return new_fps, new_counters, hit


def cache_sim_chunk_ref(fps, counters, time, set_idx, key_fp, valid):
    """Reference chunk simulator (plain python loop; test-only).

    fps, counters: i32[S, K]; time: i32; set_idx, key_fp, valid: i32[C].
    Returns (fps, counters, time, hits).
    """
    import numpy as np

    fps = np.array(fps)
    counters = np.array(counters)
    time = int(time)
    hits = 0
    for s, fp, v in zip(np.array(set_idx), np.array(key_fp), np.array(valid)):
        if not v:
            continue
        time += 1
        row_f = fps[s]
        row_c = counters[s]
        matches = np.nonzero(row_f == fp)[0]
        if len(matches) > 0:
            row_c[matches[0]] = time
            hits += 1
        else:
            victim = int(np.argmin(row_c))
            row_f[victim] = fp
            row_c[victim] = time
    return fps, counters, time, hits
