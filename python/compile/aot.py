"""AOT pipeline: lower every Layer-2 entry point to HLO text + manifest.

Interchange is HLO *text*, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the xla_extension 0.5.1
behind the published ``xla`` rust crate rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and rust/src/runtime/).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, whatever the arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, spec: dict) -> str:
    lowered = jax.jit(spec["fn"]).lower(*spec["specs"])
    return to_hlo_text(lowered)


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of entry names"
    )
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    entries = model.entry_points()
    if args.only:
        wanted = set(args.only.split(","))
        missing = wanted - entries.keys()
        if missing:
            print(f"unknown entries: {sorted(missing)}", file=sys.stderr)
            return 1
        entries = {k: v for k, v in entries.items() if k in wanted}

    manifest = {
        "producer": f"jax {jax.__version__}",
        "entries": [],
    }
    for name, spec in entries.items():
        text = lower_entry(name, spec)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "kind": spec["kind"],
                "params": spec["params"],
            }
        )
        print(f"  lowered {name:34s} -> {fname} ({len(text)/1024:.0f} KiB)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
