"""Layer 2: the set-parallel k-way cache model in JAX.

Three families of entry points, all AOT-lowered by `aot.py`:

* ``victim_select_batch`` / ``victim_select_hyperbolic_batch`` /
  ``set_probe_batch`` / ``sketch_estimate_batch`` — batched policy
  evaluation over many independent sets at once (the vectorized form of
  the paper's "sets are independent" argument); thin wrappers around the
  Layer-1 Pallas kernels.
* ``cache_sim_chunk`` — the sequential k-way LRU cache simulator: a
  ``lax.scan`` over a chunk of accesses, whose body is the Layer-1
  ``set_step`` kernel (probe + victim-select on one set). State is the
  full ``[num_sets, K]`` fingerprint/counter pair; the rust runtime
  carries it between chunks. Semantics match the rust native simulator
  (`sim::xla::NativeSetSim`) exactly.
* ``sketch_update_batch`` — TinyLFU sketch maintenance (XLA scatter).

Everything here runs at *build time only*; the rust binary executes the
lowered HLO through PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels import set_scan, sketch


def victim_select_batch(counters):
    """i32[B, K] -> i32[B]."""
    return (set_scan.victim_select(counters),)


def victim_select_hyperbolic_batch(counts, t0s, now):
    """i32[B, K], i32[B, K], i32[] -> i32[B]."""
    return (set_scan.victim_select_hyperbolic(counts, t0s, now),)


def set_probe_batch(fps, probes):
    """i32[B, K], i32[B] -> i32[B] (way index or -1)."""
    return (set_scan.set_probe(fps, probes),)


def sketch_estimate_batch(rows, indices):
    """i32[D, W], i32[B, D] -> i32[B]."""
    return (sketch.estimate(rows, indices),)


def sketch_update_batch(rows, indices):
    """i32[D, W], i32[B, D] -> i32[D, W] (saturating increment)."""
    return (sketch.increment(rows, indices),)


def cache_sim_chunk(fps, counters, time, set_idx, key_fp, valid):
    """Simulate one chunk of accesses against the k-way LRU state.

    fps, counters: i32[S, K] (fingerprint 0 = empty; counter = last-touch
    logical time, 0 = never).
    time: i32 scalar — logical clock carried across chunks.
    set_idx, key_fp, valid: i32[C] — the chunk (padded tail has valid=0).

    Returns (fps, counters, time, hits): the updated state and the number
    of hits in the chunk.
    """

    def step(carry, x):
        fps, counters, time = carry
        sidx, fp, valid = x
        time = time + valid  # padded steps do not advance the clock
        row_f = jax.lax.dynamic_slice_in_dim(fps, sidx, 1, axis=0)[0]
        row_c = jax.lax.dynamic_slice_in_dim(counters, sidx, 1, axis=0)[0]
        new_f, new_c, hit = set_scan.set_step(row_f, row_c, fp, time, valid)
        fps = jax.lax.dynamic_update_slice_in_dim(fps, new_f[None, :], sidx, axis=0)
        counters = jax.lax.dynamic_update_slice_in_dim(
            counters, new_c[None, :], sidx, axis=0
        )
        return (fps, counters, time), hit[0]

    (fps, counters, time), hits = jax.lax.scan(
        step, (fps, counters, time), (set_idx, key_fp, valid)
    )
    return (fps, counters, time, jnp.sum(hits).astype(jnp.int32))


def cache_sim_setpar(fps, counters, time, probe_fp, valid):
    """Set-parallel chunk simulator: the paper's "sets are independent"
    argument, vectorized. The host groups a chunk of accesses by set and
    hands over a `[L, S]` matrix — column `s` holds set `s`'s accesses in
    arrival order, padded with `valid = 0`. Each of the `L` scan steps
    applies ONE access to EVERY set simultaneously via the Layer-1
    `batch_step` kernel, so the per-step work is a fully vectorized
    `[S, K]` compare/argmin/update instead of one set's K-element scan.

    Reordering accesses *across* sets cannot change any per-set outcome
    (hits, evictions, final contents are all per-set functions of the
    per-set subsequence), so the hit total equals the sequential
    simulator's — asserted by tests on both the python and rust sides.

    fps, counters: i32[S, K]; time: i32; probe_fp, valid: i32[L, S].
    Returns (fps, counters, time, hits).
    """

    def step(carry, x):
        fps, counters, time = carry
        fp_row, valid_row = x
        time = time + 1
        fps, counters, hit = set_scan.batch_step(fps, counters, fp_row, valid_row, time)
        return (fps, counters, time), jnp.sum(hit)

    (fps, counters, time), hits = jax.lax.scan(
        step, (fps, counters, time), (probe_fp, valid)
    )
    return (fps, counters, time, jnp.sum(hits).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Entry-point registry used by aot.py and the tests: name -> (fn, specs,
# kind, params). Shapes are the static configurations shipped in
# artifacts/; add a line here to ship another variant.
# ---------------------------------------------------------------------------

def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_points():
    b, d, w = 4096, 4, 8192
    entries = {}
    for k in (4, 8, 16):
        entries[f"victim_select_lru_k{k}"] = dict(
            fn=victim_select_batch,
            specs=(_i32(b, k),),
            kind="victim_select",
            params={"k": k, "batch": b},
        )
    entries["victim_select_hyperbolic_k8"] = dict(
        fn=victim_select_hyperbolic_batch,
        specs=(_i32(b, 8), _i32(b, 8), _i32()),
        kind="victim_select_hyperbolic",
        params={"k": 8, "batch": b},
    )
    entries["set_probe_k8"] = dict(
        fn=set_probe_batch,
        specs=(_i32(b, 8), _i32(b)),
        kind="set_probe",
        params={"k": 8, "batch": b},
    )
    entries["sketch_estimate"] = dict(
        fn=sketch_estimate_batch,
        specs=(_i32(d, w), _i32(1024, d)),
        kind="sketch_estimate",
        params={"depth": d, "width": w, "batch": 1024},
    )
    entries["sketch_update"] = dict(
        fn=sketch_update_batch,
        specs=(_i32(d, w), _i32(1024, d)),
        kind="sketch_update",
        params={"depth": d, "width": w, "batch": 1024},
    )
    # The paper's small-trace cache size is 2^11 = 2048 = 256 sets x 8 ways.
    # The _c8192 variant amortizes the per-execute PJRT dispatch over a 4x
    # longer chunk (see EXPERIMENTS.md §Perf).
    for num_sets, k, chunk in ((256, 8, 2048), (256, 8, 8192)):
        suffix = "" if chunk == 2048 else f"_c{chunk}"
        entries[f"cache_sim_k{k}{suffix}"] = dict(
            fn=cache_sim_chunk,
            specs=(
                _i32(num_sets, k),
                _i32(num_sets, k),
                _i32(),
                _i32(chunk),
                _i32(chunk),
                _i32(chunk),
            ),
            kind="cache_sim",
            params={"k": k, "num_sets": num_sets, "chunk": chunk},
        )
    # Set-parallel variant: L steps x S sets per execute (EXPERIMENTS.md
    # §Perf iteration 2).
    for num_sets, k, steps in ((256, 8, 64),):
        entries[f"cache_sim_setpar_k{k}"] = dict(
            fn=cache_sim_setpar,
            specs=(
                _i32(num_sets, k),
                _i32(num_sets, k),
                _i32(),
                _i32(steps, num_sets),
                _i32(steps, num_sets),
            ),
            kind="cache_sim_setpar",
            params={"k": k, "num_sets": num_sets, "steps": steps},
        )
    return entries
