"""Layer-1 kernel correctness: Pallas (interpret) vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; fixed edge cases cover ties, empty
ways, saturation and padding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, set_scan, sketch

BLOCK = set_scan.BLOCK_B


def i32(a):
    return jnp.asarray(a, jnp.int32)


# --------------------------------------------------------------------------
# victim_select
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    blocks=st.integers(1, 3),
    k=st.sampled_from([2, 4, 8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
    hi=st.sampled_from([2, 100, 2**30]),
)
def test_victim_select_matches_ref(blocks, k, seed, hi):
    rng = np.random.default_rng(seed)
    counters = i32(rng.integers(0, hi, (blocks * BLOCK, k)))
    got = set_scan.victim_select(counters)
    want = ref.victim_select_ref(counters)
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_victim_select_tie_breaks_to_lowest_index():
    counters = np.full((BLOCK, 8), 7, dtype=np.int32)
    counters[0] = [9, 3, 3, 9, 9, 9, 9, 9]
    got = np.array(set_scan.victim_select(i32(counters)))
    assert got[0] == 1
    assert (got[1:] == 0).all()


def test_victim_select_rejects_misaligned_batch():
    with pytest.raises(AssertionError):
        set_scan.victim_select(jnp.zeros((BLOCK + 1, 8), jnp.int32))


# --------------------------------------------------------------------------
# hyperbolic victim
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
    now=st.integers(1, 2**20),
)
def test_victim_hyperbolic_matches_ref(k, seed, now):
    rng = np.random.default_rng(seed)
    counts = i32(rng.integers(1, 1000, (BLOCK, k)))
    t0s = i32(rng.integers(0, now + 10, (BLOCK, k)))
    got = set_scan.victim_select_hyperbolic(counts, t0s, jnp.int32(now))
    want = ref.victim_select_hyperbolic_ref(counts, t0s, jnp.int32(now))
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_victim_hyperbolic_prefers_low_rate():
    counts = np.ones((BLOCK, 4), dtype=np.int32) * 10
    t0s = np.full((BLOCK, 4), 90, dtype=np.int32)
    counts[0] = [10, 1, 10, 10]  # way 1: lowest count, same age
    got = np.array(
        set_scan.victim_select_hyperbolic(i32(counts), i32(t0s), jnp.int32(100))
    )
    assert got[0] == 1


# --------------------------------------------------------------------------
# set_probe
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    k=st.sampled_from([2, 4, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
    universe=st.sampled_from([3, 50, 2**30]),
)
def test_set_probe_matches_ref(k, seed, universe):
    rng = np.random.default_rng(seed)
    fps = i32(rng.integers(1, universe + 1, (BLOCK, k)))
    probes = i32(rng.integers(1, universe + 1, (BLOCK,)))
    got = set_scan.set_probe(fps, probes)
    want = ref.set_probe_ref(fps, probes)
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_set_probe_miss_is_minus_one():
    fps = jnp.ones((BLOCK, 8), jnp.int32)
    probes = jnp.full((BLOCK,), 2, jnp.int32)
    assert (np.array(set_scan.set_probe(fps, probes)) == -1).all()


# --------------------------------------------------------------------------
# sketch
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    w=st.sampled_from([16, 512, 8192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sketch_estimate_matches_ref(w, seed):
    rng = np.random.default_rng(seed)
    rows = i32(rng.integers(0, 16, (4, w)))
    idx = i32(rng.integers(0, w, (BLOCK, 4)))
    got = sketch.estimate(rows, idx)
    want = ref.sketch_estimate_ref(rows, idx)
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_sketch_increment_saturates_and_accumulates():
    rows = jnp.zeros((4, 32), jnp.int32)
    # Same index twice in the batch -> +2; saturation at 15.
    idx = i32(np.array([[5, 6, 7, 8], [5, 6, 7, 8]]))
    out = np.array(sketch.increment(rows, idx))
    assert out[0, 5] == 2 and out[1, 6] == 2 and out[2, 7] == 2 and out[3, 8] == 2
    assert out.sum() == 8
    full = jnp.full((4, 32), 15, jnp.int32)
    out = np.array(sketch.increment(full, idx))
    assert out.max() == 15


# --------------------------------------------------------------------------
# set_step (the cache_sim scan body)
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    k=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    valid=st.booleans(),
)
def test_set_step_matches_ref(k, seed, valid):
    rng = np.random.default_rng(seed)
    row_f = i32(rng.integers(0, 6, (k,)))  # small universe -> hits happen
    row_c = i32(rng.integers(0, 50, (k,)))
    fp = jnp.int32(rng.integers(1, 6))
    time = jnp.int32(51)
    nf, nc, hit = set_scan.set_step(row_f, row_c, fp, time, jnp.int32(valid))
    rf, rc, rhit = ref.set_step_ref(row_f, row_c, fp, time, jnp.bool_(valid))
    np.testing.assert_array_equal(np.array(nf), np.array(rf))
    np.testing.assert_array_equal(np.array(nc), np.array(rc))
    assert bool(hit[0]) == bool(rhit)


def test_set_step_invalid_is_noop():
    row_f = i32([1, 2, 3, 4])
    row_c = i32([10, 20, 30, 40])
    nf, nc, hit = set_scan.set_step(row_f, row_c, jnp.int32(9), jnp.int32(99), jnp.int32(0))
    np.testing.assert_array_equal(np.array(nf), np.array(row_f))
    np.testing.assert_array_equal(np.array(nc), np.array(row_c))
    assert hit[0] == 0
