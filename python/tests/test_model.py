"""Layer-2 model correctness: the chunked cache simulator vs the python
reference, entry-point registry sanity, and AOT lowering round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def i32(a):
    return jnp.asarray(a, jnp.int32)


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([4, 16, 64]),
    k=st.sampled_from([2, 4, 8]),
    c=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
    pad=st.integers(0, 20),
)
def test_cache_sim_chunk_matches_reference(s, k, c, seed, pad):
    rng = np.random.default_rng(seed)
    fps0 = jnp.zeros((s, k), jnp.int32)
    cnt0 = jnp.zeros((s, k), jnp.int32)
    set_idx = i32(rng.integers(0, s, (c,)))
    key_fp = i32(rng.integers(1, 40, (c,)))
    valid = np.ones(c, np.int32)
    if pad:
        valid[c - min(pad, c):] = 0
    out = jax.jit(model.cache_sim_chunk)(fps0, cnt0, jnp.int32(0), set_idx, key_fp, i32(valid))
    rf, rc, rt, rh = ref.cache_sim_chunk_ref(fps0, cnt0, 0, set_idx, key_fp, valid)
    np.testing.assert_array_equal(np.array(out[0]), rf)
    np.testing.assert_array_equal(np.array(out[1]), rc)
    assert int(out[2]) == rt
    assert int(out[3]) == rh


def test_cache_sim_state_carries_across_chunks():
    # Two chunks = one big chunk.
    rng = np.random.default_rng(7)
    s, k, c = 8, 4, 64
    set_idx = i32(rng.integers(0, s, (2 * c,)))
    key_fp = i32(rng.integers(1, 20, (2 * c,)))
    valid = jnp.ones((2 * c,), jnp.int32)

    f = jax.jit(model.cache_sim_chunk)
    fps, cnt, t = jnp.zeros((s, k), jnp.int32), jnp.zeros((s, k), jnp.int32), jnp.int32(0)
    fps, cnt, t, h1 = f(fps, cnt, t, set_idx[:c], key_fp[:c], valid[:c])
    fps, cnt, t, h2 = f(fps, cnt, t, set_idx[c:], key_fp[c:], valid[c:])

    fps2, cnt2, t2 = jnp.zeros((s, k), jnp.int32), jnp.zeros((s, k), jnp.int32), jnp.int32(0)
    fps2, cnt2, t2, h = f(fps2, cnt2, t2, set_idx, key_fp, valid)
    assert int(h1) + int(h2) == int(h)
    np.testing.assert_array_equal(np.array(fps), np.array(fps2))
    np.testing.assert_array_equal(np.array(cnt), np.array(cnt2))
    assert int(t) == int(t2)


def test_entry_points_shape_sanity():
    entries = model.entry_points()
    assert "cache_sim_k8" in entries
    assert "victim_select_lru_k8" in entries
    for name, spec in entries.items():
        assert spec["kind"], name
        assert callable(spec["fn"]), name
        assert all(isinstance(v, int) for v in spec["params"].values()), name


def test_aot_lowering_produces_parseable_hlo(tmp_path):
    # Lower the smallest entry and sanity-check the HLO text.
    entries = model.entry_points()
    text = aot.lower_entry("victim_select_lru_k8", entries["victim_select_lru_k8"])
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root computation must produce a tuple.
    assert "(s32[" in text


def test_cache_sim_hit_ratio_reasonable():
    # A working set that fits must converge to ~100% hits.
    s, k, c = 16, 8, 512
    universe = 64  # 64 keys into 128 slots
    rng = np.random.default_rng(3)
    keys = rng.integers(0, universe, (c,))
    # Map key -> (set, fp) with a trivial injective scheme.
    set_idx = i32(keys % s)
    key_fp = i32(keys + 1)
    valid = jnp.ones((c,), jnp.int32)
    f = jax.jit(model.cache_sim_chunk)
    fps, cnt, t = jnp.zeros((s, k), jnp.int32), jnp.zeros((s, k), jnp.int32), jnp.int32(0)
    fps, cnt, t, h_cold = f(fps, cnt, t, set_idx, key_fp, valid)
    fps, cnt, t, h_warm = f(fps, cnt, t, set_idx, key_fp, valid)
    assert int(h_warm) > int(h_cold)
    assert int(h_warm) >= int(0.9 * c), f"warm hits {int(h_warm)}/{c}"


def test_cache_sim_setpar_matches_sequential():
    """The set-parallel formulation must produce the same hits and the
    same final fingerprint state as the sequential scan when fed the same
    per-set subsequences (cross-set order is immaterial)."""
    rng = np.random.default_rng(11)
    s, k, l = 8, 4, 16
    n_keys = s * l  # exactly fill one [L, S] batch worth at most
    sets = rng.integers(0, s, (n_keys,))
    fps_in = rng.integers(1, 25, (n_keys,))
    # Build the [L, S] matrix: column s holds set s's accesses in order.
    probe = np.zeros((l, s), np.int32)
    valid = np.zeros((l, s), np.int32)
    depth = [0] * s
    kept = []  # (set, fp) that fit in the matrix, in arrival order
    for st, fp in zip(sets, fps_in):
        if depth[st] < l:
            probe[depth[st], st] = fp
            valid[depth[st], st] = 1
            depth[st] += 1
            kept.append((st, fp))
    f = jax.jit(model.cache_sim_setpar)
    out = f(
        jnp.zeros((s, k), jnp.int32),
        jnp.zeros((s, k), jnp.int32),
        jnp.int32(0),
        jnp.asarray(probe),
        jnp.asarray(valid),
    )
    # Sequential reference over the kept accesses in arrival order.
    seq_sets = np.array([st for st, _ in kept], np.int32)
    seq_fps = np.array([fp for _, fp in kept], np.int32)
    rf, rc, rt, rh = ref.cache_sim_chunk_ref(
        np.zeros((s, k), np.int32),
        np.zeros((s, k), np.int32),
        0,
        seq_sets,
        seq_fps,
        np.ones(len(kept), np.int32),
    )
    assert int(out[3]) == rh, f"hits {int(out[3])} vs sequential {rh}"
    np.testing.assert_array_equal(np.array(out[0]), rf)
