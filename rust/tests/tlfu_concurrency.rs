//! Concurrency smoke for the TinyLFU admission layer: threads hammering
//! `TlfuCache<KwWfsc>` with Zipf traffic while the sketch ages underneath
//! them, plus the single-threaded "no lost inserts" guarantee for
//! admitted keys.

use kway::kway::KwWfsc;
use kway::policy::Policy;
use kway::tinylfu::TlfuCache;
use kway::util::rng::{Rng, Zipf};
use kway::Cache;
use std::sync::Arc;

#[test]
fn zipf_hammer_ages_the_sketch_and_keeps_the_hot_head() {
    let capacity = 1024;
    let cache = Arc::new(TlfuCache::new(KwWfsc::new(capacity, 8, Policy::Lfu), capacity));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let cache = cache.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xF00D + t);
            let zipf = Zipf::new(8192, 0.99);
            for _ in 0..60_000 {
                let key = zipf.sample(&mut rng);
                if cache.get(key).is_none() {
                    cache.put(key, key.wrapping_mul(31));
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // ≥ 240k recorded accesses over a sample size of 10·1024: the aging
    // epoch must have advanced several times without panicking or
    // stalling (every crossing is claimed by exactly one thread).
    assert!(
        cache.sketch().resets() >= 2,
        "aging epoch never advanced: {}",
        cache.sketch().resets()
    );
    // The Zipf head (ranks 0..8) was hot enough to be admitted and must
    // have survived the churn — that is the entire point of admission.
    let mut resident = 0;
    for key in 0..8u64 {
        if let Some(v) = cache.get(key) {
            assert_eq!(v, key.wrapping_mul(31), "phantom value for hot key {key}");
            resident += 1;
        }
    }
    assert!(resident >= 6, "only {resident}/8 hot keys survived the hammer");
    assert!(cache.len() <= cache.capacity());
}

#[test]
fn batched_admission_paths_survive_concurrent_churn() {
    let capacity = 1024;
    let cache = Arc::new(TlfuCache::new(KwWfsc::new(capacity, 8, Policy::Lru), capacity));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let cache = cache.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xBA7C4 + t);
            let zipf = Zipf::new(4096, 0.99);
            let mut out = Vec::new();
            for _ in 0..1_500 {
                let keys: Vec<u64> = (0..32).map(|_| zipf.sample(&mut rng)).collect();
                out.clear();
                cache.get_batch(&keys, &mut out);
                assert_eq!(out.len(), keys.len());
                // Phantom check: a batched hit must carry its key's value.
                for (i, &key) in keys.iter().enumerate() {
                    if let Some(v) = out[i] {
                        assert_eq!(v, key.wrapping_mul(31), "phantom at position {i}");
                    }
                }
                let fills: Vec<(u64, u64)> = keys
                    .iter()
                    .zip(&out)
                    .filter(|(_, r)| r.is_none())
                    .map(|(&k, _)| (k, k.wrapping_mul(31)))
                    .collect();
                if !fills.is_empty() {
                    cache.put_batch(&fills);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // 4 × 1500 × 32 = 192k batched records: the epoch advanced.
    assert!(cache.sketch().resets() >= 1, "epoch: {}", cache.sketch().resets());
    assert!(cache.len() <= cache.capacity());
}

#[test]
fn admitted_puts_are_never_lost_when_uncontended() {
    // "Admitted" means the filter forwarded the put to the inner cache.
    // Without contention the wait-free protocols cannot drop a forwarded
    // insert, so an admitted put must be immediately readable — and a
    // rejected one must leave the cache untouched. (Under contention an
    // inner CAS may legally give up — the paper's "it is a cache" rule —
    // which is why this guarantee is pinned single-threaded.)
    let capacity = 256;
    let cache = TlfuCache::new(KwWfsc::new(capacity, 8, Policy::Lfu), capacity);
    // Warm with Zipf traffic until every set is full and admission bites.
    let mut rng = Rng::new(3);
    let zipf = Zipf::new(2048, 0.9);
    for _ in 0..50_000 {
        let key = zipf.sample(&mut rng);
        if cache.get(key).is_none() {
            cache.put(key, key);
        }
    }
    let mut admitted = 0;
    let mut rejected = 0;
    for key in 100_000..100_200u64 {
        // Build frequency for the candidate through recorded gets.
        for _ in 0..20 {
            let _ = cache.get(key);
        }
        if cache.put_admitted(key, key + 1) {
            admitted += 1;
            assert_eq!(cache.get(key), Some(key + 1), "admitted insert of {key} was lost");
        } else {
            rejected += 1;
            assert_eq!(cache.get(key), None, "rejected insert of {key} is resident");
        }
    }
    // Hot candidates against a Zipf-tail victim are mostly admitted; the
    // split just must not be degenerate in the "all lost" direction.
    assert!(admitted > 0, "no candidate was ever admitted (admitted=0 rejected={rejected})");
}
