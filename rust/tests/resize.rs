//! Online elastic resizing — the cross-variant contract suite
//! (DESIGN.md §Elastic resizing).
//!
//! What is pinned here, for **all three** k-way variants:
//!
//! * a grow loses no admitted entry — single-threaded exactly, and under
//!   concurrent churn up to the documented "it is a cache" contention
//!   drops, which a final quiescent re-put pass flushes out;
//! * `len() <= capacity()` and `weight() <=` the weight budget hold at
//!   every migration step (capacity reports the larger of the two live
//!   geometries mid-resize, converging to the target);
//! * a shrink evicts **by policy order**: merging sets `s` and
//!   `s + new_num_sets` keeps exactly the top-k entries of the merged
//!   population under the policy's own order (LRU recency here);
//! * a cache on which the resize machinery is exercised but never
//!   actually resized behaves bit-identically to an untouched twin (the
//!   no-resize fast path is inert);
//! * the requested-vs-effective capacity pair stays honest through
//!   construction and resizes.

use kway::kway::{build, Geometry, Variant};
use kway::policy::Policy;
use kway::util::rng::Rng;
use kway::Cache;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const THREADS: usize = 4;

#[test]
fn grow_preserves_every_entry_with_stepwise_invariants() {
    for variant in Variant::ALL {
        // 100 keys over 512 sets of 8 ways (~0.2 keys per set): a set
        // would need 9 of the 100 keys to overflow — vanishingly
        // unlikely under xxh64, and the assertions below would name the
        // variant if it ever happened.
        let c = build(variant, 4096, 8, Policy::Lru);
        for key in 0..100u64 {
            c.put(key, key + 1);
        }
        assert_eq!(c.len(), 100, "{variant:?}: warm-up fill must be complete");
        assert!(c.supports_resize(), "{variant:?}");
        assert!(c.resize(8192), "{variant:?}: grow must be accepted");
        assert!(c.resize_pending(), "{variant:?}");
        // One source set at a time, checking the invariants at every step.
        let mut steps = 0;
        while c.resize_pending() {
            c.resize_step(1);
            steps += 1;
            assert!(
                c.len() <= c.capacity(),
                "{variant:?}: len {} > capacity {} at step {steps}",
                c.len(),
                c.capacity()
            );
            assert!(
                c.weight() <= c.capacity() as u64,
                "{variant:?}: weight {} > budget {} at step {steps}",
                c.weight(),
                c.capacity()
            );
            assert!(steps <= 1024, "{variant:?}: migration must terminate");
        }
        assert_eq!(c.capacity(), 8192, "{variant:?}");
        assert_eq!(c.len(), 100, "{variant:?}: the grow must not drop entries");
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key + 1), "{variant:?}: key {key} lost in the grow");
        }
        // Post-grow inserts land in the new geometry.
        c.put(10_000, 1);
        assert_eq!(c.get(10_000), Some(1), "{variant:?}");
    }
}

#[test]
fn reads_fall_through_mid_migration() {
    for variant in Variant::ALL {
        // Same thin spread as above: no set can evict, so every miss is
        // a fall-through bug.
        let c = build(variant, 4096, 8, Policy::Lru);
        for key in 0..100u64 {
            c.put(key, key * 3);
        }
        assert!(c.resize(8192), "{variant:?}");
        // Zero sets migrated so far: every key still lives in the old
        // table and must be readable through the fall-through path.
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key * 3), "{variant:?}: key {key} unreadable mid-resize");
        }
        // Half-migrated: both tables hold entries; still no misses.
        c.resize_step(256);
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key * 3), "{variant:?}: key {key} lost at the watermark");
        }
        while c.resize_pending() {
            c.resize_step(64);
        }
    }
}

#[test]
fn shrink_evicts_by_policy_order() {
    for variant in Variant::ALL {
        let old_geo = Geometry::new(32, 4); // 8 sets
        let new_geo = old_geo.resized(16); // 4 sets
        // Pick exactly 4 keys per *old* set, so every set is full and a
        // 2:1 merge has 8 candidates for 4 ways.
        let mut per_old: HashMap<usize, Vec<u64>> = HashMap::new();
        for key in 0..4000u64 {
            let members = per_old.entry(old_geo.set_of(key)).or_default();
            if members.len() < 4 {
                members.push(key);
            }
        }
        let keys: Vec<u64> = (0..old_geo.num_sets())
            .flat_map(|s| per_old.get(&s).cloned().unwrap_or_default())
            .collect();
        assert_eq!(keys.len(), 32, "candidate range must fill every old set");

        let c = build(variant, 32, 4, Policy::Lru);
        for &key in &keys {
            c.put(key, key);
        }
        assert_eq!(c.len(), 32, "{variant:?}: every old set starts full");
        // Establish a known recency order: touch every key once in a
        // deterministic shuffled order. LRU survival is then exactly
        // "the last 4 touched of each merged set".
        let mut order = keys.clone();
        let mut rng = Rng::new(99);
        for i in (1..order.len()).rev() {
            order.swap(i, rng.index(i + 1));
        }
        let mut touch_rank: HashMap<u64, usize> = HashMap::new();
        for (rank, &key) in order.iter().enumerate() {
            assert_eq!(c.get(key), Some(key), "{variant:?}: warm key {key} must be resident");
            touch_rank.insert(key, rank);
        }

        assert!(c.resize(16), "{variant:?}");
        while c.resize_pending() {
            c.resize_step(2);
            assert!(c.len() <= c.capacity(), "{variant:?}: len bound during shrink");
        }
        assert_eq!(c.capacity(), 16, "{variant:?}");

        // Expected survivors: per merged (new) set, the 4 most recently
        // touched members — the policy order, applied to the merge.
        let mut expect: Vec<u64> = Vec::new();
        for s in 0..new_geo.num_sets() {
            let mut members: Vec<u64> =
                keys.iter().copied().filter(|&k| new_geo.set_of(k) == s).collect();
            members.sort_by_key(|k| std::cmp::Reverse(touch_rank[k]));
            expect.extend(members.into_iter().take(4));
        }
        expect.sort_unstable();
        let mut got: Vec<u64> = keys.iter().copied().filter(|&k| c.get(k).is_some()).collect();
        got.sort_unstable();
        assert_eq!(got, expect, "{variant:?}: shrink must evict in LRU order per merged set");
        assert_eq!(c.len(), 16, "{variant:?}: every merged set ends full");
    }
}

#[test]
fn churn_during_migration_loses_no_admitted_put() {
    const KEYS: u64 = 128;
    for variant in Variant::ALL {
        // 128 keys over 512 sets (4096 slots, 8 ways): sets never
        // overflow, so nothing may be evicted — any missing key after
        // the final quiescent pass is a migration bug, not policy.
        let c: Arc<dyn Cache> = Arc::from(build(variant, 4096, 8, Policy::Lru));
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for t in 0..THREADS as u64 {
            let c = c.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || {
                let mut rng = Rng::new(500 + t);
                let mut iters = 0u64;
                while !stop.load(Ordering::Acquire) || iters < 20_000 {
                    let key = rng.below(KEYS);
                    if rng.chance(0.5) {
                        c.put(key, key.wrapping_mul(31));
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key.wrapping_mul(31), "{variant:?}: phantom for {key}");
                    }
                    iters += 1;
                    if iters >= 200_000 {
                        break; // safety valve; the stop flag is the norm
                    }
                }
            }));
        }
        // Trigger the grow mid-churn and migrate slowly, checking the
        // occupancy invariants at every step. The slack of THREADS
        // covers in-flight stragglers: an op that snapshotted the
        // pre-resize epoch may briefly leave one extra copy behind.
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(c.resize(8192), "{variant:?}");
        while c.resize_pending() {
            c.resize_step(2);
            let len = c.len();
            let cap = c.capacity();
            assert!(len <= cap + THREADS, "{variant:?}: len {len} > capacity {cap} + slack");
            assert!(
                c.weight() <= (cap + THREADS) as u64,
                "{variant:?}: weight above budget mid-churn"
            );
        }
        stop.store(true, Ordering::Release);
        for w in workers {
            w.join().unwrap();
        }
        assert!(!c.resize_pending(), "{variant:?}");
        assert_eq!(c.capacity(), 8192, "{variant:?}");
        // Quiescent flush: contention may legally have dropped individual
        // racing puts ("it is a cache"), so re-put once single-threaded —
        // after which every key MUST be present: there is no contention
        // left to excuse a loss, and no set is ever full.
        for key in 0..KEYS {
            c.put(key, key.wrapping_mul(31));
        }
        for key in 0..KEYS {
            assert_eq!(
                c.get(key),
                Some(key.wrapping_mul(31)),
                "{variant:?}: admitted put of {key} lost"
            );
        }
        assert!(c.len() <= c.capacity(), "{variant:?}");
    }
}

#[test]
fn no_resize_twin_drive_stays_bit_identical() {
    for variant in Variant::ALL {
        let exercised = build(variant, 512, 8, Policy::Lru);
        let twin = build(variant, 512, 8, Policy::Lru);
        let mut rng = Rng::new(2024);
        for step in 0..6000u32 {
            let key = rng.below(2048);
            if rng.chance(0.4) {
                exercised.put(key, key ^ 0xBEEF);
                twin.put(key, key ^ 0xBEEF);
            } else {
                assert_eq!(
                    exercised.get(key),
                    twin.get(key),
                    "{variant:?}: drives diverged at step {step} (key {key})"
                );
            }
            // Exercise the inert resize machinery on one cache only: a
            // step with nothing pending, the pending probe, and (once,
            // mid-drive) a resize to the *same* capacity. None of it may
            // perturb behaviour.
            if step % 97 == 0 {
                assert_eq!(exercised.resize_step(4), 0, "{variant:?}");
                assert!(!exercised.resize_pending(), "{variant:?}");
            }
            if step == 3000 {
                assert!(exercised.resize(512), "{variant:?}: same-capacity resize is accepted");
                assert!(!exercised.resize_pending(), "{variant:?}: ...and migrates nothing");
            }
        }
        assert_eq!(exercised.len(), twin.len(), "{variant:?}: occupancy diverged");
        for key in 0..2048u64 {
            assert_eq!(exercised.get(key), twin.get(key), "{variant:?}: final state diverged");
        }
    }
}

#[test]
fn weighted_churn_across_a_grow_respects_budgets() {
    use kway::EntryOpts;
    for variant in Variant::ALL {
        let c: Arc<dyn Cache> = Arc::from(build(variant, 512, 8, Policy::Lru));
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for t in 0..2u64 {
            let c = c.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || {
                let mut rng = Rng::new(900 + t);
                while !stop.load(Ordering::Acquire) {
                    let key = rng.below(4096);
                    let weight = 1 + (key % 3) as u32;
                    c.put_with(key, key, EntryOpts::weight(weight));
                }
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.resize(1024), "{variant:?}");
        while c.resize_pending() {
            c.resize_step(4);
        }
        stop.store(true, Ordering::Release);
        for w in workers {
            w.join().unwrap();
        }
        // Quiesced: the publish/repair protocol (Release/Acquire
        // publishes + the irreducible SeqCst repair fence — see the
        // ordering argument atop kway/wfsc.rs) makes the weight bound
        // exact again (same contract as rust/tests/expiry.rs, now
        // across a geometry change).
        assert!(
            c.weight() <= c.capacity() as u64,
            "{variant:?}: weight {} > budget {} after the grow",
            c.weight(),
            c.capacity()
        );
    }
}

#[test]
fn requested_and_effective_capacity_stay_honest() {
    for variant in Variant::ALL {
        let c = build(variant, 1000, 8, Policy::Lru);
        assert_eq!(c.requested_capacity(), 1000, "{variant:?}");
        assert_eq!(c.capacity(), 1024, "{variant:?}: 125 sets round up to 128");
        assert!(c.resize(1500), "{variant:?}");
        while c.resize_pending() {
            c.resize_step(16);
        }
        assert_eq!(c.requested_capacity(), 1500, "{variant:?}");
        assert_eq!(c.capacity(), 2048, "{variant:?}: 188 sets round up to 256");
    }
}

#[test]
fn fixed_geometry_impls_refuse_resizes_honestly() {
    use kway::fully::Sampled;
    use kway::products::CaffeineLike;
    let fixed = CaffeineLike::new(256);
    assert!(!fixed.supports_resize());
    assert!(!fixed.resize(512), "a fixed-geometry cache must refuse, not pretend");
    assert_eq!(fixed.capacity(), 256);
    assert_eq!(fixed.resize_step(usize::MAX), 0);
    // The sampled baseline has real support (segment re-budgeting).
    let sampled = Sampled::with_defaults(256, 8, Policy::Lru);
    assert!(sampled.supports_resize());
    assert!(sampled.resize(512));
    assert_eq!(sampled.capacity(), 512);
}
