//! The proof layer for byte-blob values on the slab store
//! (DESIGN.md §Value store): a differential test against a
//! `HashMap<u64, Vec<u8>>` reference model, a concurrent torture test
//! that churns slab classes while an online shrink-resize runs, the
//! weight-honesty regression (reported weight ⇔ slab bytes held), and
//! the word-path twin drive (a byte-capable cache whose byte API is
//! never used must behave bit-identically to a plain word cache).
//!
//! The invariants these tests pin:
//!
//! * a `get_bytes` hit returns exactly the bytes last stored for that
//!   key — never torn, never another slot's recycled bytes;
//! * deletes (the TTL-zero tombstone idiom) and expiries read as
//!   misses, never stale values;
//! * at quiesce every slab class balances `carved = live + free`, the
//!   byte ledger equals Σ live × item_bytes, and carving never exceeds
//!   the configured cap;
//! * `Cache::weight() × 64 == Cache::value_bytes()` when every entry
//!   is a byte entry — the per-set weight budget meters bytes the slab
//!   actually holds.

use kway::kway::slab::GRANULE;
use kway::kway::{build_with_values, KwLs, KwWfa, KwWfsc, SlabStore, Variant};
use kway::lifetime::{EntryOpts, ValueDist};
use kway::policy::Policy;
use kway::util::rng::Rng;
use kway::Cache;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic payload for (key, version): differential puts change
/// the value on every overwrite, so a stale read cannot masquerade as
/// the current one.
fn payload(key: u64, version: u64, len: usize) -> Vec<u8> {
    let mut state = key ^ version.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ len as u64;
    (0..len)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8
        })
        .collect()
}

/// Lengths spanning the bottom of the class ladder: zero-length, both
/// sides of the 64 B and 128 B class boundaries, mid-ladder sizes and a
/// multi-KiB blob. All fit the differential cache's per-set budget.
const DIFF_LENS: [usize; 12] = [0, 1, 63, 64, 65, 100, 128, 129, 500, 1000, 4000, 16384];

/// 20k random get/put/delete ops against a reference `HashMap`: every
/// hit must be byte-identical to the reference; misses are always legal
/// ("it is a cache"). Runs per variant — all three publish protocols
/// (wfa claim, wfsc two-pass, ls lock) free and recycle handles.
fn differential(variant: Variant) {
    let cache = build_with_values(variant, 1024, 8, Policy::Lru, 1 << 24);
    assert!(cache.supports_values(), "{}", cache.name());
    let mut reference: HashMap<u64, Vec<u8>> = HashMap::new();
    let mut rng = Rng::new(0xD1FF ^ variant as u64);
    let mut version = 0u64;
    let mut hits = 0u64;
    for _ in 0..20_000 {
        let key = rng.below(512);
        match rng.below(10) {
            0..=5 => {
                let len = DIFF_LENS[rng.below(DIFF_LENS.len() as u64) as usize];
                version += 1;
                let value = payload(key, version, len);
                if cache.put_bytes(key, &value) {
                    reference.insert(key, value);
                }
                // On refusal the old entry (if any) stays acceptable:
                // the reference is left untouched.
            }
            6..=8 => {
                if let Some(got) = cache.get_bytes(key) {
                    hits += 1;
                    match reference.get(&key) {
                        Some(expect) => assert_eq!(
                            &got, expect,
                            "{}: key {key} returned foreign/stale/torn bytes",
                            cache.name()
                        ),
                        None => panic!(
                            "{}: key {key} hit after delete (len {})",
                            cache.name(),
                            got.len()
                        ),
                    }
                }
            }
            _ => {
                // Delete = the TTL-zero tombstone idiom; publishing the
                // tombstone releases the displaced slab handle.
                cache.put_with(key, 0, EntryOpts::ttl(Duration::ZERO));
                reference.remove(&key);
            }
        }
    }
    assert!(hits > 1000, "{}: differential never hit ({hits})", cache.name());
    assert!(cache.value_bytes() > 0, "{}: live blobs must meter bytes", cache.name());
}

#[test]
fn differential_vs_hashmap_wfa() {
    differential(Variant::Wfa);
}

#[test]
fn differential_vs_hashmap_wfsc() {
    differential(Variant::Wfsc);
}

#[test]
fn differential_vs_hashmap_ls() {
    differential(Variant::Ls);
}

#[test]
fn zero_length_and_max_size_roundtrip() {
    // A tiny key space over a generous budget: the per-way granule
    // budget admits even the largest (1 MiB) class.
    for variant in Variant::ALL {
        let cache = build_with_values(variant, 64, 8, Policy::Lru, 1 << 26);
        assert!(cache.put_bytes(1, b""), "{}: zero-length refused", cache.name());
        assert_eq!(cache.get_bytes(1).as_deref(), Some(&b""[..]), "{}", cache.name());
        let big = payload(2, 0, 1 << 20);
        assert!(cache.put_bytes(2, &big), "{}: 1 MiB refused", cache.name());
        assert_eq!(cache.get_bytes(2), Some(big), "{}", cache.name());
        let over = payload(3, 0, (1 << 20) + 1);
        assert!(!cache.put_bytes(3, &over), "{}: oversize must be refused", cache.name());
    }
}

#[test]
fn ttl_expiry_reads_as_miss_never_stale() {
    for variant in Variant::ALL {
        let cache = build_with_values(variant, 256, 8, Policy::Lru, 1 << 22);
        let value = payload(7, 0, 300);
        assert!(cache.put_bytes_with(7, &value, EntryOpts::ttl(Duration::from_millis(40))));
        assert_eq!(cache.get_bytes(7), Some(value), "{}: live before expiry", cache.name());
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(cache.get_bytes(7), None, "{}: expired blob must miss", cache.name());
    }
}

/// The word-path twin drive: the same word-only op sequence against a
/// plain cache and a byte-capable cache must be bit-identical — and the
/// byte cache's slab must stay completely unused.
#[test]
fn word_path_is_bit_identical_when_slab_unused() {
    for variant in Variant::ALL {
        let plain = kway::kway::build(variant, 1024, 8, Policy::Lru);
        let byted = build_with_values(variant, 1024, 8, Policy::Lru, 1 << 22);
        let mut rng = Rng::new(0x7 ^ variant as u64);
        for _ in 0..30_000 {
            let key = rng.below(2048);
            if rng.below(3) == 0 {
                let value = key.wrapping_mul(0x9E37);
                plain.put(key, value);
                byted.put(key, value);
            } else {
                assert_eq!(plain.get(key), byted.get(key), "{}: twin diverged", plain.name());
            }
        }
        assert_eq!(plain.len(), byted.len(), "{}", plain.name());
        assert_eq!(plain.weight(), byted.weight(), "{}", plain.name());
        assert_eq!(byted.value_bytes(), 0, "{}: word drive must not touch the slab", plain.name());
    }
}

/// Weight honesty: with only byte entries resident, the cache's
/// reported weight ×64 is exactly the slab bytes held — internal
/// fragmentation included, understating impossible.
#[test]
fn reported_weight_equals_slab_bytes_held() {
    for variant in Variant::ALL {
        let cache = build_with_values(variant, 4096, 8, Policy::Lru, 1 << 24);
        for (i, &len) in DIFF_LENS.iter().enumerate() {
            assert!(cache.put_bytes(i as u64, &payload(i as u64, 0, len)));
        }
        assert!(cache.value_bytes() > 0);
        assert_eq!(
            cache.weight() * GRANULE as u64,
            cache.value_bytes(),
            "{}: weight must meter slab bytes, not requested lengths",
            cache.name()
        );
        // And the fragmentation is the *known* ladder fragmentation: a
        // 65-byte value costs the 128-byte class.
        let store = SlabStore::new(1 << 22);
        assert_eq!(store.granules_for(65), Some(2));
        assert_eq!(store.granules_for(0), Some(1));
    }
}

/// The concurrent slab torture: churn threads overwrite, read-verify
/// and tombstone keys whose payload sizes straddle class boundaries
/// while the cache shrinks online (evictions + migration both free
/// handles); then at quiesce the ledgers must balance exactly.
fn torture(cache: Arc<dyn Cache>, store: Arc<SlabStore>) {
    const KEYS: u64 = 4096;
    // Uniform lengths 0..=2048 span the bottom ~15 slab classes.
    let dist = ValueDist::Uniform { max: 2048 };
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let cache = Arc::clone(&cache);
            let stop = &stop;
            scope.spawn(move || {
                let mut rng = Rng::new(0x70 ^ t);
                let mut buf = Vec::new();
                let mut expect = Vec::new();
                while !stop.load(Ordering::Acquire) {
                    for _ in 0..128 {
                        let key = rng.below(KEYS);
                        match rng.below(10) {
                            0..=5 => {
                                // Key-stamped payload: every writer of
                                // `key` stores identical bytes, so any
                                // hit is verifiable below.
                                dist.fill(key, &mut buf);
                                cache.put_bytes(key, &buf);
                            }
                            6..=8 => {
                                if let Some(got) = cache.get_bytes(key) {
                                    dist.fill(key, &mut expect);
                                    assert_eq!(
                                        got, expect,
                                        "key {key}: foreign/torn/recycled bytes"
                                    );
                                }
                            }
                            _ => {
                                cache.put_with(key, 0, EntryOpts::ttl(Duration::ZERO));
                            }
                        }
                    }
                }
            });
        }
        // Shrink online while the churn runs: migration re-homes live
        // handles and evicts the overflow, freeing their items.
        std::thread::sleep(Duration::from_millis(40));
        assert!(cache.resize(cache.capacity() / 2), "shrink refused");
        let deadline = std::time::Instant::now() + Duration::from_millis(400);
        while cache.resize_pending() && std::time::Instant::now() < deadline {
            cache.resize_step(32);
        }
        std::thread::sleep(Duration::from_millis(40));
        stop.store(true, Ordering::Release);
    });
    // Drive any resize tail to completion now that churn has stopped.
    while cache.resize_pending() {
        if cache.resize_step(64) == 0 {
            std::thread::yield_now();
        }
    }

    // Quiesce: every surviving blob still verifies against its key.
    let mut expect = Vec::new();
    let mut live_hits = 0u64;
    for key in 0..KEYS {
        if let Some(got) = cache.get_bytes(key) {
            dist.fill(key, &mut expect);
            assert_eq!(got, expect, "key {key} corrupt at quiesce");
            live_hits += 1;
        }
    }
    assert!(live_hits > 0, "torture ended with an empty cache");

    // Ledger balance: nothing leaked, nothing double-freed.
    let stats = store.stats();
    let mut live_bytes = 0u64;
    for c in &stats.classes {
        assert_eq!(
            c.carved,
            c.live + c.free,
            "class {}B: carved != live + free (leak or double free)",
            c.item_bytes
        );
        live_bytes += c.live * c.item_bytes as u64;
    }
    assert_eq!(live_bytes, stats.used_bytes, "byte ledger out of balance");
    assert_eq!(stats.used_bytes, cache.value_bytes(), "cache ledger != store ledger");
    assert!(stats.used_bytes <= stats.carved_bytes, "live bytes exceed carved memory");
    assert!(stats.carved_bytes <= stats.max_bytes, "carving broke the byte cap");
}

#[test]
fn torture_shrink_resize_wfa() {
    let c = KwWfa::with_value_store(2048, 8, Policy::Lru, 1 << 24);
    let store = Arc::clone(c.value_store().expect("byte cache has a store"));
    torture(Arc::new(c), store);
}

#[test]
fn torture_shrink_resize_wfsc() {
    let c = KwWfsc::with_value_store(2048, 8, Policy::Lru, 1 << 24);
    let store = Arc::clone(c.value_store().expect("byte cache has a store"));
    torture(Arc::new(c), store);
}

#[test]
fn torture_shrink_resize_ls() {
    let c = KwLs::with_value_store(2048, 8, Policy::Lru, 1 << 24);
    let store = Arc::clone(c.value_store().expect("byte cache has a store"));
    torture(Arc::new(c), store);
}
