//! The `Cache::peek_victim` contract across every implementation.
//!
//! `peek_victim` is advisory (used by TinyLFU admission): `None` means
//! "no eviction needed" *or* "no preview support", so this suite pins
//! down which implementations actually support previews — and that when
//! a preview is given, the named victim is really resident.
//!
//! Preview support: the three k-way variants (victim = policy scan of the
//! probed key's set), `sampled` (victim = sampled scan of the segment),
//! and Guava-like (victim = LRU tail of the segment). Caffeine-like and
//! segmented Caffeine inherit the trait default and always answer `None`.

use kway::kway::{build, KwWfsc, Variant};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
use kway::fully::Sampled;
use kway::Cache;

/// Fill far past capacity so every set / segment is full, then probe.
fn fill(cache: &dyn Cache, keys: u64) {
    for key in 0..keys {
        cache.put(key, key);
    }
}

#[test]
fn kway_previews_are_resident_for_every_variant_and_policy() {
    for variant in Variant::ALL {
        for policy in Policy::ALL {
            let cache = build(variant, 64, 4, policy);
            fill(&*cache, 2048);
            let mut previews = 0;
            for probe in 10_000..10_200u64 {
                if let Some(victim) = cache.peek_victim(probe) {
                    previews += 1;
                    // Values equal keys, so a resident victim returns
                    // itself; a non-resident "victim" would be a lie.
                    assert_eq!(
                        cache.get(victim),
                        Some(victim),
                        "{variant:?}/{policy:?}: previewed victim {victim} not resident"
                    );
                }
            }
            // With every set full, a preview must be produced essentially
            // always (single-threaded: no mid-publish ways to skip).
            assert!(
                previews >= 190,
                "{variant:?}/{policy:?}: only {previews}/200 previews on a full cache"
            );
        }
    }
}

#[test]
fn kway_preview_is_none_while_room_remains() {
    for variant in Variant::ALL {
        let cache = build(variant, 1024, 8, Policy::Lru);
        // A handful of inserts cannot fill any 8-way set.
        for key in 0..4u64 {
            cache.put(key, key);
        }
        for probe in 0..64u64 {
            assert_eq!(
                cache.peek_victim(probe),
                None,
                "{variant:?}: preview with empty ways must be None"
            );
        }
    }
}

#[test]
fn kway_preview_victim_shares_the_probed_set() {
    // White-box check on the concrete type: the victim must live in the
    // same set the probe key maps to (that is what the preview promises —
    // "this is who *you* would evict").
    let cache = KwWfsc::new(64, 4, Policy::Lru);
    fill(&cache, 2048);
    let geo = cache.geometry();
    let mut checked = 0;
    for probe in 10_000..10_100u64 {
        if let Some(victim) = cache.peek_victim(probe) {
            assert_eq!(
                geo.set_of(victim),
                geo.set_of(probe),
                "victim {victim} not in probe {probe}'s set"
            );
            checked += 1;
        }
    }
    assert!(checked > 0);
}

#[test]
fn sampled_and_guava_previews_are_resident() {
    let sampled = Sampled::with_defaults(256, 8, Policy::Lru);
    fill(&sampled, 4096);
    let mut previews = 0;
    for probe in 10_000..10_100u64 {
        if let Some(victim) = sampled.peek_victim(probe) {
            previews += 1;
            assert_eq!(sampled.get(victim), Some(victim), "sampled victim {victim}");
        }
    }
    assert!(previews > 0, "full sampled cache must preview victims");

    let guava = GuavaLike::new(256, 4);
    fill(&guava, 4096);
    let mut previews = 0;
    for probe in 10_000..10_100u64 {
        if let Some(victim) = guava.peek_victim(probe) {
            previews += 1;
            assert_eq!(guava.get(victim), Some(victim), "guava victim {victim}");
        }
    }
    assert!(previews > 0, "full guava cache must preview victims");
}

#[test]
fn default_inheritors_always_answer_none() {
    // Caffeine-like and segmented Caffeine silently inherit the advisory
    // default. Pin that down: if one of them grows real preview support,
    // this test should be updated alongside the TinyLFU admission wiring.
    let caffeine = CaffeineLike::new(64);
    let seg = SegmentedCaffeine::new(64, 2);
    fill(&caffeine, 2048);
    fill(&seg, 2048);
    for probe in 0..256u64 {
        assert_eq!(caffeine.peek_victim(probe), None, "CaffeineLike grew previews?");
        assert_eq!(seg.peek_victim(probe), None, "SegmentedCaffeine grew previews?");
    }
}
