//! Concurrency stress tests across every concurrent cache implementation:
//! no phantom values, bounded occupancy, progress under oversubscription,
//! and single-threaded equivalence between the three k-way variants.

use kway::fully::Sampled;
use kway::kway::{build, Variant};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
use kway::util::check::check;
use kway::util::rng::Rng;
use kway::Cache;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn all_impls(capacity: usize) -> Vec<Arc<dyn Cache>> {
    let mut v: Vec<Arc<dyn Cache>> = Vec::new();
    for variant in Variant::ALL {
        v.push(Arc::from(build(variant, capacity, 8, Policy::Lru)));
    }
    v.push(Arc::new(Sampled::with_defaults(capacity, 8, Policy::Lru)));
    v.push(Arc::new(GuavaLike::new(capacity, 4)));
    v.push(Arc::new(CaffeineLike::new(capacity)));
    v.push(Arc::new(SegmentedCaffeine::new(capacity, 4)));
    v
}

/// Values are derived from keys; readers must never observe a value that
/// does not belong to the key they asked for (torn read / phantom).
#[test]
fn no_phantom_values_under_contention() {
    for cache in all_impls(2048) {
        let cache: Arc<dyn Cache> = cache;
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let cache = cache.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xBEEF ^ t);
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) || ops < 10_000 {
                    let key = rng.below(8192);
                    if rng.chance(0.5) {
                        cache.put(key, key.wrapping_mul(0x9E37) ^ 7);
                    } else if let Some(v) = cache.get(key) {
                        assert_eq!(
                            v,
                            key.wrapping_mul(0x9E37) ^ 7,
                            "{}: phantom value for key {key}",
                            cache.name()
                        );
                    }
                    ops += 1;
                    if ops == 50_000 {
                        break;
                    }
                }
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// Occupancy must never exceed capacity (k-way exact; products may have a
/// small in-flight overshoot from their async policy, bounded here).
#[test]
fn occupancy_bounded_after_churn() {
    for cache in all_impls(1024) {
        let cache: Arc<dyn Cache> = cache;
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cache = cache.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50_000 {
                    let key = rng.next_u64() >> 16;
                    cache.put(key, key);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Allow the async products to catch up.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let len = cache.len();
        let slack = cache.capacity() / 4 + 64; // generous for async drains
        assert!(
            len <= cache.capacity() + slack,
            "{}: len {} way over capacity {}",
            cache.name(),
            len,
            cache.capacity()
        );
    }
}

/// The three k-way variants implement the same abstract cache: driven
/// single-threaded with the same inputs they must give identical hit/miss
/// sequences (KW-LS upgrades always succeed without contention).
#[test]
fn kway_variants_agree_single_threaded() {
    check("variants-agree", 10, |rng| {
        let caches: Vec<Box<dyn Cache>> = Variant::ALL
            .iter()
            .map(|&v| build(v, 512, 8, Policy::Lru))
            .collect();
        for _ in 0..5_000 {
            let key = rng.below(2048);
            let read = rng.chance(0.6);
            let mut outcomes = Vec::new();
            for c in &caches {
                if read {
                    outcomes.push(c.get(key).is_some());
                } else {
                    c.put(key, key);
                    outcomes.push(true);
                }
            }
            assert!(
                outcomes.windows(2).all(|w| w[0] == w[1]),
                "variant divergence on key {key}: {outcomes:?}"
            );
        }
    });
}

/// Worst-case contention: a single set hammered by 8 threads must still
/// make progress (no livelock) and stay bounded.
#[test]
fn single_set_hotspot_makes_progress() {
    for variant in [Variant::Wfa, Variant::Wfsc] {
        // Capacity 8 with 8 ways = ONE set.
        let cache: Arc<dyn Cache> = Arc::from(build(variant, 8, 8, Policy::Lfu));
        let start = std::time::Instant::now();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let cache = cache.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..20_000 {
                    let key = rng.below(64);
                    if cache.get(key).is_none() {
                        cache.put(key, key);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            start.elapsed() < std::time::Duration::from_secs(30),
            "{variant:?} single-set hotspot took too long (livelock?)"
        );
        assert!(cache.len() <= 8);
    }
}

/// Concurrent duplicates of the same key converge to one of the written
/// values.
#[test]
fn concurrent_same_key_put_converges() {
    for cache in all_impls(256) {
        let cache: Arc<dyn Cache> = cache;
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cache = cache.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    cache.put(42, 1000 + (t * 10_000 + i) % 7);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        match cache.get(42) {
            Some(v) => assert!((1000..1007).contains(&v), "{}: bad value {v}", cache.name()),
            None => {
                // Eviction is legal (it's a cache) but with capacity 256
                // and one hot key it would indicate a bug for k-way.
                assert!(
                    !cache.name().starts_with("KW"),
                    "{}: hot key vanished",
                    cache.name()
                );
            }
        }
    }
}
