//! The loopback torture test: one serving stack driven through every
//! overload and fault path at once — pipelined load with a worker panic
//! mid-run, a slow client that stops reading, and a connection storm
//! past `--max-conns` — then checked for the only things that matter
//! under chaos: no deadlock (the test finishes), no admitted put lost
//! once the fault window closes, counters that match what clients saw,
//! and a clean shutdown.
//!
//! The epoll backend is Linux/x86_64 only, and the injected worker
//! panic needs the `fault-inject` feature; the guard-only phases run
//! without it.

#![cfg(all(target_os = "linux", target_arch = "x86_64"))]

mod torture {
    use kway::coordinator::{CacheService, ServiceConfig};
    use kway::kway::KwWfsc;
    use kway::net::{Server, ServerConfig};
    use kway::policy::Policy;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Duration;

    #[cfg(feature = "fault-inject")]
    const MAX_CONNS: usize = 32;
    #[cfg(feature = "fault-inject")]
    const SEEDED: std::ops::Range<u64> = 900_000..900_200;

    fn connect(server: &Server) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    fn expect_lines(reader: &mut BufReader<TcpStream>, expected: &[String]) {
        for want in expected {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert_eq!(line.trim_end_matches(['\r', '\n']), want);
        }
    }

    /// Read `stats` output into (name, value) pairs, consuming `END`.
    /// Non-integer stats (`io_backend`, `syscalls_per_op`) are skipped —
    /// the chaos assertions only consume counters.
    #[cfg(feature = "fault-inject")]
    fn read_stats(reader: &mut BufReader<TcpStream>) -> Vec<(String, u64)> {
        let mut pairs = Vec::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let line = line.trim_end_matches(['\r', '\n']);
            if line == "END" {
                return pairs;
            }
            let mut parts = line.splitn(3, ' ');
            assert_eq!(parts.next(), Some("STAT"), "unexpected stats line {line:?}");
            let name = parts.next().unwrap().to_string();
            if let Ok(value) = parts.next().unwrap().parse::<u64>() {
                pairs.push((name, value));
            }
        }
    }

    #[test]
    #[cfg(feature = "fault-inject")]
    fn torture_survives_panics_slow_clients_and_conn_storms() {
        use kway::fault::FaultPlan;
        use kway::net::loadgen::{self, LoadgenConfig, WireProto};
        // Capacity far above the resident set (~2.3k keys over 8k sets of
        // 8 ways) so no admitted put can be evicted by load: any lost key
        // at the end is a real durability bug, not cache policy.
        let plan = Arc::new(FaultPlan::parse("worker_panic@30ms").unwrap());
        let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(65_536, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, faults: Some(plan.clone()), ..Default::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = Server::start(
            listener,
            Arc::clone(&service),
            ServerConfig {
                io_threads: 2,
                max_conns: MAX_CONNS,
                max_wq_bytes: 32 * 1024,
                idle_timeout: Some(Duration::from_secs(30)),
                request_deadline: Some(Duration::from_secs(30)),
                faults: Some(plan.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let metrics = service.metrics();

        // Phase 1 — seed: admitted puts, each acknowledged STORED. These
        // must all still be readable after the chaos.
        let (mut seed, mut seed_r) = connect(&server);
        for k in SEEDED {
            seed.write_all(format!("set {k} 0 0 8\r\n{k:08}\r\n").as_bytes()).unwrap();
            expect_lines(&mut seed_r, &["STORED".to_string()]);
        }
        drop(seed);
        drop(seed_r);

        // Phase 2 — pipelined load with a worker panic mid-run. Each
        // arm() opens one one-shot panic window; retry a few times in
        // case a run lands no op inside it (never seen in practice).
        let mut cfg = LoadgenConfig::smoke(&server.local_addr().to_string(), WireProto::Memcached);
        cfg.connections = 4;
        cfg.pipeline = 16;
        cfg.threads = 2;
        cfg.duration = Duration::from_millis(400);
        cfg.keyspace = 2048;
        cfg.set_every = 4;
        cfg.max_reconnects = 10_000;
        cfg.faults = Some(plan.clone());
        let mut result = None;
        for _ in 0..5 {
            plan.arm();
            result = Some(loadgen::run(&cfg).unwrap());
            plan.disarm();
            if metrics.worker_restarts.load(Ordering::Relaxed) > 0 {
                break;
            }
        }
        let result = result.unwrap();
        assert!(result.ops > 0, "pipelined load made no progress");
        let restarts = metrics.worker_restarts.load(Ordering::Relaxed);
        assert!(restarts >= 1, "worker panic was injected but never survived a restart");
        // Degraded answers are misses, not protocol errors: the wire
        // stayed clean through the panic.
        assert_eq!(result.errors, 0, "worker panic leaked protocol errors to clients");

        // Phase 3 — slow client: pipelines thousands of gets and never
        // reads. Once the kernel buffers fill, the queued response bytes
        // cross max_wq_bytes and the server cuts the connection loose.
        let (mut slow, _slow_r) = connect(&server);
        let burst = "get 1\r\n".repeat(40_000);
        let _ = slow.write_all(burst.as_bytes());
        let mut evicted = 0;
        for _ in 0..300 {
            evicted = metrics.evicted_slow.load(Ordering::Relaxed);
            if evicted > 0 {
                break;
            }
            let _ = slow.write_all(b"get 1\r\n");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(evicted >= 1, "slow client was never evicted past the write-queue cap");
        drop(slow);

        // Phase 4 — connection storm: 3x the accept limit, held open.
        // Refused connections still get an answer before the close.
        let mut held = Vec::new();
        let mut served = 0u64;
        let mut refused = 0u64;
        for _ in 0..3 * MAX_CONNS {
            let mut s = TcpStream::connect(server.local_addr()).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let _ = s.write_all(b"version\r\n");
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            match r.read_line(&mut line) {
                Ok(n) if n > 0 && line.starts_with("VERSION") => {
                    served += 1;
                    held.push((s, r));
                }
                _ => {
                    assert!(
                        line.is_empty() || line.starts_with("SERVER_ERROR too many connections"),
                        "refusal must be explicit, got {line:?}"
                    );
                    refused += 1;
                }
            }
        }
        assert!(served >= 1, "storm starved every connection");
        assert!(refused >= 1, "storm never tripped max-conns");
        // Counters match: the server refused at least every refusal a
        // client observed (it may also have counted ones whose answer
        // was lost in the close race).
        assert!(metrics.rejected_conns.load(Ordering::Relaxed) >= refused);
        drop(held);
        std::thread::sleep(Duration::from_millis(200));

        // Phase 5 — recovery: every admitted put from before the chaos
        // is still there, byte for byte.
        let (mut check, mut check_r) = connect(&server);
        for k in SEEDED {
            check.write_all(format!("get {k}\r\n").as_bytes()).unwrap();
            expect_lines(
                &mut check_r,
                &[format!("VALUE {k} 0 8"), format!("{k:08}"), "END".to_string()],
            );
        }

        // Counters over the wire agree with the in-process metrics.
        check.write_all(b"stats\r\n").unwrap();
        let pairs = read_stats(&mut check_r);
        let stat = |name: &str| {
            pairs
                .iter()
                .find(|(n, _)| n == name)
                .unwrap_or_else(|| panic!("stats missing {name}"))
                .1
        };
        assert_eq!(stat("worker_restarts"), metrics.worker_restarts.load(Ordering::Relaxed));
        assert_eq!(stat("rejected_conns"), metrics.rejected_conns.load(Ordering::Relaxed));
        assert_eq!(stat("evicted_slow_clients"), metrics.evicted_slow.load(Ordering::Relaxed));
        assert!(stat("gets") > 0 && stat("puts") > 0);
        drop(check);
        drop(check_r);

        // Phase 6 — clean shutdown: stop() joins the io threads, halt()
        // joins the workers; nothing hangs, and late ops degrade.
        server.stop();
        service.halt();
        assert_eq!(service.get(SEEDED.start), None, "post-shutdown op must degrade to a miss");
    }

    /// The overload guards alone (no fault injection): exceeding the
    /// accept limit refuses with an answer, and the stack still serves
    /// and shuts down cleanly afterwards.
    #[test]
    fn accept_limit_holds_without_fault_injection() {
        let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..Default::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = Server::start(
            listener,
            Arc::clone(&service),
            ServerConfig { io_threads: 1, max_conns: 2, ..Default::default() },
        )
        .unwrap();
        let (mut a, mut a_r) = connect(&server);
        let (_b, _b_r) = connect(&server);
        a.write_all(b"set 1 0 0 1\r\n7\r\n").unwrap();
        expect_lines(&mut a_r, &["STORED".to_string()]);
        // Third connection: over the limit, answered then closed.
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut c_r = BufReader::new(c.try_clone().unwrap());
        let _ = c.write_all(b"version\r\n");
        let mut line = String::new();
        let _ = c_r.read_line(&mut line);
        assert!(
            line.is_empty() || line.starts_with("SERVER_ERROR too many connections"),
            "got {line:?}"
        );
        // The admitted connections keep serving.
        a.write_all(b"get 1\r\n").unwrap();
        expect_lines(&mut a_r, &["VALUE 1 0 1".to_string(), "7".to_string(), "END".to_string()]);
        assert!(service.metrics().rejected_conns.load(Ordering::Relaxed) >= 1);
        server.stop();
        service.halt();
    }
}
