//! The `Cache::get_batch`/`put_batch` contract across every
//! implementation.
//!
//! The k-way caches override the batched defaults with prefetching fast
//! paths; everything else (`products::*`, `fully::Sampled`, the TinyLFU
//! admission wrapper) inherits the trait defaults. Nothing pinned the
//! defaults' semantics until now, so this suite does: results are
//! **appended** to `out` with exactly one entry per key, in input order
//! (`out[i]` answers `keys[i]` when `out` starts empty), and `put_batch`
//! applies its items in input order (last write of a duplicate key wins).
//!
//! Key-count note: 300 keys over ≥ 512 sets stays far below any 8-way
//! set's capacity (same bound the per-impl unit tests use), so none of
//! the assertions can be disturbed by evictions.

use kway::fully::Sampled;
use kway::kway::{build, KwWfsc, Variant};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
use kway::tinylfu::TlfuCache;
use kway::util::rng::Rng;
use kway::Cache;

/// One of every `Cache` implementation in the crate, at a capacity large
/// enough that the test keys never face eviction.
fn lineup() -> Vec<Box<dyn Cache>> {
    let capacity = 4096;
    let mut v: Vec<Box<dyn Cache>> = Vec::new();
    for variant in Variant::ALL {
        v.push(build(variant, capacity, 8, Policy::Lru));
    }
    v.push(Box::new(Sampled::with_defaults(capacity, 8, Policy::Lru)));
    v.push(Box::new(GuavaLike::new(capacity, 4)));
    v.push(Box::new(CaffeineLike::new(capacity)));
    v.push(Box::new(SegmentedCaffeine::new(capacity, 4)));
    v.push(Box::new(TlfuCache::new(KwWfsc::new(capacity, 8, Policy::Lru), capacity)));
    v
}

#[test]
fn put_batch_then_get_batch_round_trips_in_input_order() {
    for cache in lineup() {
        let items: Vec<(u64, u64)> =
            (0..300u64).map(|k| (k, k.wrapping_mul(31) + 7)).collect();
        cache.put_batch(&items);
        let keys: Vec<u64> = items.iter().map(|&(k, _)| k).collect();
        let mut out = Vec::new();
        cache.get_batch(&keys, &mut out);
        assert_eq!(out.len(), keys.len(), "{}: one result per key", cache.name());
        for (i, &(k, v)) in items.iter().enumerate() {
            assert_eq!(out[i], Some(v), "{}: position {i} key {k}", cache.name());
        }
    }
}

#[test]
fn get_batch_matches_scalar_gets_positionally_with_misses() {
    for cache in lineup() {
        for key in 0..300u64 {
            cache.put(key, key ^ 0x5A5A);
        }
        // Shuffled mix of residents and misses: out[i] must answer
        // keys[i], not some reordered or compacted result.
        let mut keys: Vec<u64> = (0..600u64).collect();
        Rng::new(7).shuffle(&mut keys);
        let mut out = Vec::new();
        cache.get_batch(&keys, &mut out);
        assert_eq!(out.len(), keys.len(), "{}", cache.name());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(out[i], cache.get(key), "{}: position {i} key {key}", cache.name());
        }
    }
}

#[test]
fn get_batch_appends_to_a_non_empty_buffer() {
    // The documented contract is "out[i] answers keys[i] when out starts
    // empty"; the appending behaviour behind it (reuse-friendly caller
    // buffers) must hold for overrides and defaults alike.
    for cache in lineup() {
        cache.put(1, 11);
        cache.put(2, 22);
        let mut out = vec![Some(999u64)];
        cache.get_batch(&[1, 2], &mut out);
        assert_eq!(
            out,
            vec![Some(999), Some(11), Some(22)],
            "{}: batched results must append",
            cache.name()
        );
    }
}

#[test]
fn put_batch_applies_duplicates_in_input_order() {
    for cache in lineup() {
        cache.put_batch(&[(5, 1), (5, 2), (5, 3)]);
        assert_eq!(
            cache.get(5),
            Some(3),
            "{}: last write of a duplicate key must win",
            cache.name()
        );
    }
}

#[test]
fn empty_batches_are_noops() {
    for cache in lineup() {
        let mut out = Vec::new();
        cache.get_batch(&[], &mut out);
        assert!(out.is_empty(), "{}", cache.name());
        cache.put_batch(&[]);
        assert_eq!(cache.len(), 0, "{}", cache.name());
    }
}
