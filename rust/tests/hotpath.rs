//! Hot-path differential tests (DESIGN.md §Hot path).
//!
//! Three layers of evidence that the vectorized fingerprint probe is an
//! optimization, not a semantic change:
//!
//! 1. **Mask differential** — every probe kernel the CPU supports
//!    (scalar / SWAR / SSE2 / AVX2, via `match_mask_kind`, which never
//!    touches the process-wide override) must return bit-identical
//!    match masks over randomized word arrays, including the `EMPTY`
//!    (0) and `MIGRATING` (2) sentinels, colliding fingerprints, every
//!    way count the engine supports and unaligned sub-slices.
//! 2. **Cache-level differential** — one populated KW-WFSC probed for
//!    the same keys under each *forced* kernel answers identically.
//!    This is the only test in the binary that calls `simd::force`
//!    (the override is process-wide; `cargo test` runs tests on shared
//!    threads, so a second caller would race it).
//! 3. **Relaxed-ordering churn** — the memory-ordering audit replaced
//!    the SeqCst publish path with Release/Acquire pairs (see the
//!    safety arguments at the top of `kway/wfsc.rs` and `kway/wfa.rs`);
//!    the multi-thread churn here re-runs the no-phantom and
//!    quiesced-weight-bound claims under those weaker orderings, with
//!    TTLs and weights in play.

use kway::kway::simd::{self, ProbeKind};
use kway::kway::{KwLs, KwWfa, KwWfsc};
use kway::lifetime::EntryOpts;
use kway::policy::Policy;
use kway::util::hash;
use kway::util::rng::Rng;
use kway::Cache;
use std::sync::atomic::AtomicU64;
use std::time::Duration;

/// Sentinel values the WFSC fingerprint array actually holds: real
/// fingerprints are `mix64(key) | 1` (odd), `EMPTY` is 0, `MIGRATING`
/// is 2 (even, so no live fingerprint collides with it).
const EMPTY: u64 = 0;
const MIGRATING: u64 = 2;

fn atomic_words(values: &[u64]) -> Vec<AtomicU64> {
    values.iter().map(|&v| AtomicU64::new(v)).collect()
}

/// The reference answer: a plain scalar scan.
fn reference_mask(values: &[u64], needle: u64) -> u128 {
    let mut mask = 0u128;
    for (i, &v) in values.iter().enumerate() {
        if v == needle {
            mask |= 1 << i;
        }
    }
    mask
}

fn assert_all_kinds_agree(values: &[u64], needle: u64, what: &str) {
    let words = atomic_words(values);
    let expect = reference_mask(values, needle);
    for kind in ProbeKind::available() {
        let got = simd::match_mask_kind(kind, &words, needle);
        assert_eq!(
            got,
            expect,
            "{what}: {} disagrees with the scalar reference (needle {needle:#x}, k={})",
            kind.name(),
            values.len()
        );
    }
}

#[test]
fn mask_differential_randomized_across_kinds() {
    let mut rng = Rng::new(0xD1FF);
    // Every way count the engine supports, including non-vector-multiple
    // and max widths; 1..3 exercise the kernels' scalar tails alone.
    for k in [1usize, 2, 3, 4, 5, 7, 8, 12, 16, 31, 32, 64, 128] {
        for round in 0..50 {
            let mut values: Vec<u64> = (0..k)
                .map(|_| match rng.below(10) {
                    0 => EMPTY,
                    1 => MIGRATING,
                    // Realistic odd fingerprints from a small key space,
                    // so within-set collisions actually happen.
                    _ => hash::fingerprint(rng.below(16)),
                })
                .collect();
            // The needle is drawn from the same palette, so some rounds
            // have multiple matches and some none.
            let needle = match rng.below(4) {
                0 => EMPTY,
                1 => MIGRATING,
                _ => hash::fingerprint(rng.below(16)),
            };
            assert_all_kinds_agree(&values, needle, "randomized");
            // Forced full-match round: every lane equals the needle.
            if round == 0 {
                values.iter_mut().for_each(|v| *v = needle);
                assert_all_kinds_agree(&values, needle, "all-match");
            }
        }
    }
}

#[test]
fn mask_differential_half_word_adversary() {
    // Values agreeing with the needle in exactly one 32-bit half: the
    // SSE2 kernel has no 64-bit compare and builds one from two 32-bit
    // compares — these inputs fail if the halves are combined wrongly.
    let needle = 0xABCD_1234_5678_9EF1u64;
    let low_only = (needle & 0xFFFF_FFFF) | 0xDEAD_0000_0000_0000;
    let high_only = (needle & !0xFFFF_FFFF) | 0x1357_9BDF;
    let values = [low_only, needle, high_only, needle, low_only ^ 2, high_only ^ 2, EMPTY, needle];
    assert_all_kinds_agree(&values, needle, "half-word adversary");
}

#[test]
fn mask_differential_unaligned_subslices() {
    // The engine hands `match_mask` the sub-slice `fps[start..start+k]`;
    // with the 64-byte base alignment a k=8 set is always line-aligned,
    // but the kernels must not *require* that. Probe every offset into a
    // longer array so SSE2/AVX2 see genuinely unaligned loads.
    let mut rng = Rng::new(0xA11);
    let backing: Vec<u64> = (0..64).map(|_| hash::fingerprint(rng.below(8))).collect();
    for start in 0..32 {
        for k in [2usize, 4, 8, 16] {
            let window = &backing[start..start + k];
            let needle = backing[start + rng.below(k as u64) as usize];
            assert_all_kinds_agree(window, needle, "unaligned window");
        }
    }
}

#[test]
fn mask_differential_empty_slice() {
    // k=0 never happens in the engine, but the kernels must not read
    // out of bounds to answer it.
    for kind in ProbeKind::available() {
        assert_eq!(simd::match_mask_kind(kind, &[], 7), 0, "{}", kind.name());
    }
}

/// The one test allowed to touch the process-wide `simd::force`
/// override: a single populated cache, probed for the same keys under
/// every forced kernel, must answer get/peek identically. Runs across
/// all policies (victim choice differs; probe semantics must not) —
/// including `Random`, which is why one cache is probed repeatedly
/// rather than two caches compared (Random's thread-local RNG would
/// diverge two otherwise-identical caches' eviction choices).
#[test]
fn forced_kinds_answer_identically_on_a_live_cache() {
    for policy in Policy::ALL {
        let cache = KwWfsc::new(4096, 8, policy);
        let mut rng = Rng::new(0xCAFE ^ policy as u64);
        // Overfill by 2x so sets are full and fingerprints collide.
        for _ in 0..8192 {
            let k = rng.below(6000);
            cache.put(k, k.wrapping_mul(31));
        }
        // Quiescent now: the probe kernels may only differ in speed.
        let probe_keys: Vec<u64> = (0..2000).map(|_| rng.below(6000)).collect();
        let reference: Vec<Option<u64>> = {
            simd::force(Some(ProbeKind::Scalar));
            probe_keys.iter().map(|&k| cache.get(k)).collect()
        };
        for kind in ProbeKind::available() {
            simd::force(Some(kind));
            for (i, &k) in probe_keys.iter().enumerate() {
                assert_eq!(
                    cache.get(k),
                    reference[i],
                    "{} vs scalar on key {k} under {:?}",
                    kind.name(),
                    policy
                );
            }
        }
        simd::force(None);
        // A hit must carry the value the key was last published with.
        for &k in &probe_keys {
            if let Some(v) = cache.get(k) {
                assert_eq!(v, k.wrapping_mul(31), "phantom value for key {k}");
            }
        }
    }
}

/// Multi-thread churn under the audited (relaxed) orderings: readers,
/// writers with TTLs and weights, and a sweeper all hammer one cache;
/// afterwards no phantom values exist and the quiesced per-set weight
/// bound of the PR 3 claim still holds — re-derived for Release/Acquire
/// in the module safety arguments, re-checked empirically here.
fn relaxed_ordering_churn<C: Cache>(cache: &C, seed: u64) {
    let keyspace = 4096u64;
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ t);
                for i in 0..30_000u64 {
                    let key = rng.below(keyspace);
                    match rng.below(10) {
                        // Readers: a hit must never observe a torn pair.
                        0..=4 => {
                            if let Some(v) = cache.get(key) {
                                assert_eq!(
                                    v,
                                    key.wrapping_mul(31),
                                    "phantom read under relaxed orderings (key {key})"
                                );
                            }
                        }
                        // Weighted writers against the per-set budget.
                        5..=7 => {
                            let w = 1 + (rng.below(4) as u32);
                            cache.put_with(
                                key,
                                key.wrapping_mul(31),
                                EntryOpts::weight(w),
                            );
                        }
                        // TTL writers: half already-dead, half short-lived.
                        8 => {
                            let opts = if i % 2 == 0 {
                                EntryOpts::ttl(Duration::ZERO)
                            } else {
                                EntryOpts::ttl(Duration::from_millis(5))
                            };
                            cache.put_with(key, key.wrapping_mul(31), opts);
                        }
                        // Sweeper: reclaims expired lines concurrently.
                        _ => {
                            cache.sweep_expired(16);
                        }
                    }
                }
            });
        }
    });
}

#[test]
fn relaxed_orderings_keep_wfsc_phantom_free_and_weight_bounded() {
    let cache = KwWfsc::new(1024, 8, Policy::Lru);
    relaxed_ordering_churn(&cache, 0x5EED_1);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-WFSC: quiesced set weight {max} exceeds the budget of 8");
    assert!(cache.weight() <= cache.capacity() as u64);
}

#[test]
fn relaxed_orderings_keep_wfa_phantom_free_and_weight_bounded() {
    let cache = KwWfa::new(1024, 8, Policy::Lru);
    relaxed_ordering_churn(&cache, 0x5EED_2);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-WFA: quiesced set weight {max} exceeds the budget of 8");
    assert!(cache.weight() <= cache.capacity() as u64);
}

#[test]
fn relaxed_orderings_keep_ls_phantom_free_and_weight_bounded() {
    // KW-LS is lock-based — unchanged by the audit — but runs the same
    // churn as the behavioral control group.
    let cache = KwLs::new(1024, 8, Policy::Lru);
    relaxed_ordering_churn(&cache, 0x5EED_3);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-LS: set weight {max} exceeds the budget of 8");
    assert!(cache.weight() <= cache.capacity() as u64);
}
