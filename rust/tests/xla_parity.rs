//! Integration: the AOT-compiled XLA artifacts (Layers 1–2) agree with the
//! native rust implementations (Layer 3) on every shared computation.
//!
//! Requires `make artifacts` *and a real PJRT runtime*. The offline
//! container vendors an `xla` stub whose client constructor always fails
//! (DESIGN.md §Offline build), so these tests can never pass there; they
//! are compiled out unless the `pjrt` feature is enabled on a machine with
//! a real xla-rs build:
//!
//! ```bash
//! cargo test --features pjrt --test xla_parity
//! ```
#![cfg(feature = "pjrt")]

use kway::runtime::{lit_i32, to_vec, XlaRuntime};
use kway::sim::xla::{fp31, NativeSetSim, XlaSim};
use kway::trace::paper;
use kway::util::rng::Rng;

/// PJRT handles are not `Sync`, so each test builds its own runtime.
fn load_runtime() -> XlaRuntime {
    let dir = std::env::var("KWAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    XlaRuntime::load(&dir).unwrap_or_else(|e| {
        panic!("failed to load artifacts from {dir:?} (run `make artifacts` first): {e:#}")
    })
}

#[test]
fn runtime_loads_all_manifest_entries() {
    let rt = load_runtime();
    let rt = &rt;
    let platform = rt.platform().to_lowercase();
    assert!(
        platform.contains("cpu") || platform.contains("host"),
        "unexpected platform {platform:?}"
    );
    let names = rt.entry_names();
    for expected in [
        "victim_select_lru_k4",
        "victim_select_lru_k8",
        "victim_select_lru_k16",
        "victim_select_hyperbolic_k8",
        "set_probe_k8",
        "sketch_estimate",
        "sketch_update",
        "cache_sim_k8",
    ] {
        assert!(names.contains(&expected), "missing artifact {expected}; have {names:?}");
    }
}

#[test]
fn victim_select_matches_native_argmin() {
    let rt = load_runtime();
    let rt = &rt;
    let spec = rt.manifest().entry("victim_select_lru_k8").unwrap();
    let b = spec.require("batch").unwrap() as usize;
    let k = spec.require("k").unwrap() as usize;

    let mut rng = Rng::new(1);
    let counters: Vec<i32> = (0..b * k).map(|_| (rng.below(1 << 20)) as i32).collect();
    let out = rt
        .execute(
            "victim_select_lru_k8",
            &[lit_i32(&counters, &[b as i64, k as i64]).unwrap()],
        )
        .unwrap();
    let got = to_vec::<i32>(&out[0]).unwrap();
    assert_eq!(got.len(), b);
    for (row, &victim) in got.iter().enumerate() {
        let slice = &counters[row * k..(row + 1) * k];
        let native = slice
            .iter()
            .enumerate()
            .min_by_key(|&(i, &v)| (v, i))
            .map(|(i, _)| i as i32)
            .unwrap();
        assert_eq!(victim, native, "row {row}: {slice:?}");
    }
}

#[test]
fn set_probe_matches_native_scan() {
    let rt = load_runtime();
    let rt = &rt;
    let spec = rt.manifest().entry("set_probe_k8").unwrap();
    let b = spec.require("batch").unwrap() as usize;
    let k = spec.require("k").unwrap() as usize;

    let mut rng = Rng::new(2);
    // Small fingerprint universe so both hits and misses occur.
    let fps: Vec<i32> = (0..b * k).map(|_| 1 + rng.below(40) as i32).collect();
    let probes: Vec<i32> = (0..b).map(|_| 1 + rng.below(40) as i32).collect();
    let out = rt
        .execute(
            "set_probe_k8",
            &[
                lit_i32(&fps, &[b as i64, k as i64]).unwrap(),
                lit_i32(&probes, &[b as i64]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec::<i32>(&out[0]).unwrap();
    let mut hits = 0;
    for row in 0..b {
        let slice = &fps[row * k..(row + 1) * k];
        let native = slice.iter().position(|&f| f == probes[row]).map(|i| i as i32).unwrap_or(-1);
        assert_eq!(got[row], native, "row {row}");
        if native >= 0 {
            hits += 1;
        }
    }
    assert!(hits > 0, "degenerate test: no probe hits");
    assert!(hits < b, "degenerate test: no probe misses");
}

#[test]
fn cache_sim_artifact_matches_native_simulator() {
    let rt = load_runtime();
    let rt = &rt;
    let sim = XlaSim::new(rt, "cache_sim_k8").unwrap();
    assert_eq!(sim.capacity(), 2048, "paper's small-trace cache size 2^11");

    // A real trace model, long enough to cross several chunks.
    let trace = paper::build("oltp", 3 * sim.chunk + 517, 9).unwrap();
    let xla_stats = sim.run(&trace).unwrap();

    let mut native = NativeSetSim::new(sim.num_sets, sim.ways);
    let native_stats = native.run(&trace.keys);

    assert_eq!(xla_stats.accesses, native_stats.accesses);
    assert_eq!(
        xla_stats.hits, native_stats.hits,
        "XLA and native simulators must agree exactly (xla={} native={})",
        xla_stats.hits, native_stats.hits
    );
    assert!(xla_stats.hits > 0, "degenerate: zero hits");
}

#[test]
fn sketch_estimate_matches_native_min() {
    let rt = load_runtime();
    let rt = &rt;
    let spec = rt.manifest().entry("sketch_estimate").unwrap();
    let d = spec.require("depth").unwrap() as usize;
    let w = spec.require("width").unwrap() as usize;
    let b = spec.require("batch").unwrap() as usize;

    let mut rng = Rng::new(3);
    let rows: Vec<i32> = (0..d * w).map(|_| rng.below(16) as i32).collect();
    let idx: Vec<i32> = (0..b * d).map(|_| rng.below(w as u64) as i32).collect();
    let out = rt
        .execute(
            "sketch_estimate",
            &[
                lit_i32(&rows, &[d as i64, w as i64]).unwrap(),
                lit_i32(&idx, &[b as i64, d as i64]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec::<i32>(&out[0]).unwrap();
    for bi in 0..b {
        let native = (0..d).map(|j| rows[j * w + idx[bi * d + j] as usize]).min().unwrap();
        assert_eq!(got[bi], native, "batch row {bi}");
    }
}

#[test]
fn fp31_is_consistent_between_backends() {
    // The XlaSim host code and NativeSetSim share fp31; spot-check the
    // domain properties the artifact relies on (positive, non-zero).
    for key in (0..10_000u64).chain([u64::MAX, u64::MAX - 2]) {
        assert!(fp31(key) > 0);
    }
}

#[test]
fn setpar_artifact_matches_native_simulator() {
    let rt = load_runtime();
    let sim = kway::sim::xla::SetParSim::new(&rt, "cache_sim_setpar_k8").unwrap();
    assert_eq!(sim.capacity(), 2048);
    // Three skew levels: Zipf-hot (oltp), near-uniform (w3), drifting
    // working set (sprite). Exact hit parity is required on all — the
    // cross-set reordering and host-side run compression must be
    // invisible in the totals.
    for trace_name in ["oltp", "w3", "sprite"] {
        let trace = paper::build(trace_name, 40_000, 13).unwrap();
        let xla = sim.run(&trace).unwrap();
        let native =
            NativeSetSim::new(sim.num_sets, sim.ways).run(&trace.keys);
        assert_eq!(
            xla.hits, native.hits,
            "set-parallel vs native divergence on {trace_name}"
        );
        assert_eq!(xla.accesses, native.accesses);
    }
}
