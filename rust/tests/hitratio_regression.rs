//! Hit-ratio regression tests: the qualitative *shapes* from the paper's
//! evaluation that must hold on the trace models (DESIGN.md
//! §Per-experiment index, "expected shapes").

use kway::kway::Variant;
use kway::policy::Policy;
use kway::sim::{self, Config};
use kway::trace::paper;

fn ratio(trace: &kway::trace::Trace, capacity: usize, cfg: &Config) -> f64 {
    let mut cache = cfg.build(capacity, 7);
    sim::run(cache.as_mut(), &trace.keys).ratio()
}

fn kway(ways: usize, policy: Policy, tlfu: bool) -> Config {
    Config::KWay { variant: Variant::Wfsc, ways, policy, tlfu }
}

/// Shape (i): the 8-way vs fully-associative gap is marginal on every
/// trace model, for LRU.
#[test]
fn eight_way_close_to_full_lru_everywhere() {
    for (trace_name, capacity) in [
        ("wiki_a", 4096),
        ("sprite", 1024),
        ("oltp", 2048),
        ("multi1", 2048),
        ("f1", 2048),
        ("p8", 4096),
    ] {
        let trace = paper::build(trace_name, 300_000, 3).unwrap();
        let full = ratio(&trace, capacity, &Config::FullLru { tlfu: false });
        let k8 = ratio(&trace, capacity, &kway(8, Policy::Lru, false));
        assert!(
            (full - k8).abs() <= 0.05,
            "{trace_name}: full {full:.4} vs 8-way {k8:.4}"
        );
    }
}

/// Shape (i) continued: the gap shrinks (weakly) as associativity grows.
#[test]
fn associativity_gap_shrinks_with_k() {
    let trace = paper::build("wiki_a", 300_000, 4).unwrap();
    let capacity = 4096;
    let full = ratio(&trace, capacity, &Config::FullLru { tlfu: false });
    let gap4 = (full - ratio(&trace, capacity, &kway(4, Policy::Lru, false))).abs();
    let gap64 = (full - ratio(&trace, capacity, &kway(64, Policy::Lru, false))).abs();
    assert!(gap64 <= gap4 + 0.005, "gap4 {gap4:.4} gap64 {gap64:.4}");
}

/// Shape (ii): sampled eviction ≈ limited associativity at equal budget
/// (sample size = ways), as the paper observes in subfigures (a)/(b).
#[test]
fn sampled_and_kway_comparable() {
    for trace_name in ["oltp", "wiki_a", "multi2"] {
        let trace = paper::build(trace_name, 300_000, 5).unwrap();
        let capacity = 2048;
        let k8 = ratio(&trace, capacity, &kway(8, Policy::Lru, false));
        let s8 = ratio(
            &trace,
            capacity,
            &Config::Sampled { sample: 8, policy: Policy::Lru, tlfu: false },
        );
        assert!(
            (k8 - s8).abs() < 0.05,
            "{trace_name}: 8-way {k8:.4} vs sampled8 {s8:.4}"
        );
    }
}

/// TinyLFU admission must not lose badly on scan-heavy traces (the multiN
/// models) — the reason the paper pairs LFU with TinyLFU in subfigure (b).
#[test]
fn tinylfu_admission_helps_on_scans() {
    let trace = paper::build("multi2", 400_000, 6).unwrap();
    let capacity = 2048;
    let plain = ratio(&trace, capacity, &kway(8, Policy::Lru, false));
    let tlfu = ratio(&trace, capacity, &kway(8, Policy::Lfu, true));
    assert!(
        tlfu > plain - 0.02,
        "LFU+TLFU ({tlfu:.4}) should not lose badly to LRU ({plain:.4}) on scans"
    );
}

/// Caffeine-like (W-TinyLFU) is at least as good as Guava-like (plain
/// LRU) on frequency-biased traces — the paper's subfigure (c) finding.
#[test]
fn caffeine_beats_guava_on_frequency_biased_trace() {
    let trace = paper::build("wiki_a", 400_000, 8).unwrap();
    let capacity = 2048;
    let caffeine = ratio(&trace, capacity, &Config::Caffeine);
    let guava = ratio(&trace, capacity, &Config::Guava { segments: 4 });
    assert!(
        caffeine >= guava - 0.01,
        "Caffeine {caffeine:.4} should be >= Guava {guava:.4}"
    );
}

/// Segmented Caffeine ≈ Caffeine on hit ratio (the paper: "nearly
/// identical").
#[test]
fn segmented_caffeine_close_to_caffeine() {
    let trace = paper::build("oltp", 300_000, 9).unwrap();
    let capacity = 2048;
    let caffeine = ratio(&trace, capacity, &Config::Caffeine);
    let seg = ratio(&trace, capacity, &Config::SegCaffeine { segments: 8 });
    assert!(
        (caffeine - seg).abs() < 0.06,
        "Caffeine {caffeine:.4} vs segmented {seg:.4}"
    );
}

/// Hyperbolic: limited associativity ≈ sampling, per Figures 6/8/12.
#[test]
fn hyperbolic_kway_close_to_sampled() {
    let trace = paper::build("p12", 400_000, 10).unwrap();
    let capacity = 8192;
    let k8 = ratio(&trace, capacity, &kway(8, Policy::Hyperbolic, false));
    let s64 = ratio(&trace, capacity, &Config::FullHyperbolic { sample: 64, tlfu: false });
    assert!((k8 - s64).abs() < 0.06, "8-way hyp {k8:.4} vs sampled-64 hyp {s64:.4}");
}

/// Sanity: sprite is the high-hit-ratio trace (>80% at small capacity),
/// w3 the low one (<10%) — the workload spread the paper leans on.
#[test]
fn trace_models_span_hit_ratio_range() {
    let sprite = paper::build("sprite", 200_000, 11).unwrap();
    let w3 = paper::build("w3", 200_000, 11).unwrap();
    let hi = ratio(&sprite, 2048, &kway(8, Policy::Lru, false));
    let lo = ratio(&w3, 2048, &kway(8, Policy::Lru, false));
    assert!(hi > 0.8, "sprite model should be hit-heavy, got {hi:.4}");
    assert!(lo < 0.1, "w3 model should be miss-heavy, got {lo:.4}");
}

/// Determinism: the whole sim pipeline is reproducible from the seed.
#[test]
fn simulation_is_deterministic() {
    let trace = paper::build("f1", 100_000, 12).unwrap();
    let a = ratio(&trace, 2048, &kway(8, Policy::Lru, false));
    let b = ratio(&trace, 2048, &kway(8, Policy::Lru, false));
    assert_eq!(a, b);
}
