//! The entry-lifetime contract across every lifetime-supporting
//! implementation (DESIGN.md §Expiration, §Weighted capacity):
//!
//! 1. **An expired key is never returned** — by single gets or batched
//!    gets — whether the entry was born expired (TTL 0) or outlived a
//!    real deadline.
//! 2. **Weight accounting never exceeds a set's capacity share** once
//!    churn quiesces, including under concurrent weighted puts (exact at
//!    all times for KW-LS, which mutates under the set lock; the
//!    wait-free variants repair on insert behind a publish fence).
//! 3. **TTL = ∞ is bit-identical to the pre-lifetime behaviour**: a
//!    cache driven through `put_with` with default options returns, step
//!    for step, exactly what a twin driven through plain `put` returns —
//!    and never reads the wall clock (the activity flags stay cold).
//!
//! Implementations without lifetime support (the `products/`
//! re-implementations) honestly report it and treat every entry as
//! immortal; the lineup test pins who claims what.

use kway::fully::Sampled;
use kway::kway::{KwLs, KwWfa, KwWfsc};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
use kway::tinylfu::TlfuCache;
use kway::util::rng::Rng;
use kway::{Cache, EntryOpts};
use std::time::Duration;

/// Every implementation that claims lifetime support, at a capacity
/// large enough that the test keys never face capacity eviction.
fn lifetime_lineup() -> Vec<Box<dyn Cache>> {
    let capacity = 4096;
    vec![
        Box::new(KwWfa::new(capacity, 8, Policy::Lru)),
        Box::new(KwWfsc::new(capacity, 8, Policy::Lru)),
        Box::new(KwLs::new(capacity, 8, Policy::Lru)),
        Box::new(Sampled::with_defaults(capacity, 8, Policy::Lru)),
        Box::new(TlfuCache::new(KwWfsc::new(capacity, 8, Policy::Lru), capacity)),
    ]
}

#[test]
fn lineup_claims_match_reality() {
    for cache in lifetime_lineup() {
        assert!(cache.supports_lifetime(), "{} must support lifetime", cache.name());
    }
    // The product re-implementations honestly report no support (their
    // put_with stores immortal unit-weight entries — the trait default).
    let products: Vec<Box<dyn Cache>> = vec![
        Box::new(GuavaLike::new(1024, 4)),
        Box::new(CaffeineLike::new(1024)),
        Box::new(SegmentedCaffeine::new(1024, 4)),
    ];
    for cache in products {
        assert!(!cache.supports_lifetime(), "{} claims unimplemented support", cache.name());
        // And the default really is "immortal": a zero-TTL put stays.
        cache.put_with(1, 11, EntryOpts::ttl(Duration::ZERO));
        assert_eq!(cache.get(1), Some(11), "{}: default put_with is a plain put", cache.name());
    }
}

#[test]
fn expired_keys_are_never_returned_single_get() {
    for cache in lifetime_lineup() {
        let name = cache.name();
        // Born expired (TTL 0): never readable, no sleeping needed.
        cache.put_with(1, 10, EntryOpts::ttl(Duration::ZERO));
        assert_eq!(cache.get(1), None, "{name}: zero-TTL key returned");
        // Real deadline: readable now, gone after it passes. The window
        // is generous (100 ms) so scheduler hiccups between the put and
        // the first get cannot flake the "live" assertion.
        cache.put_with(2, 20, EntryOpts::ttl(Duration::from_millis(100)));
        assert_eq!(cache.get(2), Some(20), "{name}: live key must hit");
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(cache.get(2), None, "{name}: out-lived key returned");
        // Immortal neighbours are untouched.
        cache.put(3, 30);
        assert_eq!(cache.get(3), Some(30), "{name}");
        // An overwrite revives an expired key (fresh lifetime).
        cache.put(1, 11);
        assert_eq!(cache.get(1), Some(11), "{name}: overwrite must revive");
    }
}

#[test]
fn expired_keys_are_never_returned_batched_get() {
    for cache in lifetime_lineup() {
        let name = cache.name();
        // Interleave born-expired and immortal keys, then read the whole
        // range through the batched path: expired positions must be None
        // in input order.
        for key in 0..200u64 {
            if key % 3 == 0 {
                cache.put_with(key, key + 1000, EntryOpts::ttl(Duration::ZERO));
            } else {
                cache.put(key, key + 1000);
            }
        }
        let keys: Vec<u64> = (0..200u64).collect();
        let mut out = Vec::new();
        cache.get_batch(&keys, &mut out);
        assert_eq!(out.len(), keys.len(), "{name}");
        for (i, &key) in keys.iter().enumerate() {
            let expect = if key % 3 == 0 { None } else { Some(key + 1000) };
            assert_eq!(out[i], expect, "{name}: position {i} key {key}");
        }
    }
}

#[test]
fn sweep_expired_reclaims_dead_lines_everywhere() {
    for cache in lifetime_lineup() {
        let name = cache.name();
        for key in 0..100u64 {
            if key < 50 {
                cache.put_with(key, key, EntryOpts::ttl(Duration::ZERO));
            } else {
                cache.put(key, key);
            }
        }
        let reclaimed = cache.sweep_expired(usize::MAX);
        assert_eq!(reclaimed, 50, "{name}: full sweep reclaims every dead line");
        assert_eq!(cache.len(), 50, "{name}");
        assert_eq!(cache.sweep_expired(usize::MAX), 0, "{name}: second sweep finds nothing");
    }
}

/// A scripted interleaving of puts and gets driven by a seeded RNG.
/// Returns the trace of every get's answer plus the final (len, weight).
fn drive(cache: &dyn Cache, plain_put: bool, seed: u64) -> (Vec<Option<u64>>, usize, u64) {
    let mut rng = Rng::new(seed);
    let mut answers = Vec::new();
    let mut batch_out = Vec::new();
    for _ in 0..4000 {
        let key = rng.below(1024);
        if rng.chance(0.5) {
            let value = key.wrapping_mul(31);
            if plain_put {
                cache.put(key, value);
            } else {
                cache.put_with(key, value, EntryOpts::default());
            }
        } else if rng.chance(0.2) {
            let keys: Vec<u64> = (0..8).map(|_| rng.below(1024)).collect();
            batch_out.clear();
            cache.get_batch(&keys, &mut batch_out);
            answers.extend(batch_out.iter().copied());
        } else {
            answers.push(cache.get(key));
        }
    }
    (answers, cache.len(), cache.weight())
}

#[test]
fn ttl_infinity_is_bit_identical_to_plain_puts() {
    // Two twins of every k-way variant, one driven through `put`, one
    // through `put_with(.., EntryOpts::default())`, over the same
    // scripted op sequence (capacity 256 so evictions DO happen and the
    // victim choices are exercised too): every single answer must match.
    type Mk = fn() -> Box<dyn Cache>;
    let makers: [(&str, Mk); 4] = [
        ("KW-WFA", || Box::new(KwWfa::new(256, 8, Policy::Lru))),
        ("KW-WFSC", || Box::new(KwWfsc::new(256, 8, Policy::Lru))),
        ("KW-LS", || Box::new(KwLs::new(256, 8, Policy::Lru))),
        ("sampled", || Box::new(Sampled::new(256, 8, Policy::Lru, 1))),
    ];
    for (name, mk) in makers {
        let via_put = mk();
        let via_put_with = mk();
        let (a, len_a, weight_a) = drive(&*via_put, true, 99);
        let (b, len_b, weight_b) = drive(&*via_put_with, false, 99);
        assert_eq!(a, b, "{name}: answer traces diverged");
        assert_eq!(len_a, len_b, "{name}: resident sets diverged");
        assert_eq!(weight_a, weight_b, "{name}: weights diverged");
        assert_eq!(weight_a, len_a as u64, "{name}: default weights must be 1");
        // No TTL ever flowed in, so sweeping reclaims nothing.
        assert_eq!(via_put_with.sweep_expired(usize::MAX), 0, "{name}");
    }
}

/// Concurrent weighted churn: random weights 1..=4 hammered from four
/// threads, then the per-set weight bound is checked after quiescence.
fn weighted_churn<C: Cache>(cache: &C, seed: u64) {
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ t);
                for _ in 0..20_000 {
                    let key = rng.below(2048);
                    if rng.chance(0.25) {
                        let _ = cache.get(key);
                    } else {
                        let weight = 1 + rng.below(4) as u32;
                        cache.put_with(key, key, EntryOpts::default().weighted(weight));
                    }
                }
            });
        }
    });
}

#[test]
fn weight_never_exceeds_per_set_budget_under_concurrent_churn_wfa() {
    let cache = KwWfa::new(1024, 8, Policy::Lru);
    weighted_churn(&cache, 11);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-WFA: quiesced set weight {max} exceeds the budget of 8");
    assert!(cache.weight() <= cache.capacity() as u64);
}

#[test]
fn weight_never_exceeds_per_set_budget_under_concurrent_churn_wfsc() {
    let cache = KwWfsc::new(1024, 8, Policy::Lru);
    weighted_churn(&cache, 22);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-WFSC: quiesced set weight {max} exceeds the budget of 8");
    assert!(cache.weight() <= cache.capacity() as u64);
}

#[test]
fn weight_never_exceeds_per_set_budget_under_concurrent_churn_ls() {
    let cache = KwLs::new(1024, 8, Policy::Lru);
    weighted_churn(&cache, 33);
    let max = cache.max_set_weight();
    assert!(max <= 8, "KW-LS: set weight {max} exceeds the budget of 8 (exact under lock)");
    assert!(cache.weight() <= cache.capacity() as u64);
}

#[test]
fn weight_never_exceeds_capacity_under_concurrent_churn_sampled() {
    // 16 segments of 64 weight units each; the segment lock makes the
    // per-segment bound exact, so the total is bounded at all times.
    let cache = Sampled::new(1024, 8, Policy::Lru, 16);
    weighted_churn(&cache, 44);
    let w = cache.weight();
    assert!(w <= 1024, "sampled: weight {w} exceeds capacity 1024");
}

#[test]
fn expiring_churn_with_sweeper_thread() {
    // TTL'd weighted churn racing the incremental sweep hook: no panics,
    // no phantom values, and after everything expires a full sweep
    // leaves the cache empty.
    let cache = KwWfsc::new(1024, 8, Policy::Lru);
    std::thread::scope(|scope| {
        for t in 0..3u64 {
            let cache = &cache;
            scope.spawn(move || {
                let mut rng = Rng::new(7 ^ t);
                for _ in 0..10_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.4) {
                        if let Some(v) = cache.get(key) {
                            assert_eq!(v, key, "phantom value for key {key}");
                        }
                    } else {
                        let opts = EntryOpts::ttl(Duration::from_millis(rng.below(3)))
                            .weighted(1 + rng.below(3) as u32);
                        cache.put_with(key, key, opts);
                    }
                }
            });
        }
        let cache = &cache;
        scope.spawn(move || {
            for _ in 0..200 {
                cache.sweep_expired(16);
                std::thread::yield_now();
            }
        });
    });
    std::thread::sleep(Duration::from_millis(10)); // outlive every TTL (max 2 ms)
    cache.sweep_expired(usize::MAX);
    assert_eq!(cache.len(), 0, "everything carried a short TTL; all must be reclaimed");
}
