//! Coordinator integration: the cache service end-to-end over each
//! concurrent cache implementation.

use kway::coordinator::{drive_clients, CacheService, ServiceConfig};
use kway::kway::{build, Variant};
use kway::policy::Policy;
use kway::products::SegmentedCaffeine;
use kway::Cache;
use std::sync::Arc;

#[test]
fn service_works_over_every_kway_variant() {
    for variant in Variant::ALL {
        let cache: Arc<dyn Cache> = Arc::from(build(variant, 4096, 8, Policy::Lru));
        let service = CacheService::start(cache, ServiceConfig { workers: 2 });
        let secs = drive_clients(&service, 3, 3_000, 8192, 5);
        assert!(secs > 0.0);
        let m = service.metrics();
        assert!(m.ops.hit_ratio() > 0.05, "{variant:?}: no hits at all?");
        assert!(m.get_latency.percentile(99.0) > 0);
        service.shutdown();
    }
}

#[test]
fn service_works_over_products() {
    let cache: Arc<dyn Cache> = Arc::new(SegmentedCaffeine::new(4096, 2));
    let service = CacheService::start(cache, ServiceConfig { workers: 2 });
    drive_clients(&service, 2, 2_000, 8192, 6);
    assert!(service.metrics().ops.gets.load(std::sync::atomic::Ordering::Relaxed) >= 4_000);
    service.shutdown();
}

#[test]
fn per_key_ordering_through_router() {
    // Same-key requests route to the same worker, so a put followed by a
    // get of the same key must observe the put.
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 1024, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 4 });
    for key in 0..500u64 {
        service.put(key, key * 3);
        assert_eq!(service.get(key), Some(key * 3), "key {key}");
    }
    service.shutdown();
}

#[test]
fn batch_get_equals_singles() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfa, 1024, 8, Policy::Lfu));
    let service = CacheService::start(cache, ServiceConfig { workers: 3 });
    for key in 0..64u64 {
        service.put(key, key + 1);
    }
    // Per-key ordering: read back each key once to ensure puts landed.
    for key in 0..64u64 {
        assert_eq!(service.get(key), Some(key + 1));
    }
    let batch = service.get_batch((0..64u64).collect());
    for (key, v) in (0..64u64).zip(batch) {
        assert_eq!(v, Some(key + 1), "batch get mismatch at {key}");
    }
    service.shutdown();
}

#[test]
fn metrics_report_format() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 512, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 1 });
    service.put(1, 1);
    service.get(1);
    service.get(2);
    let report = service.metrics().report();
    assert!(report.contains("gets=2"), "{report}");
    assert!(report.contains("puts=1"), "{report}");
    assert!(report.contains("get latency"), "{report}");
    service.shutdown();
}
