//! Coordinator integration: the cache service end-to-end over each
//! concurrent cache implementation.

use kway::coordinator::{drive_clients, CacheService, DegradedPolicy, ServiceConfig, ServiceError};
use kway::kway::{build, Variant};
use kway::policy::Policy;
use kway::products::SegmentedCaffeine;
use kway::Cache;
use std::sync::Arc;

#[test]
fn service_works_over_every_kway_variant() {
    for variant in Variant::ALL {
        let cache: Arc<dyn Cache> = Arc::from(build(variant, 4096, 8, Policy::Lru));
        let service =
            CacheService::start(cache, ServiceConfig { workers: 2, ..Default::default() });
        let secs = drive_clients(&service, 3, 3_000, 8192, 5);
        assert!(secs > 0.0);
        let m = service.metrics();
        assert!(m.ops.hit_ratio() > 0.05, "{variant:?}: no hits at all?");
        assert!(m.get_latency.percentile(99.0) > 0);
        service.shutdown();
    }
}

#[test]
fn service_works_over_products() {
    let cache: Arc<dyn Cache> = Arc::new(SegmentedCaffeine::new(4096, 2));
    let service = CacheService::start(cache, ServiceConfig { workers: 2, ..Default::default() });
    drive_clients(&service, 2, 2_000, 8192, 6);
    assert!(service.metrics().ops.gets.load(std::sync::atomic::Ordering::Relaxed) >= 4_000);
    service.shutdown();
}

#[test]
fn per_key_ordering_through_router() {
    // Same-key requests route to the same worker, so a put followed by a
    // get of the same key must observe the put.
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 1024, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 4, ..Default::default() });
    for key in 0..500u64 {
        service.put(key, key * 3);
        assert_eq!(service.get(key), Some(key * 3), "key {key}");
    }
    service.shutdown();
}

#[test]
fn batch_get_equals_singles() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfa, 1024, 8, Policy::Lfu));
    let service = CacheService::start(cache, ServiceConfig { workers: 3, ..Default::default() });
    for key in 0..64u64 {
        service.put(key, key + 1);
    }
    // Per-key ordering: read back each key once to ensure puts landed.
    for key in 0..64u64 {
        assert_eq!(service.get(key), Some(key + 1));
    }
    let batch = service.get_batch((0..64u64).collect());
    for (key, v) in (0..64u64).zip(batch) {
        assert_eq!(v, Some(key + 1), "batch get mismatch at {key}");
    }
    service.shutdown();
}

#[test]
fn batch_scatter_gather_in_input_order_under_concurrency() {
    // A scattered batch must come back stitched in input order even while
    // writer clients continuously push traffic through every worker. The
    // writers re-put resident keys with their existing values, so the
    // working set churns the workers without ever changing an answer.
    // 2048 resident keys over 8192 sets (capacity 64k): no set comes near
    // its 8 ways, so residency is stable for the whole test.
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 65_536, 8, Policy::Lru));
    let service = Arc::new(CacheService::start(
        cache,
        ServiceConfig { workers: 4, ..Default::default() },
    ));
    const RESIDENT: u64 = 2048;
    let value_of = |k: u64| k * 7 + 1;
    for key in 0..RESIDENT {
        service.put(key, value_of(key));
    }
    // Per-key FIFO through the router: one get per key flushes its worker.
    for key in 0..RESIDENT {
        assert_eq!(service.get(key), Some(value_of(key)));
    }

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut writers = Vec::new();
    for t in 0..2u64 {
        let service = service.clone();
        let stop = stop.clone();
        writers.push(std::thread::spawn(move || {
            let mut key = t * 31;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                key = (key + 1) % RESIDENT;
                service.put(key, value_of(key));
            }
        }));
    }

    let mut rng = kway::util::rng::Rng::new(3);
    for round in 0..200 {
        // 97 keys: not a multiple of the worker count, shuffled across all
        // four workers' shards.
        let keys: Vec<u64> = (0..97).map(|_| rng.below(RESIDENT)).collect();
        let out = service.get_batch(keys.clone());
        assert_eq!(out.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(out[i], Some(value_of(key)), "round {round} position {i} key {key}");
        }
    }

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for w in writers {
        w.join().unwrap();
    }
    // Dropping the last Arc shuts the service down (Drop joins workers).
}

#[test]
fn batched_drive_clients_hits_like_scalar() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Ls, 4096, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 2, ..Default::default() });
    let secs = kway::coordinator::drive_clients_batched(&service, 3, 2_000, 16, 8192, 9);
    assert!(secs > 0.0);
    let m = service.metrics();
    assert!(
        m.ops.gets.load(std::sync::atomic::Ordering::Relaxed) >= 6_000,
        "batched gets are counted per key"
    );
    assert!(m.ops.hit_ratio() > 0.05, "zipf batched workload should hit");
    service.shutdown();
}

#[test]
fn ops_after_halt_degrade_instead_of_panicking() {
    // The shutdown-then-op regression: a service whose workers are gone
    // must answer every op shape as a degraded miss/no-op — never panic,
    // never block.
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 1024, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 2, ..Default::default() });
    service.put(1, 10);
    assert_eq!(service.get(1), Some(10));
    service.halt();
    assert_eq!(service.get(1), None);
    service.put(2, 20);
    assert_eq!(service.get_batch(vec![1, 2, 3]), vec![None, None, None]);
    service.put_batch(vec![(4, 40), (5, 50)]);
    assert!(matches!(service.try_get(1), Err(ServiceError::Stopped)));
    let degraded = service.metrics().degraded_ops.load(std::sync::atomic::Ordering::Relaxed);
    assert!(degraded >= 4, "expected every infallible op counted, got {degraded}");
    // halt is idempotent, and shutdown after halt is a clean no-op join.
    service.halt();
    service.shutdown();
}

#[test]
fn error_policy_is_visible_on_the_fallible_paths() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 1024, 8, Policy::Lru));
    let service = CacheService::start(
        cache,
        ServiceConfig { workers: 2, degraded: DegradedPolicy::Error, ..Default::default() },
    );
    assert_eq!(service.degraded_policy(), DegradedPolicy::Error);
    service.halt();
    assert!(matches!(service.try_get(7), Err(ServiceError::Stopped)));
    assert!(matches!(service.try_get_batch(vec![1, 2]), Err(ServiceError::Stopped)));
    // The infallible entry points still answer misses regardless of the
    // policy — Error only changes what the *wire layer* tells clients.
    assert_eq!(service.get(7), None);
    service.shutdown();
}

#[cfg(feature = "fault-inject")]
#[test]
fn panicked_workers_are_restarted_and_service_recovers() {
    use kway::fault::FaultPlan;
    use std::time::{Duration, Instant};
    let plan = Arc::new(FaultPlan::parse("worker_panic@1ms").unwrap());
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 1024, 8, Policy::Lru));
    let service = CacheService::start(
        cache,
        ServiceConfig { workers: 2, faults: Some(plan.clone()), ..Default::default() },
    );
    for key in 0..100u64 {
        service.put(key, key);
    }
    plan.arm();
    let deadline = Instant::now() + Duration::from_secs(5);
    while service.metrics().worker_restarts.load(std::sync::atomic::Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "no worker restart within 5s");
        for key in 0..50u64 {
            service.put(key, key);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    plan.disarm();
    // The restarted worker serves its shard again: a fresh put lands and
    // reads back, end to end.
    service.put(5, 123);
    assert_eq!(service.get(5), Some(123));
    service.shutdown();
}

#[test]
fn metrics_report_format() {
    let cache: Arc<dyn Cache> = Arc::from(build(Variant::Wfsc, 512, 8, Policy::Lru));
    let service = CacheService::start(cache, ServiceConfig { workers: 1, ..Default::default() });
    service.put(1, 1);
    service.get(1);
    service.get(2);
    let report = service.metrics().report();
    assert!(report.contains("gets=2"), "{report}");
    assert!(report.contains("puts=1"), "{report}");
    assert!(report.contains("get latency"), "{report}");
    service.shutdown();
}
