//! End-to-end tests of the TCP wire front end: a real [`Server`] on a
//! loopback ephemeral port over a real [`CacheService`], driven with
//! plain blocking sockets. Both protocols, the TTL path, pipelined
//! multi-key reads (the batch-fusion path), protocol-error handling,
//! binary payload safety over a slab-backed byte cache (CRLF/NUL/1MiB
//! blobs, length-framed, never CRLF-scanned) and the in-process
//! loadgen smoke all run here; byte-level codec corner
//! cases (split reads, frames straddling buffers, malformed commands)
//! live in the `net::memcached` / `net::resp` unit tests.
//!
//! Every loopback test runs once per event-loop backend — epoll
//! readiness mode and io_uring completion mode — through
//! [`each_backend`](loopback::each_backend), so the two paths are held
//! to byte-identical wire behaviour. On kernels without io_uring the
//! uring pass is skipped with an explicit notice, never silently.
//!
//! The event-loop backends are Linux/x86_64 only, so the
//! server-spawning tests are gated on that target; elsewhere this file
//! checks that starting the server reports a clean `Unsupported` error
//! instead.
//!
//! [`Server`]: kway::net::Server
//! [`CacheService`]: kway::coordinator::CacheService

use kway::coordinator::{CacheService, ServiceConfig};
use kway::kway::KwWfsc;
use kway::policy::Policy;
use kway::tinylfu::AdmissionMode;
use std::sync::Arc;
use std::time::Duration;

fn start_service(default_ttl: Option<Duration>) -> Arc<CacheService> {
    let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
    Arc::new(CacheService::start(
        cache,
        ServiceConfig {
            workers: 2,
            admission: AdmissionMode::None,
            default_ttl,
            ..Default::default()
        },
    ))
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod unsupported {
    use super::*;
    use kway::net::{Server, ServerConfig};
    use std::net::TcpListener;

    #[test]
    fn server_start_reports_unsupported() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = Server::start(listener, start_service(None), ServerConfig::default())
            .expect_err("no epoll backend on this target");
        assert_eq!(err.kind(), std::io::ErrorKind::Unsupported);
    }
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod loopback {
    use super::*;
    use kway::net::loadgen::{self, LoadgenConfig, WireProto};
    use kway::net::{BackendChoice, Server, ServerConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{TcpListener, TcpStream};

    /// Run `test` against a fresh serving stack once per event-loop
    /// backend. The epoll pass always runs; the io_uring pass is
    /// skipped with a notice when the kernel lacks io_uring — an
    /// explicit skip, never a silent green.
    pub fn each_backend(make_service: impl Fn() -> Arc<CacheService>, test: impl Fn(&Server)) {
        for backend in [BackendChoice::Epoll, BackendChoice::Uring] {
            if backend == BackendChoice::Uring && !kway::net::uring::supported() {
                eprintln!("skipping uring backend pass: io_uring is unavailable on this kernel");
                continue;
            }
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let server = Server::start(
                listener,
                make_service(),
                ServerConfig { io_threads: 2, backend, ..Default::default() },
            )
            .unwrap();
            test(&server);
            server.stop();
        }
    }

    fn connect(server: &Server) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    fn expect_lines(reader: &mut BufReader<TcpStream>, expected: &[&str]) {
        for want in expected {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert_eq!(line.trim_end_matches(['\r', '\n']), *want);
        }
    }

    /// Encode one RESP array-of-bulk-strings command.
    fn resp(parts: &[&str]) -> Vec<u8> {
        let mut out = format!("*{}\r\n", parts.len()).into_bytes();
        for p in parts {
            out.extend_from_slice(format!("${}\r\n{p}\r\n", p.len()).as_bytes());
        }
        out
    }

    /// Encode one RESP command whose arguments are raw bytes — bulk
    /// strings are length-prefixed, so payloads may contain anything.
    fn resp_bin(parts: &[&[u8]]) -> Vec<u8> {
        let mut out = format!("*{}\r\n", parts.len()).into_bytes();
        for p in parts {
            out.extend_from_slice(format!("${}\r\n", p.len()).as_bytes());
            out.extend_from_slice(p);
            out.extend_from_slice(b"\r\n");
        }
        out
    }

    /// A service over a byte-value (slab-backed) cache. The weight
    /// budget is per-way `(value_bytes / capacity) / GRANULE` granules,
    /// so a small capacity with a wide budget keeps a full
    /// `MAX_VALUE_LEN` entry admissible in a single set.
    fn start_byte_service() -> Arc<CacheService> {
        use kway::kway::{build_with_values, Variant};
        let cache: Arc<dyn kway::Cache> =
            Arc::from(build_with_values(Variant::Wfsc, 256, 8, Policy::Lru, 1 << 26));
        Arc::new(CacheService::start(
            cache,
            ServiceConfig {
                workers: 2,
                admission: AdmissionMode::None,
                default_ttl: None,
                ..Default::default()
            },
        ))
    }

    /// Deterministic byte blob: an LCG stream, so every byte value
    /// (CR, LF, NUL, ...) shows up and the content is reproducible.
    fn blob(seed: u64, len: usize) -> Vec<u8> {
        let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
        let mut out = Vec::with_capacity(len + 8);
        while out.len() < len {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.truncate(len);
        out
    }

    /// Read one memcached `VALUE <key> <flags> <len>` response,
    /// length-driven: the data block is consumed by byte count, never
    /// scanned for CRLF, then the trailing `END` is checked.
    fn read_mc_value(reader: &mut BufReader<TcpStream>, key: &str) -> Vec<u8> {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim_end_matches(['\r', '\n']);
        let mut parts = header.split(' ');
        assert_eq!(parts.next(), Some("VALUE"), "bad header {header:?}");
        assert_eq!(parts.next(), Some(key), "bad header {header:?}");
        let _flags = parts.next().expect("flags field");
        let len: usize = parts.next().expect("length field").parse().unwrap();
        let mut data = vec![0u8; len + 2];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data[len..], b"\r\n", "data block must end in CRLF");
        data.truncate(len);
        expect_lines(reader, &["END"]);
        data
    }

    /// Read one `gets` response, checking the payload round-trips and
    /// returning the cas token from the `VALUE <key> <flags> <len>
    /// <token>` header.
    fn read_gets_token(reader: &mut BufReader<TcpStream>, key: &str, want: &[u8]) -> u64 {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim_end_matches(['\r', '\n']);
        let mut parts = header.split(' ');
        assert_eq!(parts.next(), Some("VALUE"), "bad header {header:?}");
        assert_eq!(parts.next(), Some(key), "bad header {header:?}");
        let _flags = parts.next().expect("flags field");
        let len: usize = parts.next().expect("length field").parse().unwrap();
        let token: u64 = parts.next().expect("cas token field").parse().unwrap();
        let mut data = vec![0u8; len + 2];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data[..len], want, "gets payload must round-trip");
        expect_lines(reader, &["END"]);
        token
    }

    /// Read one RESP bulk-string reply, length-driven via the `$len`
    /// prefix.
    fn read_resp_bulk(reader: &mut BufReader<TcpStream>) -> Vec<u8> {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim_end_matches(['\r', '\n']);
        let len: usize = header
            .strip_prefix('$')
            .unwrap_or_else(|| panic!("expected bulk string, got {header:?}"))
            .parse()
            .unwrap();
        let mut data = vec![0u8; len + 2];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data[len..], b"\r\n");
        data.truncate(len);
        data
    }

    #[test]
    fn memcached_full_command_set() {
        each_backend(|| start_service(None), memcached_full_command_set_on);
    }

    fn memcached_full_command_set_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        s.write_all(b"set 7 0 0 2\r\n42\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get 7\r\n").unwrap();
        expect_lines(&mut r, &["VALUE 7 0 2", "42", "END"]);
        // gets: on a word cache the cas token is the stored word itself
        // (documented deviation).
        s.write_all(b"gets 7\r\n").unwrap();
        expect_lines(&mut r, &["VALUE 7 0 2 42", "42", "END"]);
        // cas: the live token stores, a stale one reports EXISTS.
        s.write_all(b"cas 7 0 0 2 42\r\n43\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"cas 7 0 0 2 42\r\n44\r\n").unwrap();
        expect_lines(&mut r, &["EXISTS"]);
        s.write_all(b"get 7\r\n").unwrap();
        expect_lines(&mut r, &["VALUE 7 0 2", "43", "END"]);
        // add: refused on a present key, stored on an absent one.
        s.write_all(b"add 7 0 0 1\r\n9\r\n").unwrap();
        expect_lines(&mut r, &["NOT_STORED"]);
        s.write_all(b"add 8 0 0 1\r\n9\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"touch 7 100\r\n").unwrap();
        expect_lines(&mut r, &["TOUCHED"]);
        s.write_all(b"delete 7\r\n").unwrap();
        expect_lines(&mut r, &["DELETED"]);
        s.write_all(b"get 7\r\n").unwrap();
        expect_lines(&mut r, &["END"]);
        s.write_all(b"delete 7\r\n").unwrap();
        expect_lines(&mut r, &["NOT_FOUND"]);
        // Non-numeric keys hash into the high key space and still work.
        s.write_all(b"set user:alice 0 0 4\r\n1234\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get user:alice\r\n").unwrap();
        expect_lines(&mut r, &["VALUE user:alice 0 4", "1234", "END"]);
    }

    #[test]
    fn memcached_pipelined_multiget_is_order_preserving() {
        each_backend(|| start_service(None), pipelined_multiget_on);
    }

    fn pipelined_multiget_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        for k in 1..=6u64 {
            s.write_all(format!("set {k} 0 0 2\r\n1{k}\r\n").as_bytes()).unwrap();
            expect_lines(&mut r, &["STORED"]);
        }
        // One write carrying a whole pipeline: a multi-key get, another
        // get, an immediate command, and a trailing set. Responses must
        // come back in request order even though the reads are fused
        // into one get_batch and the set is answered at accumulation.
        let mut burst = Vec::new();
        burst.extend_from_slice(b"get 1 2 3 4\r\n");
        burst.extend_from_slice(b"get 5 6 999\r\n");
        burst.extend_from_slice(b"version\r\n");
        burst.extend_from_slice(b"set 9 0 0 2\r\n19\r\n");
        s.write_all(&burst).unwrap();
        expect_lines(
            &mut r,
            &[
                "VALUE 1 0 2",
                "11",
                "VALUE 2 0 2",
                "12",
                "VALUE 3 0 2",
                "13",
                "VALUE 4 0 2",
                "14",
                "END",
                "VALUE 5 0 2",
                "15",
                "VALUE 6 0 2",
                "16",
                "END",
            ],
        );
        let mut version = String::new();
        r.read_line(&mut version).unwrap();
        assert!(version.starts_with("VERSION"), "got {version:?}");
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get 9\r\n").unwrap();
        expect_lines(&mut r, &["VALUE 9 0 2", "19", "END"]);
    }

    #[test]
    fn memcached_service_ttl_expires_over_the_wire() {
        each_backend(|| start_service(Some(Duration::from_millis(50))), service_ttl_on);
    }

    fn service_ttl_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        s.write_all(b"set 3 0 0 1\r\n7\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get 3\r\n").unwrap();
        expect_lines(&mut r, &["VALUE 3 0 1", "7", "END"]);
        std::thread::sleep(Duration::from_millis(90));
        s.write_all(b"get 3\r\n").unwrap();
        expect_lines(&mut r, &["END"]);
    }

    #[test]
    fn resp_full_command_set() {
        each_backend(|| start_service(None), resp_full_command_set_on);
    }

    fn resp_full_command_set_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        s.write_all(&resp(&["PING"])).unwrap();
        expect_lines(&mut r, &["+PONG"]);
        s.write_all(&resp(&["SET", "5", "99"])).unwrap();
        expect_lines(&mut r, &["+OK"]);
        s.write_all(&resp(&["GET", "5"])).unwrap();
        expect_lines(&mut r, &["$2", "99"]);
        s.write_all(&resp(&["GET", "404"])).unwrap();
        expect_lines(&mut r, &["$-1"]);
        s.write_all(&resp(&["MSET", "6", "16", "7", "17"])).unwrap();
        expect_lines(&mut r, &["+OK"]);
        s.write_all(&resp(&["MGET", "5", "6", "404"])).unwrap();
        expect_lines(&mut r, &["*3", "$2", "99", "$2", "16", "$-1"]);
        s.write_all(&resp(&["DEL", "6"])).unwrap();
        expect_lines(&mut r, &[":1"]);
        s.write_all(&resp(&["GET", "6"])).unwrap();
        expect_lines(&mut r, &["$-1"]);
        s.write_all(&resp(&["EXPIRE", "7", "100"])).unwrap();
        expect_lines(&mut r, &[":1"]);
        s.write_all(&resp(&["EXPIRE", "404", "100"])).unwrap();
        expect_lines(&mut r, &[":0"]);
        // SET PX: the entry must expire.
        s.write_all(&resp(&["SET", "8", "1", "PX", "40"])).unwrap();
        expect_lines(&mut r, &["+OK"]);
        std::thread::sleep(Duration::from_millis(80));
        s.write_all(&resp(&["GET", "8"])).unwrap();
        expect_lines(&mut r, &["$-1"]);
    }

    #[test]
    fn both_protocols_share_one_port() {
        each_backend(|| start_service(None), shared_port_on);
    }

    fn shared_port_on(server: &Server) {
        let (mut mc, mut mc_r) = connect(server);
        let (mut rd, mut rd_r) = connect(server);

        mc.write_all(b"set 11 0 0 2\r\n66\r\n").unwrap();
        expect_lines(&mut mc_r, &["STORED"]);
        // The RESP client reads what the memcached client stored.
        rd.write_all(&resp(&["GET", "11"])).unwrap();
        expect_lines(&mut rd_r, &["$2", "66"]);
        rd.write_all(&resp(&["SET", "12", "77"])).unwrap();
        expect_lines(&mut rd_r, &["+OK"]);
        mc.write_all(b"get 12\r\n").unwrap();
        expect_lines(&mut mc_r, &["VALUE 12 0 2", "77", "END"]);
    }

    #[test]
    fn recoverable_errors_keep_the_connection() {
        each_backend(|| start_service(None), recoverable_errors_on);
    }

    fn recoverable_errors_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        // Unknown verb: ERROR, then the connection keeps serving.
        s.write_all(b"frobnicate 1 2 3\r\n").unwrap();
        expect_lines(&mut r, &["ERROR"]);
        // Oversized key: client error, still recoverable.
        let long_key = "k".repeat(300);
        s.write_all(format!("get {long_key}\r\n").as_bytes()).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("CLIENT_ERROR"), "got {line:?}");
        s.write_all(b"set 2 0 0 1\r\n5\r\nget 2\r\n").unwrap();
        expect_lines(&mut r, &["STORED", "VALUE 2 0 1", "5", "END"]);
    }

    #[test]
    fn fatal_protocol_error_answers_then_closes() {
        each_backend(|| start_service(None), fatal_error_on);
    }

    fn fatal_error_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        // An unparseable byte count cannot be re-framed: the decoder
        // cannot know where the data block ends, so the server answers
        // once and hangs up.
        s.write_all(b"set 1 0 0 notanumber\r\nleftover\r\n").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("CLIENT_ERROR"), "got {line:?}");
        let mut rest = Vec::new();
        r.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "connection must be closed after a fatal error");
    }

    #[test]
    fn memcached_binary_payloads_are_length_framed() {
        each_backend(start_byte_service, binary_payloads_on);
    }

    fn binary_payloads_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        // Payloads chosen to break any CRLF-scanning decoder: embedded
        // line endings, NULs, and memcached's own framing vocabulary.
        let hostile: [&[u8]; 4] = [
            b"\r\n",
            b"\0\0\0",
            b"END\r\nVALUE 9 0 2\r\nhi\r\n",
            b"a\0b\r\nc\rd\ne",
        ];
        for (i, payload) in hostile.iter().enumerate() {
            let key = format!("bin{i}");
            let mut cmd = format!("set {key} 0 0 {}\r\n", payload.len()).into_bytes();
            cmd.extend_from_slice(payload);
            cmd.extend_from_slice(b"\r\n");
            s.write_all(&cmd).unwrap();
            expect_lines(&mut r, &["STORED"]);
            s.write_all(format!("get {key}\r\n").as_bytes()).unwrap();
            assert_eq!(read_mc_value(&mut r, &key), *payload, "payload {i} must round-trip");
        }
        // The connection is still framed correctly after all of that.
        s.write_all(b"version\r\n").unwrap();
        let mut version = String::new();
        r.read_line(&mut version).unwrap();
        assert!(version.starts_with("VERSION"), "got {version:?}");
    }

    #[test]
    fn resp_binary_payloads_round_trip() {
        each_backend(start_byte_service, resp_binary_payloads_on);
    }

    fn resp_binary_payloads_on(server: &Server) {
        let (mut s, mut r) = connect(server);

        let hostile: [&[u8]; 3] = [b"\r\n\r\n", b"\0binary\0", b"*2\r\n$3\r\nGET\r\n"];
        for (i, payload) in hostile.iter().enumerate() {
            let key = format!("rbin{i}");
            s.write_all(&resp_bin(&[b"SET", key.as_bytes(), payload])).unwrap();
            expect_lines(&mut r, &["+OK"]);
            s.write_all(&resp_bin(&[b"GET", key.as_bytes()])).unwrap();
            assert_eq!(read_resp_bulk(&mut r), *payload, "payload {i} must round-trip");
        }
        // Zero-length values are legal and distinct from a miss.
        s.write_all(&resp_bin(&[b"SET", b"empty", b""])).unwrap();
        expect_lines(&mut r, &["+OK"]);
        s.write_all(&resp(&["GET", "empty"])).unwrap();
        expect_lines(&mut r, &["$0", ""]);
        s.write_all(&resp(&["GET", "nosuch"])).unwrap();
        expect_lines(&mut r, &["$-1"]);
    }

    #[test]
    fn megabyte_blob_round_trips_both_protocols() {
        each_backend(start_byte_service, megabyte_blob_on);
    }

    fn megabyte_blob_on(server: &Server) {
        let (mut mc, mut mc_r) = connect(server);
        let (mut rd, mut rd_r) = connect(server);

        let payload = blob(0xB10B, kway::net::MAX_VALUE_LEN);
        assert!(payload.windows(2).any(|w| w == b"\r\n"), "blob must contain CRLF");
        assert!(payload.contains(&0), "blob must contain NUL");

        // Stored over memcached, read back over memcached *and* RESP:
        // both protocols see the same slab bytes, length-framed.
        let mut cmd = format!("set 77 0 0 {}\r\n", payload.len()).into_bytes();
        cmd.extend_from_slice(&payload);
        cmd.extend_from_slice(b"\r\n");
        mc.write_all(&cmd).unwrap();
        expect_lines(&mut mc_r, &["STORED"]);
        mc.write_all(b"get 77\r\n").unwrap();
        assert_eq!(read_mc_value(&mut mc_r, "77"), payload);
        rd.write_all(&resp(&["GET", "77"])).unwrap();
        assert_eq!(read_resp_bulk(&mut rd_r), payload);

        // And the reverse direction: stored over RESP, read over both.
        let payload2 = blob(0xB10C, kway::net::MAX_VALUE_LEN);
        rd.write_all(&resp_bin(&[b"SET", b"78", &payload2])).unwrap();
        expect_lines(&mut rd_r, &["+OK"]);
        rd.write_all(&resp(&["GET", "78"])).unwrap();
        assert_eq!(read_resp_bulk(&mut rd_r), payload2);
        mc.write_all(b"get 78\r\n").unwrap();
        assert_eq!(read_mc_value(&mut mc_r, "78"), payload2);

        // One byte past the cap is refused before the block is ever
        // buffered; an oversize count can't be re-framed, so the server
        // answers once and hangs up.
        let mut over = format!("set 79 0 0 {}\r\n", kway::net::MAX_VALUE_LEN + 1).into_bytes();
        over.extend_from_slice(&payload[..16]);
        mc.write_all(&over).unwrap();
        let mut line = String::new();
        mc_r.read_line(&mut line).unwrap();
        assert!(line.starts_with("CLIENT_ERROR"), "got {line:?}");
        let mut rest = Vec::new();
        mc_r.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "oversize count is fatal: connection must close");
    }

    #[test]
    fn loadgen_smoke_both_protocols() {
        each_backend(|| start_service(None), |server| {
            let addr = server.local_addr().to_string();
            for proto in [WireProto::Memcached, WireProto::Resp] {
                let result = loadgen::run(&LoadgenConfig::smoke(&addr, proto)).unwrap();
                assert!(result.ops > 0, "{}: no requests completed", proto.name());
                assert_eq!(result.errors, 0, "{}: wire errors", proto.name());
                assert!(result.sets > 0 && result.gets > 0);
                assert!(result.p99_ns >= result.p50_ns);
            }
        });
    }

    /// cas on a byte cache: the token `gets` hands out is the entry's
    /// generation-stamped slab handle, so replacing the value rotates
    /// it and a stale token loses with EXISTS.
    #[test]
    fn memcached_cas_over_the_wire() {
        each_backend(start_byte_service, cas_over_the_wire_on);
    }

    fn cas_over_the_wire_on(server: &Server) {
        let (mut s, mut r) = connect(server);
        s.write_all(b"set k 0 0 5\r\nhello\r\n").unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"gets k\r\n").unwrap();
        let token = read_gets_token(&mut r, "k", b"hello");
        // The live token wins and the store is visible.
        s.write_all(format!("cas k 0 0 5 {token}\r\nworld\r\n").as_bytes()).unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get k\r\n").unwrap();
        assert_eq!(read_mc_value(&mut r, "k"), b"world");
        // The replaced entry carries a fresh token: the old one loses.
        s.write_all(format!("cas k 0 0 2 {token}\r\nxx\r\n").as_bytes()).unwrap();
        expect_lines(&mut r, &["EXISTS"]);
        s.write_all(format!("cas nosuch 0 0 2 {token}\r\nxx\r\n").as_bytes()).unwrap();
        expect_lines(&mut r, &["NOT_FOUND"]);
        s.write_all(b"gets k\r\n").unwrap();
        let token2 = read_gets_token(&mut r, "k", b"world");
        assert_ne!(token, token2, "replacing the value must rotate the cas token");
        s.write_all(format!("cas k 0 0 2 {token2}\r\nhi\r\n").as_bytes()).unwrap();
        expect_lines(&mut r, &["STORED"]);
        s.write_all(b"get k\r\n").unwrap();
        assert_eq!(read_mc_value(&mut r, "k"), b"hi");
    }

    /// `--backend auto` always resolves to a concrete backend and
    /// serves; which one depends on the running kernel.
    #[test]
    fn auto_backend_resolves_and_serves() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = Server::start(
            listener,
            start_service(None),
            ServerConfig { io_threads: 1, backend: BackendChoice::Auto, ..Default::default() },
        )
        .unwrap();
        assert!(matches!(server.backend(), BackendChoice::Epoll | BackendChoice::Uring));
        if kway::net::uring::supported() {
            assert_eq!(server.backend(), BackendChoice::Uring, "auto must prefer uring");
        }
        let (mut s, mut r) = connect(&server);
        s.write_all(b"set 1 0 0 1\r\n5\r\nget 1\r\n").unwrap();
        expect_lines(&mut r, &["STORED", "VALUE 1 0 1", "5", "END"]);
        server.stop();
    }

    /// An explicit `--backend uring` on a kernel without io_uring must
    /// fail loudly instead of silently falling back; only observable
    /// where the probe actually fails.
    #[test]
    fn explicit_uring_without_kernel_support_fails_fast() {
        if kway::net::uring::supported() {
            eprintln!("skipping: io_uring is available, the explicit-uring failure can't fire");
            return;
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = Server::start(
            listener,
            start_service(None),
            ServerConfig { backend: BackendChoice::Uring, ..Default::default() },
        )
        .expect_err("explicit uring must not silently fall back");
        assert_eq!(err.kind(), std::io::ErrorKind::Unsupported);
    }
}
