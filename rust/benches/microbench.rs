//! Microbenchmarks and ablations for the design choices DESIGN.md calls
//! out:
//!
//! * per-op latency (get-hit / get-miss+put) for every implementation;
//! * the O(K) scan cost vs associativity for WFA (array-of-structs) vs
//!   WFSC (structure-of-arrays) — the paper's §3 locality argument;
//! * the KW-LS upgrade path vs the wait-free paths;
//! * hash function cost (xxh64 vs mix64) and victim-select cost per
//!   policy — the "one hash vs K PRNG draws" comparison of §1.1;
//! * the **probe path** (DESIGN.md §Hot path): KW-WFSC resident-set gets
//!   under every available fingerprint-probe kernel
//!   (avx2/sse2/swar/scalar) × thread counts, core-pinned, reporting
//!   ns/op *and* cycles/op — the SIMD-speedup figure of the hot-path
//!   work. `--json` writes the rows to `BENCH_hotpath.json`
//!   (schema `kway-hotpath-v2`); `--hugepages` madvises the tables onto
//!   transparent huge pages first, and the artifact records which.
//!
//! ```bash
//! cargo bench --bench microbench              # full run
//! cargo bench --bench microbench -- --smoke   # seconds-scale CI smoke
//! cargo bench --bench microbench -- --json    # also write BENCH_hotpath.json
//! cargo bench --bench microbench -- --hugepages --json   # THP-backed tables
//! KWAY_BENCH_QUICK=1 cargo bench --bench microbench
//! ```

use kway::fully::Sampled;
use kway::kway::simd::{self, ProbeKind};
use kway::kway::{KwLs, KwWfa, KwWfsc};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike};
use kway::util::clock::{self, Stopwatch};
use kway::util::hash;
use kway::util::rng::Rng;
use kway::util::{affinity, cli::Args, json::Json};
use kway::Cache;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

fn ns_per_op(total_ops: u64, secs: f64) -> f64 {
    secs * 1e9 / total_ops as f64
}

fn bench_cache(c: &dyn Cache, label: &str, iters: u64) {
    let mut rng = Rng::new(7);
    // Resident working set: half capacity.
    let resident = (c.capacity() / 2) as u64;
    for k in 0..resident {
        c.put(k, k);
    }
    // get-hit
    let sw = Stopwatch::start();
    let mut sink = 0u64;
    for _ in 0..iters {
        let k = rng.below(resident);
        sink ^= c.get(k).unwrap_or(0);
    }
    let hit_ns = ns_per_op(iters, sw.elapsed_secs());
    // get-miss + put (the miss path)
    let mut next = 1u64 << 40;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        if c.get(next).is_none() {
            c.put(next, next);
        }
        next += 1;
    }
    let miss_ns = ns_per_op(iters, sw.elapsed_secs());
    println!("{label:14} get-hit {hit_ns:7.1} ns   miss+put {miss_ns:7.1} ns   (sink {sink})");
}

/// One measured (probe kernel, thread count) point of the probe-path
/// bench; serialized into `BENCH_hotpath.json`.
struct ProbeRow {
    probe: &'static str,
    threads: usize,
    mops: f64,
    ns_per_op: f64,
    cycles_per_op: f64,
}

/// The hot-path measurement: KW-WFSC resident-set gets (the workload
/// where the fingerprint probe *is* the work), repeated under every
/// available probe kernel so the avx2/sse2/swar rows read directly
/// against the scalar baseline. Workers are core-pinned; ns/op and
/// cycles/op are per-thread sums over total ops (scheduler-migration-
/// and frequency-honest respectively), Mops/s is over the wall clock.
fn bench_probe_path(iters_per_thread: u64, thread_counts: &[usize]) -> Vec<ProbeRow> {
    const CAPACITY: usize = 1 << 18;
    let working = (CAPACITY / 2) as u64;
    let mut rows = Vec::new();
    println!(
        "{:8} {:>8} {:>10} {:>10} {:>12}",
        "probe", "threads", "Mops/s", "ns/op", "cycles/op"
    );
    for kind in ProbeKind::available() {
        simd::force(Some(kind));
        for &threads in thread_counts {
            let cache = Arc::new(KwWfsc::new(CAPACITY, 8, Policy::Lru));
            for k in 0..working {
                cache.put(k, k);
            }
            let barrier = Barrier::new(threads);
            let busy_ns = AtomicU64::new(0);
            let cycles = AtomicU64::new(0);
            let wall = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        let cache = cache.clone();
                        let barrier = &barrier;
                        let busy_ns = &busy_ns;
                        let cycles = &cycles;
                        scope.spawn(move || {
                            affinity::pin_to_core(t);
                            let mut rng = Rng::new(17 ^ t as u64);
                            barrier.wait();
                            let sw = Stopwatch::start();
                            let tsc0 = clock::cycles_now();
                            let mut sink = 0u64;
                            for _ in 0..iters_per_thread {
                                sink ^= cache.get(rng.below(working)).unwrap_or(0);
                            }
                            let tsc1 = clock::cycles_now();
                            std::hint::black_box(sink);
                            busy_ns.fetch_add(sw.elapsed_nanos() as u64, Ordering::Relaxed);
                            cycles.fetch_add(tsc1.wrapping_sub(tsc0), Ordering::Relaxed);
                        })
                    })
                    .collect();
                let sw = Stopwatch::start();
                for h in handles {
                    h.join().unwrap();
                }
                sw.elapsed_secs()
            });
            let ops = iters_per_thread * threads as u64;
            let row = ProbeRow {
                probe: kind.name(),
                threads,
                mops: ops as f64 / wall / 1e6,
                ns_per_op: busy_ns.load(Ordering::Relaxed) as f64 / ops as f64,
                cycles_per_op: cycles.load(Ordering::Relaxed) as f64 / ops as f64,
            };
            println!(
                "{:8} {:>8} {:>10.2} {:>10.2} {:>12.1}",
                row.probe, row.threads, row.mops, row.ns_per_op, row.cycles_per_op
            );
            rows.push(row);
        }
    }
    simd::force(None);
    rows
}

/// Write the probe-path rows as `BENCH_hotpath.json` (schema
/// `kway-hotpath-v2`), refusing a document that fails its own check.
fn write_hotpath_json(rows: &[ProbeRow], duration_ms: i64) {
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::Object(vec![
                ("probe".to_string(), Json::Str(r.probe.to_string())),
                ("threads".to_string(), Json::Int(r.threads as i64)),
                ("mops".to_string(), Json::Float(r.mops)),
                ("ns_per_op".to_string(), Json::Float(r.ns_per_op)),
                ("cycles_per_op".to_string(), Json::Float(r.cycles_per_op)),
            ])
        })
        .collect();
    let doc = Json::Object(vec![
        ("schema".to_string(), Json::Str(kway::util::json::HOTPATH_SCHEMA.to_string())),
        ("impl".to_string(), Json::Str("KW-WFSC".to_string())),
        ("workload".to_string(), Json::Str("hit100".to_string())),
        ("capacity".to_string(), Json::Int(1 << 18)),
        ("ways".to_string(), Json::Int(8)),
        ("working_set".to_string(), Json::Int(1 << 17)),
        ("duration_ms".to_string(), Json::Int(duration_ms)),
        ("seed".to_string(), Json::Int(17)),
        ("pinned".to_string(), Json::Bool(true)),
        ("hugepages".to_string(), Json::Bool(kway::kway::hugepages_enabled())),
        ("provenance".to_string(), Json::Str("measured".to_string())),
        ("results".to_string(), Json::Array(json_rows)),
    ]);
    if let Err(e) = kway::util::json::check_hotpath_schema(&doc) {
        eprintln!("refusing to write malformed BENCH_hotpath.json: {e:#}");
        return;
    }
    match std::fs::write("BENCH_hotpath.json", format!("{doc}\n")) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => eprintln!("writing BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    // Before any table is allocated, so every cache under test gets the
    // advised backing; the JSON artifact records the setting.
    if args.has_flag("hugepages") {
        kway::kway::set_hugepages(true);
        println!("(tables madvise(MADV_HUGEPAGE)-backed)");
    }
    let smoke = args.has_flag("smoke");
    let quick = smoke || kway::figures::quick_mode();
    let iters: u64 = if smoke {
        50_000
    } else if quick {
        200_000
    } else {
        1_000_000
    };
    let capacity = 1 << 16;

    println!(
        "== probe path: KW-WFSC resident-set gets per probe kernel (pinned) ==\n\
         active auto-dispatch: {}",
        simd::active_kind().name()
    );
    let probe_threads: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8, 16] };
    let probe_iters = if smoke { 100_000 } else { 2_000_000 };
    let sw = Stopwatch::start();
    let rows = bench_probe_path(probe_iters, probe_threads);
    let probe_ms = (sw.elapsed_secs() * 1e3) as i64;
    if args.has_flag("json") {
        write_hotpath_json(&rows, probe_ms);
    }
    if smoke {
        // CI smoke: the probe path ran under every kernel; the rest of
        // the suite is long-form ablation, not needed for a health check.
        println!("\n(smoke mode: skipping the long-form ablation sections)");
        return;
    }

    println!("\n== per-op latency (capacity 2^16, 8 ways / sample 8) ==");
    bench_cache(&KwWfa::new(capacity, 8, Policy::Lru), "KW-WFA", iters);
    bench_cache(&KwWfsc::new(capacity, 8, Policy::Lru), "KW-WFSC", iters);
    bench_cache(&KwLs::new(capacity, 8, Policy::Lru), "KW-LS", iters);
    bench_cache(&Sampled::with_defaults(capacity, 8, Policy::Lru), "sampled", iters);
    bench_cache(&GuavaLike::new(capacity, 4), "Guava", iters);
    bench_cache(&CaffeineLike::new(capacity), "Caffeine", iters / 4);

    println!("\n== ablation: scan cost vs associativity (get-hit ns) ==");
    print!("{:10}", "ways");
    for ways in [4usize, 8, 16, 32, 64, 128] {
        print!(" {ways:>8}");
    }
    println!();
    for (name, make) in [
        ("KW-WFA", Box::new(|w| Box::new(KwWfa::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)
            as Box<dyn Fn(usize) -> Box<dyn Cache>>),
        ("KW-WFSC", Box::new(|w| Box::new(KwWfsc::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)),
        ("KW-LS", Box::new(|w| Box::new(KwLs::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)),
    ] {
        print!("{name:10}");
        for ways in [4usize, 8, 16, 32, 64, 128] {
            let c = make(ways);
            let resident = (c.capacity() / 2) as u64;
            for k in 0..resident {
                c.put(k, k);
            }
            let mut rng = Rng::new(9);
            let n = iters / 4;
            let sw = Stopwatch::start();
            let mut sink = 0u64;
            for _ in 0..n {
                sink ^= c.get(rng.below(resident)).unwrap_or(0);
            }
            let ns = ns_per_op(n, sw.elapsed_secs());
            print!(" {:8.1}", ns + (sink & 1) as f64 * 1e-9);
        }
        println!();
    }

    println!("\n== hash & policy primitives ==");
    {
        let n = iters * 4;
        let sw = Stopwatch::start();
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= hash::xxh64_u64(i, 0);
        }
        println!("xxh64_u64      {:6.2} ns/hash (acc {acc})", ns_per_op(n, sw.elapsed_secs()));
        let sw = Stopwatch::start();
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= hash::mix64(i);
        }
        println!("mix64          {:6.2} ns/hash (acc {acc})", ns_per_op(n, sw.elapsed_secs()));
    }
    {
        // Victim selection over one 8-way set, per policy.
        let metas: Vec<u64> = (0..8).map(|i| 1000 - i).collect();
        let mut rng = Rng::new(11);
        for policy in Policy::ALL {
            let n = iters;
            let sw = Stopwatch::start();
            let mut acc = 0usize;
            for t in 0..n {
                acc ^= policy.select_victim(std::hint::black_box(&metas), t, &mut rng);
            }
            std::hint::black_box(acc);
            println!(
                "victim_select[{:10}] {:6.2} ns (acc {acc})",
                policy.name(),
                ns_per_op(n, sw.elapsed_secs())
            );
        }
    }

    println!("\n== the paper's §1.1 comparison: 1 hash vs K PRNG draws ==");
    {
        let n = iters;
        let sw = Stopwatch::start();
        let mut acc = 0usize;
        for i in 0..n {
            acc ^= hash::set_index(i, 1 << 13); // k-way: one hash per miss
        }
        let one_hash = ns_per_op(n, sw.elapsed_secs());
        let mut rng = Rng::new(13);
        let sw = Stopwatch::start();
        let mut acc2 = 0u64;
        for _ in 0..n {
            for _ in 0..8 {
                acc2 ^= rng.below(1 << 16); // sampled: 8 PRNG draws per miss
            }
        }
        let eight_draws = ns_per_op(n, sw.elapsed_secs());
        println!(
            "k-way set hash {one_hash:6.2} ns vs sampled 8 PRNG draws {eight_draws:6.2} ns (x{:.1}) (acc {acc} {acc2})",
            eight_draws / one_hash
        );
    }
}
