//! Microbenchmarks and ablations for the design choices DESIGN.md calls
//! out:
//!
//! * per-op latency (get-hit / get-miss+put) for every implementation;
//! * the O(K) scan cost vs associativity for WFA (array-of-structs) vs
//!   WFSC (structure-of-arrays) — the paper's §3 locality argument;
//! * the KW-LS upgrade path vs the wait-free paths;
//! * hash function cost (xxh64 vs mix64) and victim-select cost per
//!   policy — the "one hash vs K PRNG draws" comparison of §1.1.
//!
//! ```bash
//! cargo bench --bench microbench
//! ```

use kway::fully::Sampled;
use kway::kway::{KwLs, KwWfa, KwWfsc};
use kway::policy::Policy;
use kway::products::{CaffeineLike, GuavaLike};
use kway::util::clock::Stopwatch;
use kway::util::hash;
use kway::util::rng::Rng;
use kway::Cache;

fn ns_per_op(total_ops: u64, secs: f64) -> f64 {
    secs * 1e9 / total_ops as f64
}

fn bench_cache(c: &dyn Cache, label: &str, iters: u64) {
    let mut rng = Rng::new(7);
    // Resident working set: half capacity.
    let resident = (c.capacity() / 2) as u64;
    for k in 0..resident {
        c.put(k, k);
    }
    // get-hit
    let sw = Stopwatch::start();
    let mut sink = 0u64;
    for _ in 0..iters {
        let k = rng.below(resident);
        sink ^= c.get(k).unwrap_or(0);
    }
    let hit_ns = ns_per_op(iters, sw.elapsed_secs());
    // get-miss + put (the miss path)
    let mut next = 1u64 << 40;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        if c.get(next).is_none() {
            c.put(next, next);
        }
        next += 1;
    }
    let miss_ns = ns_per_op(iters, sw.elapsed_secs());
    println!("{label:14} get-hit {hit_ns:7.1} ns   miss+put {miss_ns:7.1} ns   (sink {sink})");
}

fn main() {
    let quick = kway::figures::quick_mode();
    let iters: u64 = if quick { 200_000 } else { 1_000_000 };
    let capacity = 1 << 16;

    println!("== per-op latency (capacity 2^16, 8 ways / sample 8) ==");
    bench_cache(&KwWfa::new(capacity, 8, Policy::Lru), "KW-WFA", iters);
    bench_cache(&KwWfsc::new(capacity, 8, Policy::Lru), "KW-WFSC", iters);
    bench_cache(&KwLs::new(capacity, 8, Policy::Lru), "KW-LS", iters);
    bench_cache(&Sampled::with_defaults(capacity, 8, Policy::Lru), "sampled", iters);
    bench_cache(&GuavaLike::new(capacity, 4), "Guava", iters);
    bench_cache(&CaffeineLike::new(capacity), "Caffeine", iters / 4);

    println!("\n== ablation: scan cost vs associativity (get-hit ns) ==");
    print!("{:10}", "ways");
    for ways in [4usize, 8, 16, 32, 64, 128] {
        print!(" {ways:>8}");
    }
    println!();
    for (name, make) in [
        ("KW-WFA", Box::new(|w| Box::new(KwWfa::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)
            as Box<dyn Fn(usize) -> Box<dyn Cache>>),
        ("KW-WFSC", Box::new(|w| Box::new(KwWfsc::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)),
        ("KW-LS", Box::new(|w| Box::new(KwLs::new(1 << 16, w, Policy::Lru)) as Box<dyn Cache>)),
    ] {
        print!("{name:10}");
        for ways in [4usize, 8, 16, 32, 64, 128] {
            let c = make(ways);
            let resident = (c.capacity() / 2) as u64;
            for k in 0..resident {
                c.put(k, k);
            }
            let mut rng = Rng::new(9);
            let n = iters / 4;
            let sw = Stopwatch::start();
            let mut sink = 0u64;
            for _ in 0..n {
                sink ^= c.get(rng.below(resident)).unwrap_or(0);
            }
            let ns = ns_per_op(n, sw.elapsed_secs());
            print!(" {:8.1}", ns + (sink & 1) as f64 * 1e-9);
        }
        println!();
    }

    println!("\n== hash & policy primitives ==");
    {
        let n = iters * 4;
        let sw = Stopwatch::start();
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= hash::xxh64_u64(i, 0);
        }
        println!("xxh64_u64      {:6.2} ns/hash (acc {acc})", ns_per_op(n, sw.elapsed_secs()));
        let sw = Stopwatch::start();
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= hash::mix64(i);
        }
        println!("mix64          {:6.2} ns/hash (acc {acc})", ns_per_op(n, sw.elapsed_secs()));
    }
    {
        // Victim selection over one 8-way set, per policy.
        let metas: Vec<u64> = (0..8).map(|i| 1000 - i).collect();
        let mut rng = Rng::new(11);
        for policy in Policy::ALL {
            let n = iters;
            let sw = Stopwatch::start();
            let mut acc = 0usize;
            for t in 0..n {
                acc ^= policy.select_victim(std::hint::black_box(&metas), t, &mut rng);
            }
            std::hint::black_box(acc);
            println!(
                "victim_select[{:10}] {:6.2} ns (acc {acc})",
                policy.name(),
                ns_per_op(n, sw.elapsed_secs())
            );
        }
    }

    println!("\n== the paper's §1.1 comparison: 1 hash vs K PRNG draws ==");
    {
        let n = iters;
        let sw = Stopwatch::start();
        let mut acc = 0usize;
        for i in 0..n {
            acc ^= hash::set_index(i, 1 << 13); // k-way: one hash per miss
        }
        let one_hash = ns_per_op(n, sw.elapsed_secs());
        let mut rng = Rng::new(13);
        let sw = Stopwatch::start();
        let mut acc2 = 0u64;
        for _ in 0..n {
            for _ in 0..8 {
                acc2 ^= rng.below(1 << 16); // sampled: 8 PRNG draws per miss
            }
        }
        let eight_draws = ns_per_op(n, sw.elapsed_secs());
        println!(
            "k-way set hash {one_hash:6.2} ns vs sampled 8 PRNG draws {eight_draws:6.2} ns (x{:.1}) (acc {acc} {acc2})",
            eight_draws / one_hash
        );
    }
}
