//! Regenerates the paper's trace-replay throughput figures
//! (Figures 14–26): Mops/s vs thread count for KW-WFA / KW-WFSC / KW-LS /
//! sampled / Guava / Caffeine / segmented Caffeine, with the §5.1.2
//! methodology (warm-up, barrier start, timed run, repeated runs).
//!
//! ```bash
//! cargo bench --bench throughput
//! KWAY_BENCH_QUICK=1 cargo bench --bench throughput
//! cargo bench --bench throughput -- --figure fig14
//! ```
//!
//! Single-core container note: the thread sweep oversubscribes one core,
//! so absolute scaling flattens; the *relative ordering* of the
//! synchronization designs is the reproducible signal (DESIGN.md
//! §Substitutions).

use kway::figures::{quick_mode, THROUGHPUT_FIGURES};
use kway::policy::Policy;
use kway::throughput::{impl_factory, measure, RunConfig, Workload, IMPLS};
use kway::tinylfu::AdmissionMode;
use kway::trace::paper;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--figure")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = quick_mode();
    let threads: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
    let duration = Duration::from_millis(if quick { 100 } else { 300 });
    let repeats = if quick { 2 } else { 3 };
    let len = if quick { 100_000 } else { 500_000 };

    for fig in THROUGHPUT_FIGURES {
        if let Some(ref f) = only {
            if f != fig.id {
                continue;
            }
        }
        let trace = Arc::new(paper::build(fig.trace, len, 42).expect("trace model"));
        println!(
            "\n==== {} — trace {} cache 2^{} ({} in the paper) — Mops/s ====",
            fig.id,
            fig.trace,
            fig.capacity.trailing_zeros(),
            fig.platform,
        );
        print!("{:14}", "impl\\threads");
        for t in &threads {
            print!(" {t:>9}");
        }
        println!("   hit-ratio");
        for name in IMPLS {
            print!("{name:14}");
            let mut last_hit = 0.0;
            for &t in &threads {
                let factory =
                    impl_factory(name, fig.capacity, t, Policy::Lru, AdmissionMode::None)
                        .unwrap();
                let cfg =
                    RunConfig { threads: t, duration, repeats, seed: 42, ..Default::default() };
                let r = measure(&*factory, &Workload::TraceReplay(trace.clone()), &cfg);
                last_hit = r.hit_ratio;
                print!(" {:9.2}", r.mops.mean());
            }
            println!("   {last_hit:9.3}");
        }
    }
}
