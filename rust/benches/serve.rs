//! Wire-serving benchmark: the backend × connections × pipeline-depth
//! × threads sweep behind `BENCH_serve.json` (schema `kway-serve-v2`).
//!
//! Starts the TCP front end in-process on a loopback ephemeral port over
//! a [`CacheService`] — once per event-loop backend (epoll readiness
//! mode, io_uring completion mode) — then drives it with the crate's
//! own pipelined load generator for every (proto, connections,
//! pipeline) point. Two headline comparisons fall out of the sweep:
//!
//! * the pipeline axis at equal connections: a P-deep pipeline
//!   amortizes syscalls per request *and* lets the per-connection
//!   accumulator hand P-wide scatter/gather batches to the cache
//!   workers, so pipeline=16 rows should clearly beat pipeline=1;
//! * the backend axis at equal pipeline: completion mode submits one
//!   `io_uring_enter` per tick where readiness mode pays
//!   epoll_wait + read + writev per ready connection, so uring rows
//!   should show a lower measured `syscalls_per_op` (read off the
//!   server's own io-syscall ledger, not asserted).
//!
//! ```bash
//! cargo bench --bench serve                    # full sweep
//! cargo bench --bench serve -- --smoke         # seconds-scale CI smoke
//! cargo bench --bench serve -- --json          # also write BENCH_serve.json
//! cargo bench --bench serve -- --hugepages     # THP-backed cache tables
//! ```
//!
//! On targets without the epoll backend the bench prints a skip notice
//! and exits cleanly; on kernels without io_uring the uring rows are
//! skipped with a notice and the epoll rows still run (the JSON is
//! only written from a real run).
//!
//! [`CacheService`]: kway::coordinator::CacheService

use kway::coordinator::{CacheService, ServiceConfig};
use kway::kway::KwWfsc;
use kway::net::loadgen::{self, LoadgenConfig, LoadgenResult, WireProto};
use kway::net::{BackendChoice, Server, ServerConfig};
use kway::policy::Policy;
use kway::tinylfu::AdmissionMode;
use kway::util::cli::Args;
use kway::util::json::{check_serve_schema, Json, SERVE_SCHEMA};
use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 42;

struct Row {
    backend: &'static str,
    cfg: LoadgenConfig,
    result: LoadgenResult,
    syscalls_per_op: f64,
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    if args.has_flag("hugepages") {
        kway::kway::set_hugepages(true);
    }
    let smoke = args.has_flag("smoke") || kway::figures::quick_mode();
    let pin = args.has_flag("pin");
    let duration = Duration::from_millis(if smoke { 200 } else { 1000 });
    let conn_axis: &[usize] = if smoke { &[2] } else { &[4, 16] };
    let pipe_axis: &[usize] = &[1, 16];
    let threads = if smoke { 1 } else { 2 };
    let keyspace = 1u64 << 15;

    let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(1 << 16, 8, Policy::Lru));
    let service = Arc::new(CacheService::start(
        cache,
        ServiceConfig {
            workers: 2,
            admission: AdmissionMode::None,
            default_ttl: None,
            ..Default::default()
        },
    ));
    println!("== wire serving: loopback, duration {duration:?}, threads {threads} ==");
    println!(
        "{:>10} {:>8} {:>12} {:>9} {:>8} {:>9} {:>7} {:>9} {:>9} {:>7} {:>8}",
        "proto",
        "backend",
        "connections",
        "pipeline",
        "threads",
        "Mops/s",
        "hit",
        "p50_ns",
        "p99_ns",
        "errs",
        "sys/op"
    );

    let mut rows: Vec<Row> = Vec::new();
    let mut served_any = false;
    for backend in [BackendChoice::Epoll, BackendChoice::Uring] {
        // A fresh server per backend over the same service: the cache
        // stays warm across backends (both measure the same traffic),
        // and per-row syscall figures come from metric *deltas*, so the
        // shared counters do not bleed between rows.
        let listener = TcpListener::bind("127.0.0.1:0").expect("binding loopback");
        let server = match Server::start(
            listener,
            Arc::clone(&service),
            ServerConfig { io_threads: 2, backend, ..Default::default() },
        ) {
            Ok(s) => s,
            Err(e) => {
                println!(
                    "{} rows skipped: backend unavailable on this target ({e})",
                    backend.name()
                );
                continue;
            }
        };
        served_any = true;
        let addr = server.local_addr().to_string();
        for proto in [WireProto::Memcached, WireProto::Resp] {
            for &connections in conn_axis {
                for &pipeline in pipe_axis {
                    let cfg = LoadgenConfig {
                        addr: addr.clone(),
                        proto,
                        connections,
                        pipeline,
                        threads: threads.min(connections),
                        duration,
                        keyspace,
                        set_every: 8,
                        ttl: None,
                        zipf_alpha: None,
                        value_dist: kway::lifetime::ValueDist::Word,
                        seed: SEED,
                        pin,
                        max_reconnects: 1024,
                        faults: None,
                    };
                    let m = service.metrics();
                    let ops_at = |m: &kway::coordinator::ServiceMetrics| {
                        m.ops.gets.load(Ordering::Relaxed) + m.ops.puts.load(Ordering::Relaxed)
                    };
                    let sys_before = m.io_syscalls.load(Ordering::Relaxed);
                    let ops_before = ops_at(m);
                    match loadgen::run(&cfg) {
                        Ok(r) => {
                            let sys = m.io_syscalls.load(Ordering::Relaxed) - sys_before;
                            let ops = ops_at(m) - ops_before;
                            let spo = if ops > 0 { sys as f64 / ops as f64 } else { 0.0 };
                            println!(
                                "{:>10} {:>8} {:>12} {:>9} {:>8} {:>9.3} {:>7.3} {:>9} {:>9} \
                                 {:>7} {:>8.4}",
                                proto.name(),
                                backend.name(),
                                connections,
                                pipeline,
                                cfg.threads,
                                r.mops(),
                                r.hit_ratio(),
                                r.p50_ns,
                                r.p99_ns,
                                r.errors,
                                spo
                            );
                            rows.push(Row {
                                backend: backend.name(),
                                cfg,
                                result: r,
                                syscalls_per_op: spo,
                            });
                        }
                        Err(e) => eprintln!(
                            "{} {} c={connections} p={pipeline}: {e:#}",
                            proto.name(),
                            backend.name()
                        ),
                    }
                }
            }
        }
        server.stop();
    }
    if !served_any {
        println!("serve bench skipped: no event-loop backend available on this target");
        return;
    }

    // Headline claim #1: deep pipelines beat depth-1 at equal
    // connections (per backend).
    for backend in ["epoll", "uring"] {
        for proto in [WireProto::Memcached, WireProto::Resp] {
            for &connections in conn_axis {
                let at = |p: usize| {
                    rows.iter()
                        .find(|row| {
                            row.backend == backend
                                && row.cfg.proto == proto
                                && row.cfg.connections == connections
                                && row.cfg.pipeline == p
                        })
                        .map(|row| row.result.mops())
                };
                if let (Some(deep), Some(shallow)) = (at(16), at(1)) {
                    if shallow > 0.0 {
                        println!(
                            "{:>10} {backend} c={connections}: pipeline 16 vs 1 = {:.2}x",
                            proto.name(),
                            deep / shallow
                        );
                    }
                }
            }
        }
    }

    // Headline claim #2: completion mode spends fewer syscalls per op
    // than readiness mode at the deep-pipeline point.
    for proto in [WireProto::Memcached, WireProto::Resp] {
        for &connections in conn_axis {
            let at = |b: &str| {
                rows.iter()
                    .find(|row| {
                        row.backend == b
                            && row.cfg.proto == proto
                            && row.cfg.connections == connections
                            && row.cfg.pipeline == 16
                    })
                    .map(|row| row.syscalls_per_op)
            };
            if let (Some(uring), Some(epoll)) = (at("uring"), at("epoll")) {
                println!(
                    "{:>10} c={connections} p=16: syscalls/op uring {uring:.4} vs epoll \
                     {epoll:.4}{}",
                    proto.name(),
                    if uring < epoll { "" } else { "  (!! uring not cheaper)" }
                );
            }
        }
    }

    if args.has_flag("json") && !rows.is_empty() {
        let json_rows: Vec<Json> = rows
            .iter()
            .map(|row| {
                Json::Object(vec![
                    ("proto".to_string(), Json::Str(row.cfg.proto.name().to_string())),
                    ("backend".to_string(), Json::Str(row.backend.to_string())),
                    ("connections".to_string(), Json::Int(row.cfg.connections as i64)),
                    ("pipeline".to_string(), Json::Int(row.cfg.pipeline as i64)),
                    ("threads".to_string(), Json::Int(row.cfg.threads as i64)),
                    ("ops".to_string(), Json::Int(row.result.ops as i64)),
                    ("mops".to_string(), Json::Float(row.result.mops())),
                    ("hit_ratio".to_string(), Json::Float(row.result.hit_ratio())),
                    ("p50_ns".to_string(), Json::Int(row.result.p50_ns as i64)),
                    ("p99_ns".to_string(), Json::Int(row.result.p99_ns as i64)),
                    ("errors".to_string(), Json::Int(row.result.errors as i64)),
                    ("syscalls_per_op".to_string(), Json::Float(row.syscalls_per_op)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("schema".to_string(), Json::Str(SERVE_SCHEMA.to_string())),
            ("addr".to_string(), Json::Str("127.0.0.1:0 (per-backend ephemeral)".to_string())),
            ("duration_ms".to_string(), Json::Int(duration.as_millis() as i64)),
            ("keyspace".to_string(), Json::Int(keyspace as i64)),
            ("seed".to_string(), Json::Int(SEED as i64)),
            ("pinned".to_string(), Json::Bool(pin)),
            ("provenance".to_string(), Json::Str("measured".to_string())),
            ("results".to_string(), Json::Array(json_rows)),
        ]);
        if let Err(e) = check_serve_schema(&doc) {
            eprintln!("refusing to write malformed BENCH_serve.json: {e:#}");
        } else {
            match std::fs::write("BENCH_serve.json", format!("{doc}\n")) {
                Ok(()) => println!("\nwrote BENCH_serve.json"),
                Err(e) => eprintln!("writing BENCH_serve.json: {e}"),
            }
        }
    }

    if let Ok(service) = Arc::try_unwrap(service) {
        service.shutdown();
    }
}
