//! Wire-serving benchmark: the connections × pipeline-depth × threads
//! sweep behind `BENCH_serve.json` (schema `kway-serve-v1`).
//!
//! Starts the TCP front end in-process on a loopback ephemeral port over
//! a [`CacheService`], then drives it with the crate's own pipelined
//! load generator for every (proto, connections, pipeline) point. The
//! headline comparison is the pipeline axis at equal connections: a
//! P-deep pipeline amortizes syscalls per request *and* lets the
//! per-connection accumulator hand P-wide scatter/gather batches to the
//! cache workers, so pipeline=16 rows should clearly beat pipeline=1.
//!
//! ```bash
//! cargo bench --bench serve                    # full sweep
//! cargo bench --bench serve -- --smoke         # seconds-scale CI smoke
//! cargo bench --bench serve -- --json          # also write BENCH_serve.json
//! cargo bench --bench serve -- --hugepages     # THP-backed cache tables
//! ```
//!
//! On targets without the epoll backend the bench prints a skip notice
//! and exits cleanly (the JSON is only written from a real run).
//!
//! [`CacheService`]: kway::coordinator::CacheService

use kway::coordinator::{CacheService, ServiceConfig};
use kway::kway::KwWfsc;
use kway::net::loadgen::{self, LoadgenConfig, LoadgenResult, WireProto};
use kway::net::{Server, ServerConfig};
use kway::policy::Policy;
use kway::tinylfu::AdmissionMode;
use kway::util::cli::Args;
use kway::util::json::{check_serve_schema, Json, SERVE_SCHEMA};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 42;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    if args.has_flag("hugepages") {
        kway::kway::set_hugepages(true);
    }
    let smoke = args.has_flag("smoke") || kway::figures::quick_mode();
    let pin = args.has_flag("pin");
    let duration = Duration::from_millis(if smoke { 200 } else { 1000 });
    let conn_axis: &[usize] = if smoke { &[2] } else { &[4, 16] };
    let pipe_axis: &[usize] = &[1, 16];
    let threads = if smoke { 1 } else { 2 };
    let keyspace = 1u64 << 15;

    let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(1 << 16, 8, Policy::Lru));
    let service = Arc::new(CacheService::start(
        cache,
        ServiceConfig {
            workers: 2,
            admission: AdmissionMode::None,
            default_ttl: None,
            ..Default::default()
        },
    ));
    let listener = TcpListener::bind("127.0.0.1:0").expect("binding loopback");
    let server = match Server::start(
        listener,
        Arc::clone(&service),
        ServerConfig { io_threads: 2, ..Default::default() },
    ) {
        Ok(s) => s,
        Err(e) => {
            println!("serve bench skipped: wire front end unavailable on this target ({e})");
            return;
        }
    };
    let addr = server.local_addr().to_string();
    println!("== wire serving: {addr}, duration {duration:?}, threads {threads} ==");
    println!(
        "{:>10} {:>12} {:>9} {:>8} {:>9} {:>7} {:>9} {:>9} {:>7}",
        "proto", "connections", "pipeline", "threads", "Mops/s", "hit", "p50_ns", "p99_ns", "errs"
    );

    let mut rows: Vec<(LoadgenConfig, LoadgenResult)> = Vec::new();
    for proto in [WireProto::Memcached, WireProto::Resp] {
        for &connections in conn_axis {
            for &pipeline in pipe_axis {
                let cfg = LoadgenConfig {
                    addr: addr.clone(),
                    proto,
                    connections,
                    pipeline,
                    threads: threads.min(connections),
                    duration,
                    keyspace,
                    set_every: 8,
                    ttl: None,
                    zipf_alpha: None,
                    value_dist: kway::lifetime::ValueDist::Word,
                    seed: SEED,
                    pin,
                    max_reconnects: 1024,
                    faults: None,
                };
                match loadgen::run(&cfg) {
                    Ok(r) => {
                        println!(
                            "{:>10} {:>12} {:>9} {:>8} {:>9.3} {:>7.3} {:>9} {:>9} {:>7}",
                            proto.name(),
                            connections,
                            pipeline,
                            cfg.threads,
                            r.mops(),
                            r.hit_ratio(),
                            r.p50_ns,
                            r.p99_ns,
                            r.errors
                        );
                        rows.push((cfg, r));
                    }
                    Err(e) => eprintln!("{} c={connections} p={pipeline}: {e:#}", proto.name()),
                }
            }
        }
    }

    // The tentpole claim, read straight off the sweep: deep pipelines
    // beat depth-1 at equal connections.
    for proto in [WireProto::Memcached, WireProto::Resp] {
        for &connections in conn_axis {
            let at = |p: usize| {
                rows.iter()
                    .find(|(c, _)| {
                        c.proto == proto && c.connections == connections && c.pipeline == p
                    })
                    .map(|(_, r)| r.mops())
            };
            if let (Some(deep), Some(shallow)) = (at(16), at(1)) {
                if shallow > 0.0 {
                    println!(
                        "{:>10} c={connections}: pipeline 16 vs 1 = {:.2}x",
                        proto.name(),
                        deep / shallow
                    );
                }
            }
        }
    }

    if args.has_flag("json") && !rows.is_empty() {
        let json_rows: Vec<Json> = rows
            .iter()
            .map(|(cfg, r)| {
                Json::Object(vec![
                    ("proto".to_string(), Json::Str(cfg.proto.name().to_string())),
                    ("connections".to_string(), Json::Int(cfg.connections as i64)),
                    ("pipeline".to_string(), Json::Int(cfg.pipeline as i64)),
                    ("threads".to_string(), Json::Int(cfg.threads as i64)),
                    ("ops".to_string(), Json::Int(r.ops as i64)),
                    ("mops".to_string(), Json::Float(r.mops())),
                    ("hit_ratio".to_string(), Json::Float(r.hit_ratio())),
                    ("p50_ns".to_string(), Json::Int(r.p50_ns as i64)),
                    ("p99_ns".to_string(), Json::Int(r.p99_ns as i64)),
                    ("errors".to_string(), Json::Int(r.errors as i64)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("schema".to_string(), Json::Str(SERVE_SCHEMA.to_string())),
            ("addr".to_string(), Json::Str(addr.clone())),
            ("duration_ms".to_string(), Json::Int(duration.as_millis() as i64)),
            ("keyspace".to_string(), Json::Int(keyspace as i64)),
            ("seed".to_string(), Json::Int(SEED as i64)),
            ("pinned".to_string(), Json::Bool(pin)),
            ("provenance".to_string(), Json::Str("measured".to_string())),
            ("results".to_string(), Json::Array(json_rows)),
        ]);
        if let Err(e) = check_serve_schema(&doc) {
            eprintln!("refusing to write malformed BENCH_serve.json: {e:#}");
        } else {
            match std::fs::write("BENCH_serve.json", format!("{doc}\n")) {
                Ok(()) => println!("\nwrote BENCH_serve.json"),
                Err(e) => eprintln!("writing BENCH_serve.json: {e}"),
            }
        }
    }

    server.stop();
    if let Ok(service) = Arc::try_unwrap(service) {
        service.shutdown();
    }
}
