//! Entry lifetime and weighted capacity (figE* series, the lifetime
//! extension): throughput and steady-state hit ratio of the expiring
//! get-or-fill workload across the TTL and weight-distribution points in
//! `kway::figures::EXPIRY_FIGURES`, for the three k-way variants against
//! the sampled baseline.
//!
//! ```bash
//! cargo bench --bench expiry
//! KWAY_BENCH_QUICK=1 cargo bench --bench expiry
//! ```
//!
//! What to look for (DESIGN.md §Expiration, §Weighted capacity): the
//! figE0 row (no TTL, unit weights) is the control — it runs the exact
//! pre-lifetime code path, so its Mops/s should match the 100%-hit
//! synthetic figures. Shrinking the TTL lowers the hit ratio (entries
//! die between touches) while k-way throughput stays nearly flat: lazy
//! reclamation is folded into probes the engine performs anyway, which
//! is the limited-associativity advantage — no timer wheel, no
//! background sweeper. The zipf-weighted rows hold fewer, heavier
//! entries per set, trading hit ratio for byte-accurate capacity.

use kway::figures::{quick_mode, EXPIRY_FIGURES};
use kway::lifetime::WeightDist;
use kway::policy::Policy;
use kway::throughput::{impl_factory, measure, FillSpec, RunConfig, Workload};
use kway::tinylfu::AdmissionMode;
use std::time::Duration;

fn main() {
    let quick = quick_mode();
    let capacity: usize = if quick { 1 << 12 } else { 1 << 16 };
    // Working set 2x capacity: misses and evictions happen even without
    // TTLs, so the TTL effect shows on top of a realistic baseline.
    let working_set = (capacity * 2) as u64;
    let threads_list: Vec<usize> = if quick { vec![2] } else { vec![1, 4] };
    let duration = Duration::from_millis(if quick { 100 } else { 300 });
    let repeats = if quick { 2 } else { 3 };
    let impls = ["KW-WFA", "KW-WFSC", "KW-LS", "sampled"];

    for &threads in &threads_list {
        println!(
            "\n==== expiring get-or-fill — capacity 2^{} working set {} threads {} ====",
            capacity.trailing_zeros(),
            working_set,
            threads
        );
        println!(
            "{:10} {:>8} {:>10} {:14} {:>10} {:>12} {:>12} {:>8}",
            "figure", "ttl(ms)", "weights", "impl", "Mops/s", "p50(ns)", "p99(ns)", "hit"
        );
        for fig in EXPIRY_FIGURES {
            let fill = FillSpec {
                ttl: (fig.ttl_ms > 0).then(|| Duration::from_millis(fig.ttl_ms)),
                weight_dist: WeightDist::parse(fig.weight_dist).unwrap(),
            };
            for name in impls {
                let factory =
                    impl_factory(name, capacity, threads, Policy::Lru, AdmissionMode::None)
                        .unwrap();
                let cfg = RunConfig {
                    threads,
                    duration,
                    repeats,
                    seed: 42,
                    fill: fill.clone(),
                    ..Default::default()
                };
                let r = measure(&*factory, &Workload::Expiring { working_set }, &cfg);
                println!(
                    "{:10} {:>8} {:>10} {:14} {:>10.2} {:>12} {:>12} {:>8.3}",
                    fig.id,
                    fig.ttl_ms,
                    fig.weight_dist,
                    name,
                    r.mops.mean(),
                    r.lat_p50_ns,
                    r.lat_p99_ns,
                    r.hit_ratio
                );
            }
        }
    }
    println!(
        "\nReading: figE0 is the immortal/unit control (the pre-lifetime\n\
         path, bit-identical by construction); hit ratio falls as TTL\n\
         shrinks below the re-reference interval while k-way Mops/s stays\n\
         nearly flat (reclamation rides the probe). zipf:8 rows bound each\n\
         set by total weight instead of entry count."
    );
}
