//! Byte-value slab benchmark: the (variant × value distribution)
//! sweep behind `BENCH_slab.json` (schema `kway-slab-v1`).
//!
//! Every K-Way variant is built over a slab value store
//! (`build_with_values`) and driven with a get-or-fill loop whose
//! payloads come from a deterministic [`ValueDist`]: fixed sizes pin a
//! single slab class, `uniform`/`zipf` straddle many classes at once —
//! the allocation pattern the free lists must absorb. Each row reports
//! throughput, hit ratio, sampled per-op latency, and the slab bytes
//! the cache actually held when the run quiesced (`value_bytes`, the
//! weight-honesty column: DESIGN.md §Value store).
//!
//! ```bash
//! cargo bench --bench slab                    # full sweep
//! cargo bench --bench slab -- --smoke         # seconds-scale CI smoke
//! cargo bench --bench slab -- --json          # also write BENCH_slab.json
//! ```
//!
//! [`ValueDist`]: kway::lifetime::ValueDist

use kway::kway::{build_with_values, Variant};
use kway::lifetime::ValueDist;
use kway::policy::Policy;
use kway::util::cli::Args;
use kway::util::json::{check_slab_schema, Json, SLAB_SCHEMA};
use kway::util::rng::Rng;
use kway::util::stats::{percentile_u64, Reservoir};
use kway::Cache;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const SEED: u64 = 42;

/// One sampled op in ~64 keeps the `Instant::now` cost off the hot path.
const SAMPLE_GAP: u64 = 64;

/// One measured row of the sweep.
struct Row {
    impl_name: &'static str,
    dist: ValueDist,
    threads: usize,
    ops: u64,
    mops: f64,
    hit_ratio: f64,
    p50_ns: u64,
    p99_ns: u64,
    value_bytes: u64,
}

/// Drive `threads` get-or-fill workers with `dist`-shaped byte payloads
/// over a uniform working set for `duration`.
fn run_point(
    cache: &Arc<dyn Cache>,
    dist: ValueDist,
    working_set: u64,
    threads: usize,
    duration: Duration,
) -> (u64, f64, f64, u64, u64) {
    // Pre-install the resident set so the measured window starts warm.
    let mut payload = Vec::new();
    for key in 0..working_set {
        dist.fill(key, &mut payload);
        cache.put_bytes(key, &payload);
    }
    let stop = AtomicBool::new(false);
    let ops = AtomicU64::new(0);
    let hits = AtomicU64::new(0);
    let gets = AtomicU64::new(0);
    let samples: std::sync::Mutex<Vec<u64>> = std::sync::Mutex::new(Vec::new());
    let start = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads {
            let cache = Arc::clone(cache);
            let stop = &stop;
            let ops = &ops;
            let hits = &hits;
            let gets = &gets;
            let samples = &samples;
            scope.spawn(move || {
                let mut rng = Rng::new(SEED ^ (0x51AB << 8) ^ t as u64);
                let mut reservoir = Reservoir::new(10_000, SEED ^ 0x5A3B ^ t as u64);
                let mut payload = Vec::new();
                let mut local = (0u64, 0u64, 0u64);
                let mut countdown = 1u64;
                loop {
                    for _ in 0..256 {
                        let key = rng.below(working_set);
                        local.2 += 1;
                        countdown -= 1;
                        let timed = countdown == 0;
                        let t0 = if timed { Some(Instant::now()) } else { None };
                        match cache.get_bytes(key) {
                            Some(_) => {
                                local.1 += 1;
                                local.0 += 1;
                            }
                            None => {
                                dist.fill(key, &mut payload);
                                cache.put_bytes(key, &payload);
                                local.0 += 2;
                            }
                        }
                        if let Some(t0) = t0 {
                            reservoir.record(t0.elapsed().as_nanos() as u64);
                            countdown = rng.range_u64(1, 2 * SAMPLE_GAP - 1);
                        }
                    }
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                }
                ops.fetch_add(local.0, Ordering::Relaxed);
                hits.fetch_add(local.1, Ordering::Relaxed);
                gets.fetch_add(local.2, Ordering::Relaxed);
                samples.lock().unwrap().extend_from_slice(reservoir.samples());
            });
        }
        std::thread::sleep(duration);
        stop.store(true, Ordering::Release);
    });
    let secs = start.elapsed().as_secs_f64();
    let total_ops = ops.load(Ordering::Relaxed);
    let total_gets = gets.load(Ordering::Relaxed);
    let hit_ratio = if total_gets > 0 {
        hits.load(Ordering::Relaxed) as f64 / total_gets as f64
    } else {
        0.0
    };
    let mut lat = std::mem::take(&mut *samples.lock().unwrap());
    (
        total_ops,
        total_ops as f64 / secs / 1e6,
        hit_ratio,
        percentile_u64(&mut lat, 50.0),
        percentile_u64(&mut lat, 99.0),
    )
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let smoke = args.has_flag("smoke") || kway::figures::quick_mode();
    let duration = Duration::from_millis(if smoke { 150 } else { 1000 });
    let capacity: usize = if smoke { 1 << 12 } else { 1 << 14 };
    let value_budget: usize = if smoke { 1 << 22 } else { 1 << 26 };
    let threads = if smoke { 2 } else { 4 };
    let working_set = (capacity / 2) as u64;
    let dists: &[ValueDist] = if smoke {
        &[ValueDist::Fixed { len: 64 }, ValueDist::Zipf { max: 4096 }]
    } else {
        &[
            ValueDist::Fixed { len: 64 },
            ValueDist::Fixed { len: 1024 },
            ValueDist::Uniform { max: 4096 },
            ValueDist::Zipf { max: 16384 },
        ]
    };

    println!(
        "== slab byte values: capacity {capacity}, budget {value_budget}B, \
         threads {threads}, duration {duration:?} =="
    );
    println!(
        "{:>10} {:>14} {:>8} {:>9} {:>7} {:>9} {:>9} {:>12}",
        "impl", "values", "threads", "Mops/s", "hit", "p50_ns", "p99_ns", "value_bytes"
    );

    let mut rows: Vec<Row> = Vec::new();
    for variant in Variant::ALL {
        for &dist in dists {
            let cache: Arc<dyn Cache> =
                Arc::from(build_with_values(variant, capacity, 8, Policy::Lru, value_budget));
            let (ops, mops, hit_ratio, p50_ns, p99_ns) =
                run_point(&cache, dist, working_set, threads, duration);
            let value_bytes = cache.value_bytes();
            println!(
                "{:>10} {:>14} {:>8} {:>9.3} {:>7.3} {:>9} {:>9} {:>12}",
                variant.name(),
                dist.name(),
                threads,
                mops,
                hit_ratio,
                p50_ns,
                p99_ns,
                value_bytes
            );
            rows.push(Row {
                impl_name: variant.name(),
                dist,
                threads,
                ops,
                mops,
                hit_ratio,
                p50_ns,
                p99_ns,
                value_bytes,
            });
        }
    }

    if args.has_flag("json") && !rows.is_empty() {
        let json_rows: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::Object(vec![
                    ("impl".to_string(), Json::Str(r.impl_name.to_string())),
                    ("value_dist".to_string(), Json::Str(r.dist.name())),
                    ("threads".to_string(), Json::Int(r.threads as i64)),
                    ("ops".to_string(), Json::Int(r.ops as i64)),
                    ("mops".to_string(), Json::Float(r.mops)),
                    ("hit_ratio".to_string(), Json::Float(r.hit_ratio)),
                    ("p50_ns".to_string(), Json::Int(r.p50_ns as i64)),
                    ("p99_ns".to_string(), Json::Int(r.p99_ns as i64)),
                    ("value_bytes".to_string(), Json::Int(r.value_bytes as i64)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("schema".to_string(), Json::Str(SLAB_SCHEMA.to_string())),
            ("smoke".to_string(), Json::Bool(smoke)),
            ("seed".to_string(), Json::Int(SEED as i64)),
            ("capacity".to_string(), Json::Int(capacity as i64)),
            ("value_budget".to_string(), Json::Int(value_budget as i64)),
            ("duration_ms".to_string(), Json::Int(duration.as_millis() as i64)),
            ("provenance".to_string(), Json::Str("measured".to_string())),
            ("results".to_string(), Json::Array(json_rows)),
        ]);
        if let Err(e) = check_slab_schema(&doc) {
            eprintln!("refusing to write malformed BENCH_slab.json: {e:#}");
        } else {
            match std::fs::write("BENCH_slab.json", format!("{doc}\n")) {
                Ok(()) => println!("\nwrote BENCH_slab.json"),
                Err(e) => eprintln!("writing BENCH_slab.json: {e}"),
            }
        }
    }
}
