//! Benchmarks the Layers 1–2 artifacts through the rust PJRT runtime:
//! batched victim selection, sketch ops, and the set-parallel cache
//! simulator — plus the native rust simulator for reference.
//!
//! ```bash
//! make artifacts && cargo bench --bench xla_runtime
//! ```

use kway::runtime::{lit_i32, XlaRuntime};
use kway::sim::xla::{NativeSetSim, XlaSim};
use kway::trace::paper;
use kway::util::clock::Stopwatch;
use kway::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = kway::figures::quick_mode();
    let dir = std::env::var("KWAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = XlaRuntime::load(&dir)?;
    println!("platform={} producer={}", rt.platform(), rt.manifest().producer);

    println!("\n== batched policy evaluation (per executable execute()) ==");
    for name in [
        "victim_select_lru_k4",
        "victim_select_lru_k8",
        "victim_select_lru_k16",
        "set_probe_k8",
    ] {
        let spec = rt.manifest().entry(name).unwrap();
        let b = spec.require("batch")? as usize;
        let k = spec.require("k")? as usize;
        let mut rng = Rng::new(1);
        let counters: Vec<i32> = (0..b * k).map(|_| rng.below(1 << 30) as i32).collect();
        let lit = lit_i32(&counters, &[b as i64, k as i64])?;
        let args: Vec<xla::Literal> = if name == "set_probe_k8" {
            let probes: Vec<i32> = (0..b).map(|_| 1 + rng.below(40) as i32).collect();
            vec![lit, lit_i32(&probes, &[b as i64])?]
        } else {
            vec![lit]
        };
        let iters = if quick { 5 } else { 30 };
        let sw = Stopwatch::start();
        for _ in 0..iters {
            rt.execute(name, &args)?;
        }
        let secs = sw.elapsed_secs() / iters as f64;
        println!(
            "{name:28} {:8.2} us/batch  {:8.1} Msets/s",
            secs * 1e6,
            b as f64 / secs / 1e6
        );
    }

    println!("\n== cache_sim: XLA artifact vs native rust simulator ==");
    let sim = XlaSim::new(&rt, "cache_sim_k8")?;
    let len = if quick { 4 * sim.chunk } else { 32 * sim.chunk };
    for trace_name in ["oltp", "wiki_a"] {
        let trace = paper::build(trace_name, len, 7).unwrap();
        let sw = Stopwatch::start();
        let xla_stats = sim.run(&trace)?;
        let xla_secs = sw.elapsed_secs();
        let mut native = NativeSetSim::new(sim.num_sets, sim.ways);
        let sw = Stopwatch::start();
        let native_stats = native.run(&trace.keys);
        let native_secs = sw.elapsed_secs();
        assert_eq!(xla_stats.hits, native_stats.hits, "backend divergence");
        println!(
            "{trace_name:8} XLA {:7.2} Mkeys/s | native {:7.2} Mkeys/s | hits match ({})",
            xla_stats.accesses as f64 / xla_secs / 1e6,
            native_stats.accesses as f64 / native_secs / 1e6,
            xla_stats.hits,
        );
    }
    Ok(())
}
