//! The batched access path (figB* series, the batching extension): Mops/s
//! and per-batch p50/p99 latency for the three k-way variants at the
//! batch sizes in `kway::figures::BATCHED_FIGURES`, against the scalar
//! one-by-one path over the *same* resident-set key distribution.
//!
//! ```bash
//! cargo bench --bench batched
//! KWAY_BENCH_QUICK=1 cargo bench --bench batched
//! ```
//!
//! What to look for (DESIGN.md §Batched access path): the batched rows
//! amortize one hash pass and one virtual call over the whole chunk and
//! software-prefetch each set line before the first probe, so from batch
//! ≈ 8 upward Mops/s should exceed the 1-by-1 row — most visibly for
//! KW-WFSC, whose SoA layout means one prefetched fingerprint line covers
//! the entire probe. The trade is per-call latency: a batch of 128 takes
//! longer than a single get, which p50/p99 (per get_batch call) make
//! explicit.

use kway::figures::{quick_mode, BATCHED_FIGURES};
use kway::policy::Policy;
use kway::throughput::{impl_factory, measure, RunConfig, Workload};
use kway::tinylfu::AdmissionMode;
use std::time::Duration;

fn main() {
    let quick = quick_mode();
    let capacity: usize = if quick { 1 << 14 } else { 1 << 18 };
    let working_set = (capacity / 2) as u64;
    let threads_list: Vec<usize> = if quick { vec![2] } else { vec![1, 4] };
    let duration = Duration::from_millis(if quick { 100 } else { 300 });
    let repeats = if quick { 2 } else { 3 };
    let impls = ["KW-WFA", "KW-WFSC", "KW-LS"];

    for &threads in &threads_list {
        println!(
            "\n==== batched get — capacity 2^{} working set {} threads {} ====",
            capacity.trailing_zeros(),
            working_set,
            threads
        );
        println!(
            "{:14} {:>8} {:>10} {:>12} {:>12} {:>8}",
            "impl", "batch", "Mops/s", "p50(ns)", "p99(ns)", "hit"
        );
        for name in impls {
            let factory =
                impl_factory(name, capacity, threads, Policy::Lru, AdmissionMode::None).unwrap();
            let cfg = RunConfig { threads, duration, repeats, seed: 42, ..Default::default() };
            // Scalar baseline: same keys, one get per call.
            let base = measure(&*factory, &Workload::AllHit { working_set }, &cfg);
            println!(
                "{:14} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
                name,
                "1-by-1",
                base.mops.mean(),
                base.lat_p50_ns,
                base.lat_p99_ns,
                base.hit_ratio
            );
            for fig in BATCHED_FIGURES {
                let r = measure(
                    &*factory,
                    &Workload::Batched { working_set, batch: fig.batch },
                    &cfg,
                );
                println!(
                    "{:14} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
                    name,
                    fig.batch,
                    r.mops.mean(),
                    r.lat_p50_ns,
                    r.lat_p99_ns,
                    r.hit_ratio
                );
            }
        }
    }
    println!(
        "\nReading: Mops/s counts every key of a batch as one op; p50/p99\n\
         for batched rows are per get_batch call (the whole batch), for the\n\
         1-by-1 row per single get. Batch sizes come from BATCHED_FIGURES\n\
         (figB1/figB8/figB32/figB128)."
    );
}
