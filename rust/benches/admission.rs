//! The admission extension (figT* series): trace-replay throughput for
//! the three k-way variants with and without TinyLFU admission, against
//! the Caffeine-like baseline (whose W-TinyLFU admission is built in),
//! across thread counts.
//!
//! ```bash
//! cargo bench --bench admission
//! KWAY_BENCH_QUICK=1 cargo bench --bench admission
//! cargo bench --bench admission -- --figure figT1
//! ```
//!
//! What to look for (DESIGN.md §Admission): the `+TLFU` rows pay one
//! sketch record per access plus one victim preview per insert, so at
//! 100%-hit-style traces the overhead is a few relaxed atomics; on
//! insert-heavy traces admission *refuses* most one-hit wonders, turning
//! expensive replacements into cheap drops — throughput at equal or
//! better hit ratio. The Caffeine row shows what a write-buffered design
//! pays for the same filter.

use kway::figures::{quick_mode, ADMISSION_FIGURES};
use kway::throughput::{impl_factory, measure, RunConfig, Workload};
use kway::tinylfu::AdmissionMode;
use kway::trace::paper;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--figure")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = quick_mode();
    let threads: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4, 8, 16] };
    let duration = Duration::from_millis(if quick { 100 } else { 300 });
    let repeats = if quick { 2 } else { 3 };
    let len = if quick { 100_000 } else { 500_000 };
    let kway_impls = ["KW-WFA", "KW-WFSC", "KW-LS"];

    for fig in ADMISSION_FIGURES {
        if let Some(ref f) = only {
            if f != fig.id {
                continue;
            }
        }
        let trace = Arc::new(paper::build(fig.trace, len, 42).expect("trace model"));
        println!(
            "\n==== {} — trace {} cache 2^{} policy {} ± TLFU admission — Mops/s ====",
            fig.id,
            fig.trace,
            fig.capacity.trailing_zeros(),
            fig.policy.name(),
        );
        print!("{:20}", "impl\\threads");
        for t in &threads {
            print!(" {t:>9}");
        }
        println!("   hit-ratio");
        for name in kway_impls {
            for admission in AdmissionMode::ALL {
                let label = format!("{name}{}", admission.label());
                print!("{label:20}");
                let mut last_hit = 0.0;
                for &t in &threads {
                    let factory =
                        impl_factory(name, fig.capacity, t, fig.policy, admission).unwrap();
                    let cfg = RunConfig {
                        threads: t,
                        duration,
                        repeats,
                        seed: 42,
                        ..Default::default()
                    };
                    let r = measure(&*factory, &Workload::TraceReplay(trace.clone()), &cfg);
                    last_hit = r.hit_ratio;
                    print!(" {:9.2}", r.mops.mean());
                }
                println!("   {last_hit:9.3}");
            }
        }
        // Caffeine-like runs bare: its W-TinyLFU admission is internal,
        // so it is the "product with admission" reference line.
        print!("{:20}", "Caffeine");
        let mut last_hit = 0.0;
        for &t in &threads {
            let factory =
                impl_factory("Caffeine", fig.capacity, t, fig.policy, AdmissionMode::None)
                    .unwrap();
            let cfg = RunConfig { threads: t, duration, repeats, seed: 42, ..Default::default() };
            let r = measure(&*factory, &Workload::TraceReplay(trace.clone()), &cfg);
            last_hit = r.hit_ratio;
            print!(" {:9.2}", r.mops.mean());
        }
        println!("   {last_hit:9.3}");
    }
}
