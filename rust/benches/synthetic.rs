//! Regenerates the paper's synthetic throughput figures (Figures 27–30):
//! 100% miss, 100% hit, 95% hit and 90% hit mixes at cache size 2^21,
//! Mops/s vs threads for every implementation.
//!
//! ```bash
//! cargo bench --bench synthetic
//! cargo bench --bench synthetic -- --figure fig29
//! ```
//!
//! The paper's conclusion to reproduce: Caffeine wins 100% hit, Guava
//! wins ~95%, and below ~90% hit the K-Way designs take over, with
//! KW throughput nearly identical across mixes (they always scan the
//! set) while the products swing widely.

use kway::figures::{quick_mode, SYNTHETIC_FIGURES};
use kway::policy::Policy;
use kway::throughput::{impl_factory, measure, RunConfig, Workload, IMPLS};
use kway::tinylfu::AdmissionMode;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--figure")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = quick_mode();
    // The paper uses 2^21; warming that per (impl × threads × repeat) run
    // dominates wall-clock on one core, so the default here is 2^18 and
    // the full size is opt-in via KWAY_SYNTH_FULL=1.
    let capacity: usize = if std::env::var("KWAY_SYNTH_FULL").is_ok() {
        1 << 21
    } else if quick {
        1 << 14
    } else {
        1 << 18
    };
    let working_set = (capacity / 2) as u64;
    let threads: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
    let duration = Duration::from_millis(if quick { 100 } else { 300 });
    let repeats = if quick { 2 } else { 3 };

    for fig in SYNTHETIC_FIGURES {
        if let Some(ref f) = only {
            if f != fig.id {
                continue;
            }
        }
        let workload = if fig.all_miss {
            Workload::AllMiss
        } else {
            match fig.gets_per_put {
                None => Workload::AllHit { working_set },
                Some(g) => Workload::HitRatio { working_set, gets_per_put: g },
            }
        };
        println!(
            "\n==== {} — synthetic {} (cache 2^{}) — Mops/s ====",
            fig.id,
            fig.label,
            capacity.trailing_zeros()
        );
        print!("{:14}", "impl\\threads");
        for t in &threads {
            print!(" {t:>9}");
        }
        println!();
        for name in IMPLS {
            print!("{name:14}");
            for &t in &threads {
                let factory =
                    impl_factory(name, capacity, t, Policy::Lru, AdmissionMode::None).unwrap();
                let cfg =
                    RunConfig { threads: t, duration, repeats, seed: 42, ..Default::default() };
                let r = measure(&*factory, &workload, &cfg);
                print!(" {:9.2}", r.mops.mean());
            }
            println!();
        }
    }
}
