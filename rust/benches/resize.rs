//! Online elastic resizing (figR* series, the resize extension):
//! throughput dip and hit-ratio recovery across grow and shrink
//! transitions, for the three k-way variants and the sampled baseline
//! (segment re-budgeting), each against a *twin* cache built directly at
//! the target capacity.
//!
//! ```bash
//! cargo bench --bench resize
//! KWAY_BENCH_QUICK=1 cargo bench --bench resize
//! ```
//!
//! What to look for (DESIGN.md §Elastic resizing): the `during` column
//! is the serving-path cost of the migration — the k-way variants keep
//! serving because the move is per-set and claims lines with the same
//! CAS/lock protocols as eviction, so the dip should be a fraction, not
//! a stall. The figR2x acceptance criterion is `hitR ≈ twin`: after a
//! 2× grow refills, the steady-state hit ratio must match a cache built
//! at 2× outright. The figRhalf row shows the shrink direction: eviction
//! by policy order down to the smaller geometry, with the twin as the
//! honest post-shrink ceiling. `requested` vs `effective` capacities are
//! printed per implementation because power-of-two set rounding can
//! inflate the k-way figure up to ~2×.

use kway::figures::{quick_mode, RESIZE_FIGURES};
use kway::policy::Policy;
use kway::throughput::{impl_factory, measure_resize};
use kway::tinylfu::AdmissionMode;
use std::time::Duration;

fn main() {
    let quick = quick_mode();
    let threads = if quick { 2 } else { 4 };
    let phase = Duration::from_millis(if quick { 80 } else { 300 });
    let scale = if quick { 8 } else { 1 }; // quick mode shrinks capacities
    let impls = ["KW-WFA", "KW-WFSC", "KW-LS", "sampled"];

    for fig in RESIZE_FIGURES {
        let from = (fig.from_capacity / scale).max(1024);
        let to = (fig.to_capacity / scale).max(1024 * fig.to_capacity / fig.from_capacity);
        let working_set = (fig.working_set / scale as u64).max(1536);
        println!(
            "\n==== {}: resize {} -> {} working set {} threads {} ====",
            fig.id, from, to, working_set, threads
        );
        println!(
            "{:10} {:14} {:>9} {:>9} {:>9} {:>11} {:>7} {:>7} {:>7} {:>7}  {}",
            "figure",
            "impl",
            "before",
            "during",
            "after",
            "migrate(ms)",
            "hit0",
            "hitM",
            "hitR",
            "twin",
            "req->eff"
        );
        for name in impls {
            let factory =
                impl_factory(name, from, threads, Policy::Lru, AdmissionMode::None).unwrap();
            let twin = impl_factory(name, to, threads, Policy::Lru, AdmissionMode::None).unwrap();
            let probe = twin();
            let (requested, effective) = (probe.requested_capacity(), probe.capacity());
            let r = measure_resize(&*factory, &*twin, to, working_set, threads, phase, 42);
            println!(
                "{:10} {:14} {:>9.2} {:>9.2} {:>9.2} {:>11.1} {:>7.3} {:>7.3} {:>7.3} {:>7.3}  {}->{}",
                fig.id,
                name,
                r.before.mops,
                r.during.mops,
                r.after.mops,
                r.migrate_ms,
                r.before.hit_ratio,
                r.during.hit_ratio,
                r.after.hit_ratio,
                r.twin_hit,
                requested,
                effective
            );
        }
    }
    println!(
        "\nReading: before/during/after are Mops/s phases of one online\n\
         resize; hit0/hitM/hitR the matching hit ratios; twin is a cache\n\
         built at the target outright. Acceptance (figR2x): hitR recovers\n\
         to twin after the grow. The during-phase dip is what the\n\
         migration costs the serving path; migrate(ms) how long the split\n\
         watermark took to cover every source set."
    );
}
