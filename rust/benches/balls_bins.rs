//! Theorem 4.1 (§4): the Chernoff/union bound on the probability that a
//! k-way cache of size C' = 2C cannot hold C desired items, against a
//! Monte-Carlo balls-into-bins measurement — including the paper's two
//! worked examples (64-way/200k/100k and 128-way/2M/1M).
//!
//! ```bash
//! cargo bench --bench balls_bins
//! ```

use kway::analysis::{expected_max_load, monte_carlo_overflow, theorem41_bound};

fn main() {
    let quick = kway::figures::quick_mode();
    let trials = if quick { 100 } else { 1000 };
    println!("# Theorem 4.1 bound vs Monte-Carlo ({trials} trials per row)");
    println!(
        "{:>10} {:>10} {:>6} {:>12} {:>12} {:>14}",
        "C", "C'", "k", "bound", "empirical", "E[max load]"
    );
    let rows: &[(u64, u64, u64)] = &[
        (1024, 2048, 8),
        (2048, 4096, 16),
        (4096, 8192, 32),
        (4096, 8192, 64),
        (100_000, 200_000, 64),   // the paper's ">99%" example
        (1_000_000, 2_000_000, 128), // the paper's ">99.999%" example
    ];
    for &(c, cp, k) in rows {
        let bound = theorem41_bound(cp, k);
        let t = if cp > 500_000 && quick { trials / 10 } else { trials };
        let mc = monte_carlo_overflow(c, cp, k, t, 7);
        println!(
            "{c:>10} {cp:>10} {k:>6} {bound:>12.3e} {mc:>12.4} {:>14.2}",
            expected_max_load(c, cp / k)
        );
    }
    println!(
        "\nReading: `bound` is Theorem 4.1's (loose) upper bound on overflow\n\
         probability; `empirical` is the measured fraction of trials where\n\
         some set received more than k of the C desired items. The paper's\n\
         prose examples quote the empirical rate (<1%), which the\n\
         Monte-Carlo confirms; the bound is loose for small k, as §4 notes."
    );
}
