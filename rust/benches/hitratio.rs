//! Regenerates the paper's hit-ratio figures (Figures 4–13).
//!
//! For each figure: subfigure (a) LRU, (b) LFU + TinyLFU admission,
//! (c) products, (d) the figure's extra policy — each as hit ratio vs
//! cache size with the k-way / sampled / fully-associative series.
//!
//! ```bash
//! cargo bench --bench hitratio                 # full pass
//! KWAY_BENCH_QUICK=1 cargo bench --bench hitratio
//! cargo bench --bench hitratio -- --figure fig9
//! ```

use kway::figures::{quick_mode, ExtraSeries, HITRATIO_FIGURES};
use kway::sim;
use kway::trace::paper;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--figure")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let quick = quick_mode();
    let len = if quick { 150_000 } else { 1_000_000 };

    for fig in HITRATIO_FIGURES {
        if let Some(ref f) = only {
            if f != fig.id {
                continue;
            }
        }
        let trace = paper::build(fig.trace, len, 42).expect("trace model");
        println!(
            "\n==== {} — trace {} (accesses {}, unique {}) ====",
            fig.id,
            fig.trace,
            trace.len(),
            trace.unique_keys()
        );
        let sizes: Vec<usize> =
            if quick { vec![fig.sizes[1]] } else { fig.sizes.to_vec() };

        let mut sections: Vec<(&str, Vec<sim::Config>)> = vec![
            ("(a) LRU", sim::lru_series()),
            ("(b) LFU+TinyLFU", sim::lfu_tlfu_series()),
            ("(c) products", sim::products_series(8)),
        ];
        match fig.extra {
            ExtraSeries::Hyperbolic => {
                sections.push(("(d) Hyperbolic", sim::hyperbolic_series(false)))
            }
            ExtraSeries::HyperbolicTlfu => {
                sections.push(("(d) Hyperbolic+TinyLFU", sim::hyperbolic_series(true)))
            }
            ExtraSeries::None => {}
        }

        for (title, configs) in sections {
            println!("-- {title} --");
            print!("{:34}", "config\\size");
            for s in &sizes {
                print!(" {s:>8}");
            }
            println!();
            let per_size: Vec<Vec<sim::Row>> = sizes
                .iter()
                .map(|&s| sim::sweep(&trace, s, &configs, 1))
                .collect();
            for (i, cfg) in configs.iter().enumerate() {
                print!("{:34}", cfg.label());
                for rows in &per_size {
                    print!(" {:8.4}", rows[i].hit_ratio);
                }
                println!();
            }
        }
    }
}
