//! XLA-backed k-way hit-ratio simulation.
//!
//! Layers 1–2 express the k-way cache as a *set-parallel* computation: the
//! whole cache state is a `[num_sets, k]` pair of (fingerprint, counter)
//! arrays, and a `lax.scan` folds a chunk of accesses over it (the Pallas
//! kernels implement the probe and victim-select scans). `aot.py` lowers
//! one module per (policy, k, num_sets, chunk) combination to HLO text;
//! this module feeds trace chunks through the compiled executable and
//! accumulates hit counts.
//!
//! The native simulator and this path must agree *exactly* — both
//! implement LRU/LFU over the same geometry with the same set hash — which
//! is checked by `rust/tests/xla_parity.rs`.

use crate::runtime::{lit_i32, to_vec, XlaRuntime};
use crate::sim::HitStats;
use crate::trace::Trace;
use crate::util::hash;
use anyhow::{anyhow, bail, Result};

/// A loaded cache_sim entry point plus its static parameters.
pub struct XlaSim<'rt> {
    runtime: &'rt XlaRuntime,
    entry: String,
    /// Number of sets baked into the artifact.
    pub num_sets: usize,
    /// Ways per set baked into the artifact.
    pub ways: usize,
    /// Keys consumed per execute call.
    pub chunk: usize,
}

impl<'rt> XlaSim<'rt> {
    /// Bind to a `cache_sim` artifact by entry name.
    pub fn new(runtime: &'rt XlaRuntime, entry: &str) -> Result<Self> {
        let spec = runtime
            .manifest()
            .entry(entry)
            .ok_or_else(|| anyhow!("no artifact entry {entry:?}"))?;
        if spec.kind != "cache_sim" {
            bail!("entry {entry:?} is kind {:?}, want cache_sim", spec.kind);
        }
        Ok(Self {
            runtime,
            entry: entry.to_string(),
            num_sets: spec.require("num_sets")? as usize,
            ways: spec.require("k")? as usize,
            chunk: spec.require("chunk")? as usize,
        })
    }

    /// Capacity of the simulated cache.
    pub fn capacity(&self) -> usize {
        self.num_sets * self.ways
    }

    /// Simulate a trace; returns hit statistics. The trace is processed in
    /// fixed-size chunks (the tail is padded with a sentinel the module
    /// ignores); cache state is carried between chunks on the host.
    pub fn run(&self, trace: &Trace) -> Result<HitStats> {
        let n = self.num_sets * self.ways;
        // State: fingerprints (0 = empty) and counters, both i32 on the
        // XLA side (large enough for fingerprint-in-set uniqueness and
        // for logical time in chunks we process).
        let mut fps = vec![0i32; n];
        let mut counters = vec![0i32; n];
        let mut time = 0i32;
        let mut hits = 0u64;
        let mut accesses = 0u64;

        for chunk in trace.keys.chunks(self.chunk) {
            let mut set_idx = vec![0i32; self.chunk];
            let mut key_fp = vec![0i32; self.chunk];
            let mut valid = vec![0i32; self.chunk];
            for (i, &key) in chunk.iter().enumerate() {
                set_idx[i] = (hash::set_index(key, self.num_sets)) as i32;
                key_fp[i] = fp31(key);
                valid[i] = 1;
            }
            accesses += chunk.len() as u64;

            let out = self.runtime.execute(
                &self.entry,
                &[
                    lit_i32(&fps, &[self.num_sets as i64, self.ways as i64])?,
                    lit_i32(&counters, &[self.num_sets as i64, self.ways as i64])?,
                    xla::Literal::scalar(time),
                    lit_i32(&set_idx, &[self.chunk as i64])?,
                    lit_i32(&key_fp, &[self.chunk as i64])?,
                    lit_i32(&valid, &[self.chunk as i64])?,
                ],
            )?;
            if out.len() != 4 {
                bail!("cache_sim returned {} outputs, want 4", out.len());
            }
            fps = to_vec::<i32>(&out[0])?;
            counters = to_vec::<i32>(&out[1])?;
            time = out[2].to_vec::<i32>()?[0];
            hits += to_vec::<i32>(&out[3])?[0] as u64;
        }
        Ok(HitStats { accesses, hits })
    }
}

/// Set-parallel XLA simulator (the `cache_sim_setpar` artifact): the host
/// groups accesses by set and ships `[L, S]` rounds; each XLA scan step
/// applies one access to every set simultaneously. Reordering across sets
/// preserves every per-set outcome, so hit totals match [`XlaSim`] and
/// [`NativeSetSim`] exactly (asserted in `rust/tests/xla_parity.rs`).
pub struct SetParSim<'rt> {
    runtime: &'rt XlaRuntime,
    entry: String,
    /// Number of sets baked into the artifact.
    pub num_sets: usize,
    /// Ways per set baked into the artifact.
    pub ways: usize,
    /// Rounds per execute (the L dimension).
    pub steps: usize,
}

impl<'rt> SetParSim<'rt> {
    /// Bind to a `cache_sim_setpar` artifact by entry name.
    pub fn new(runtime: &'rt XlaRuntime, entry: &str) -> Result<Self> {
        let spec = runtime
            .manifest()
            .entry(entry)
            .ok_or_else(|| anyhow!("no artifact entry {entry:?}"))?;
        if spec.kind != "cache_sim_setpar" {
            bail!("entry {entry:?} is kind {:?}, want cache_sim_setpar", spec.kind);
        }
        Ok(Self {
            runtime,
            entry: entry.to_string(),
            num_sets: spec.require("num_sets")? as usize,
            ways: spec.require("k")? as usize,
            steps: spec.require("steps")? as usize,
        })
    }

    /// Total slots (= num_sets x ways).
    pub fn capacity(&self) -> usize {
        self.num_sets * self.ways
    }

    /// Simulate a trace. Keys are packed greedily into per-set columns; a
    /// batch is flushed whenever some set's column fills.
    pub fn run(&self, trace: &Trace) -> Result<HitStats> {
        let (s, l) = (self.num_sets, self.steps);
        let n = s * self.ways;
        let mut fps = vec![0i32; n];
        let mut counters = vec![0i32; n];
        let mut time = 0i32;
        let mut hits = 0u64;

        let mut cols: Vec<Vec<i32>> = vec![Vec::with_capacity(l); s];
        let mut queued = 0usize;

        let flush = |cols: &mut Vec<Vec<i32>>,
                         queued: &mut usize,
                         fps: &mut Vec<i32>,
                         counters: &mut Vec<i32>,
                         time: &mut i32,
                         hits: &mut u64|
         -> Result<()> {
            if *queued == 0 {
                return Ok(());
            }
            let mut probe = vec![0i32; l * s];
            let mut valid = vec![0i32; l * s];
            for (set, col) in cols.iter_mut().enumerate() {
                for (round, &fp) in col.iter().enumerate() {
                    probe[round * s + set] = fp;
                    valid[round * s + set] = 1;
                }
                col.clear();
            }
            *queued = 0;
            let out = self.runtime.execute(
                &self.entry,
                &[
                    lit_i32(fps, &[s as i64, self.ways as i64])?,
                    lit_i32(counters, &[s as i64, self.ways as i64])?,
                    xla::Literal::scalar(*time),
                    lit_i32(&probe, &[l as i64, s as i64])?,
                    lit_i32(&valid, &[l as i64, s as i64])?,
                ],
            )?;
            if out.len() != 4 {
                bail!("cache_sim_setpar returned {} outputs, want 4", out.len());
            }
            *fps = to_vec::<i32>(&out[0])?;
            *counters = to_vec::<i32>(&out[1])?;
            *time = out[2].to_vec::<i32>()?[0];
            *hits += to_vec::<i32>(&out[3])?[0] as u64;
            Ok(())
        };

        // Packing. Two tricks keep device utilization high under Zipf
        // skew (where the hottest set otherwise serializes everything —
        // the set-parallel engine's Amdahl bound):
        //
        // * run compression — an access whose fingerprint equals the
        //   previous access *of the same set* is a guaranteed hit (the
        //   previous access made it resident); it is counted on the host
        //   and never shipped. This absorbs hot-key bursts entirely.
        // * spill backlog — keys whose set-column is full are deferred to
        //   the next batch (per-set order is preserved: a spilled key is
        //   later in the trace than everything in its column, and it is
        //   replayed before newer input).
        let target = (l * s) / 2;
        let spill_budget = l * s;
        let mut backlog: Vec<u64> = Vec::new();
        let mut input = trace.keys.iter().copied();
        let mut exhausted = false;
        // Last fingerprint seen per set (column-order), for run compression.
        let mut last_fp = vec![0i32; s];
        while !exhausted || !backlog.is_empty() {
            let mut next_backlog = Vec::new();
            let mut push = |key: u64,
                            cols: &mut Vec<Vec<i32>>,
                            queued: &mut usize,
                            next_backlog: &mut Vec<u64>,
                            last_fp: &mut Vec<i32>,
                            hits: &mut u64| {
                let set = hash::set_index(key, s);
                let fp = fp31(key);
                if last_fp[set] == fp {
                    // Guaranteed hit: same fingerprint as the immediately
                    // preceding access to this set.
                    *hits += 1;
                    return;
                }
                if cols[set].len() == l {
                    next_backlog.push(key);
                    // Later duplicates compress against the spilled key
                    // too: they are guaranteed hits once it lands.
                    last_fp[set] = fp;
                } else {
                    cols[set].push(fp);
                    last_fp[set] = fp;
                    *queued += 1;
                }
            };
            for key in std::mem::take(&mut backlog) {
                push(key, &mut cols, &mut queued, &mut next_backlog, &mut last_fp, &mut hits);
            }
            while queued < target && next_backlog.len() < spill_budget {
                let Some(key) = input.next() else {
                    exhausted = true;
                    break;
                };
                push(key, &mut cols, &mut queued, &mut next_backlog, &mut last_fp, &mut hits);
            }
            flush(&mut cols, &mut queued, &mut fps, &mut counters, &mut time, &mut hits)?;
            last_fp.fill(0);
            backlog = next_backlog;
        }
        Ok(HitStats { accesses: trace.keys.len() as u64, hits })
    }
}

/// 31-bit non-zero fingerprint for the XLA i32 state (the native u64
/// fingerprint truncated into positive i32 space; collisions within a set
/// are ~k/2^31 and affect both backends identically since the parity test
/// drives the native geometry with the same function).
pub fn fp31(key: u64) -> i32 {
    let f = (hash::fingerprint(key) >> 33) as i32;
    if f == 0 {
        1
    } else {
        f
    }
}

/// A native reference simulator that matches the XLA module's semantics
/// bit-for-bit (i32 fingerprints, LRU counter = arrival index, ties to
/// the lowest way). Used for parity testing and as the fast path when the
/// runtime is not loaded.
pub struct NativeSetSim {
    /// Number of sets.
    pub num_sets: usize,
    /// Ways per set.
    pub ways: usize,
    fps: Vec<i32>,
    counters: Vec<i32>,
    time: i32,
}

impl NativeSetSim {
    /// A fresh, empty simulator of the given geometry.
    pub fn new(num_sets: usize, ways: usize) -> Self {
        Self {
            num_sets,
            ways,
            fps: vec![0; num_sets * ways],
            counters: vec![0; num_sets * ways],
            time: 0,
        }
    }

    /// Process one access; returns hit.
    pub fn access(&mut self, key: u64) -> bool {
        let set = hash::set_index(key, self.num_sets);
        let fp = fp31(key);
        let base = set * self.ways;
        self.time += 1;
        for w in 0..self.ways {
            if self.fps[base + w] == fp {
                self.counters[base + w] = self.time;
                return true;
            }
        }
        // Miss: insert over empty way or LRU victim (min counter; empty
        // ways have counter 0 which is always minimal).
        let mut victim = 0;
        for w in 1..self.ways {
            if self.counters[base + w] < self.counters[base + victim] {
                victim = w;
            }
        }
        self.fps[base + victim] = fp;
        self.counters[base + victim] = self.time;
        false
    }

    /// Replay `keys` and count hits.
    pub fn run(&mut self, keys: &[u64]) -> HitStats {
        let mut hits = 0u64;
        for &k in keys {
            if self.access(k) {
                hits += 1;
            }
        }
        HitStats { accesses: keys.len() as u64, hits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp31_nonzero_positive() {
        for k in 0..100_000u64 {
            let f = fp31(k);
            assert!(f > 0, "fp31({k}) = {f}");
        }
    }

    #[test]
    fn native_set_sim_behaves_like_lru_kway() {
        // Against the production KwWfsc cache with LRU: same geometry,
        // same hash -> same hit decisions.
        use crate::policy::Policy;
        use crate::Cache;
        let num_sets = 64;
        let ways = 8;
        let mut sim = NativeSetSim::new(num_sets, ways);
        let kw = crate::kway::KwWfsc::new(num_sets * ways, ways, Policy::Lru);
        let mut rng = crate::util::rng::Rng::new(42);
        let mut agree = 0;
        let total = 20_000;
        for _ in 0..total {
            let key = rng.below(2048);
            let sim_hit = sim.access(key);
            let kw_hit = kw.get(key).is_some();
            if !kw_hit {
                kw.put(key, key);
            }
            if sim_hit == kw_hit {
                agree += 1;
            }
        }
        // Identical geometry and policy; tiny divergence can only come
        // from fp31 collisions (~0). Require exact agreement.
        assert_eq!(agree, total, "native set sim diverged from KwWfsc/LRU");
    }
}
