//! Hit-ratio simulation: the engine behind the paper's Figures 4–13.
//!
//! The methodology follows §5.1.2: for each trace element, perform a
//! read; on a miss, write the element. [`Config`] enumerates every cache
//! configuration the figures compare — k-way at associativities
//! 4…128, sampled eviction at the same sample sizes, the fully
//! associative policies, the product baselines, each optionally behind
//! TinyLFU admission — and [`sweep`] produces the figure's series.
//!
//! `xla.rs` runs the same k-way simulation through the AOT-compiled
//! set-parallel XLA artifact (Layers 1–2) and is cross-validated against
//! the native path in `rust/tests/xla_parity.rs`.

pub mod xla;

use crate::fully::{FifoQueue, HyperbolicFull, LfuOrdered, LruList, RandomFull, Sampled};
use crate::kway::{KwLs, KwWfa, KwWfsc, Variant};
use crate::policy::Policy;
use crate::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
use crate::tinylfu::TlfuSim;
use crate::trace::Trace;
use crate::{Cache, SimCache};

/// Outcome of a simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HitStats {
    /// Total accesses simulated.
    pub accesses: u64,
    /// Accesses that hit.
    pub hits: u64,
}

impl HitStats {
    /// hits / accesses (0 when nothing was accessed).
    pub fn ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// Drive one cache over a key sequence with the paper's read-then-write
/// methodology.
pub fn run(cache: &mut dyn SimCache, keys: &[u64]) -> HitStats {
    let mut hits = 0u64;
    for &key in keys {
        if cache.sim_get(key) {
            hits += 1;
        } else {
            cache.sim_put(key);
        }
    }
    HitStats { accesses: keys.len() as u64, hits }
}

/// A cache configuration in the evaluation space.
#[derive(Debug, Clone, PartialEq)]
pub enum Config {
    /// k-way set-associative (any of the three concurrency variants —
    /// they simulate identically single-threaded; WFSC is the default).
    KWay {
        /// Which concurrency flavour to construct.
        variant: Variant,
        /// Ways per set.
        ways: usize,
        /// Eviction policy.
        policy: Policy,
        /// Layer TinyLFU admission over the cache.
        tlfu: bool,
    },
    /// Redis-style sampled eviction.
    Sampled {
        /// Entries drawn per eviction.
        sample: usize,
        /// Eviction policy.
        policy: Policy,
        /// Layer TinyLFU admission over the cache.
        tlfu: bool,
    },
    /// Exact fully-associative LRU (linked list).
    FullLru {
        /// Layer TinyLFU admission over the cache.
        tlfu: bool,
    },
    /// Exact fully-associative LFU.
    FullLfu {
        /// Layer TinyLFU admission over the cache.
        tlfu: bool,
    },
    /// Exact fully-associative FIFO.
    FullFifo,
    /// Exact fully-associative uniform-random eviction.
    FullRandom,
    /// Hyperbolic caching; `sample >= capacity` = exact.
    FullHyperbolic {
        /// Entries drawn per eviction.
        sample: usize,
        /// Layer TinyLFU admission over the cache.
        tlfu: bool,
    },
    /// Guava-style segmented LRU.
    Guava {
        /// Independent segments (Guava's concurrency level).
        segments: usize,
    },
    /// Caffeine-style W-TinyLFU cache.
    Caffeine,
    /// Hash-routed independent Caffeine instances.
    SegCaffeine {
        /// Independent Caffeine segments.
        segments: usize,
    },
}

impl Config {
    /// Legend label, matching the paper's figure legends.
    pub fn label(&self) -> String {
        fn t(tlfu: bool) -> &'static str {
            if tlfu {
                "+TLFU"
            } else {
                ""
            }
        }
        match self {
            Config::KWay { ways, policy, tlfu, .. } => {
                format!("{}-way {}{}", ways, policy.name(), t(*tlfu))
            }
            Config::Sampled { sample, policy, tlfu } => {
                format!("sampled{} {}{}", sample, policy.name(), t(*tlfu))
            }
            Config::FullLru { tlfu } => format!("full lru{}", t(*tlfu)),
            Config::FullLfu { tlfu } => format!("full lfu{}", t(*tlfu)),
            Config::FullFifo => "full fifo".into(),
            Config::FullRandom => "full random".into(),
            Config::FullHyperbolic { sample, tlfu } => {
                format!("full hyperbolic(s{}){}", sample, t(*tlfu))
            }
            Config::Guava { .. } => "Guava".into(),
            Config::Caffeine => "Caffeine".into(),
            Config::SegCaffeine { segments } => format!("segmented Caffeine x{segments}"),
        }
    }

    /// Materialize a simulated cache of `capacity` entries.
    pub fn build(&self, capacity: usize, seed: u64) -> Box<dyn SimCache> {
        fn wrap<C: SimCache + crate::fully::SimVictimPeek + 'static>(
            inner: C,
            capacity: usize,
            tlfu: bool,
        ) -> Box<dyn SimCache> {
            if tlfu {
                Box::new(TlfuSim::new(inner, capacity))
            } else {
                Box::new(inner)
            }
        }
        match *self {
            Config::KWay { variant, ways, policy, tlfu } => match variant {
                Variant::Wfa => wrap(KwWfa::new(capacity, ways, policy), capacity, tlfu),
                Variant::Wfsc => wrap(KwWfsc::new(capacity, ways, policy), capacity, tlfu),
                Variant::Ls => wrap(KwLs::new(capacity, ways, policy), capacity, tlfu),
            },
            Config::Sampled { sample, policy, tlfu } => {
                // Hit-ratio simulation uses a single segment so sampling is
                // global, exactly like Redis.
                wrap(Sampled::new(capacity, sample, policy, 1), capacity, tlfu)
            }
            Config::FullLru { tlfu } => wrap(LruList::new(capacity), capacity, tlfu),
            Config::FullLfu { tlfu } => wrap(LfuOrdered::new(capacity), capacity, tlfu),
            Config::FullFifo => Box::new(FifoQueue::new(capacity)),
            Config::FullRandom => Box::new(RandomFull::new(capacity, seed)),
            Config::FullHyperbolic { sample, tlfu } => {
                wrap(HyperbolicFull::new(capacity, sample, seed), capacity, tlfu)
            }
            Config::Guava { segments } => Box::new(GuavaLike::new(capacity, segments)),
            Config::Caffeine => Box::new(SyncCaffeine::new(capacity)),
            Config::SegCaffeine { segments } => {
                Box::new(SyncSegCaffeine::new(capacity, segments))
            }
        }
    }
}

/// Caffeine with the maintenance thread synchronized after every write,
/// making the hit-ratio simulation deterministic with respect to the
/// access stream (the real library applies policy asynchronously; syncing
/// gives it its *best-case* hit ratio).
struct SyncCaffeine {
    inner: CaffeineLike,
}

impl SyncCaffeine {
    fn new(capacity: usize) -> Self {
        Self { inner: CaffeineLike::new_inline(capacity) }
    }
}

impl SimCache for SyncCaffeine {
    fn sim_get(&mut self, key: u64) -> bool {
        self.inner.get(key).is_some()
    }
    fn sim_put(&mut self, key: u64) {
        self.inner.put(key, key);
    }
    fn sim_name(&self) -> String {
        "Caffeine(sync)".into()
    }
}

struct SyncSegCaffeine {
    inner: SegmentedCaffeine,
}

impl SyncSegCaffeine {
    fn new(capacity: usize, segments: usize) -> Self {
        Self { inner: SegmentedCaffeine::new_inline(capacity, segments) }
    }
}

impl SimCache for SyncSegCaffeine {
    fn sim_get(&mut self, key: u64) -> bool {
        self.inner.get(key).is_some()
    }
    fn sim_put(&mut self, key: u64) {
        self.inner.put(key, key);
    }
    fn sim_name(&self) -> String {
        "segmented-Caffeine(sync)".into()
    }
}

/// One row of a figure: configuration label and measured hit ratio.
#[derive(Debug, Clone)]
pub struct Row {
    /// Configuration label (cache + policy + admission).
    pub label: String,
    /// Measured hit ratio over the whole trace.
    pub hit_ratio: f64,
}

/// Evaluate a set of configurations on one trace at one cache size.
pub fn sweep(trace: &Trace, capacity: usize, configs: &[Config], seed: u64) -> Vec<Row> {
    configs
        .iter()
        .map(|cfg| {
            let mut cache = cfg.build(capacity, seed);
            let stats = run(cache.as_mut(), &trace.keys);
            Row { label: cfg.label(), hit_ratio: stats.ratio() }
        })
        .collect()
}

/// The associativity / sample-size series the figures sweep.
pub const WAYS_SERIES: [usize; 6] = [4, 8, 16, 32, 64, 128];

/// The standard series for a hit-ratio subfigure of kind (a): LRU.
pub fn lru_series() -> Vec<Config> {
    let mut v: Vec<Config> = WAYS_SERIES
        .iter()
        .map(|&ways| Config::KWay {
            variant: Variant::Wfsc,
            ways,
            policy: Policy::Lru,
            tlfu: false,
        })
        .collect();
    v.extend(WAYS_SERIES.iter().map(|&sample| Config::Sampled {
        sample,
        policy: Policy::Lru,
        tlfu: false,
    }));
    v.push(Config::FullLru { tlfu: false });
    v
}

/// Subfigure (b): LFU eviction with TinyLFU admission.
pub fn lfu_tlfu_series() -> Vec<Config> {
    let mut v: Vec<Config> = WAYS_SERIES
        .iter()
        .map(|&ways| Config::KWay { variant: Variant::Wfsc, ways, policy: Policy::Lfu, tlfu: true })
        .collect();
    v.extend(WAYS_SERIES.iter().map(|&sample| Config::Sampled {
        sample,
        policy: Policy::Lfu,
        tlfu: true,
    }));
    v.push(Config::FullLfu { tlfu: true });
    v
}

/// Subfigure (c): the product baselines.
pub fn products_series(threads_hint: usize) -> Vec<Config> {
    vec![
        Config::Guava { segments: 4 },
        Config::Caffeine,
        Config::SegCaffeine { segments: threads_hint.max(2) },
    ]
}

/// Subfigure (d): Hyperbolic caching, optionally behind TinyLFU.
pub fn hyperbolic_series(tlfu: bool) -> Vec<Config> {
    let mut v: Vec<Config> = WAYS_SERIES
        .iter()
        .map(|&ways| Config::KWay {
            variant: Variant::Wfsc,
            ways,
            policy: Policy::Hyperbolic,
            tlfu,
        })
        .collect();
    v.extend(WAYS_SERIES.iter().map(|&sample| Config::Sampled {
        sample,
        policy: Policy::Hyperbolic,
        tlfu,
    }));
    v.push(Config::FullHyperbolic { sample: 64, tlfu });
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::paper;

    #[test]
    fn run_counts_hits() {
        let mut cache = Config::FullLru { tlfu: false }.build(2, 0);
        let stats = run(cache.as_mut(), &[1, 2, 1, 2, 3, 1]);
        // 1:miss 2:miss 1:hit 2:hit 3:miss(evicts 1) 1:miss
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.accesses, 6);
        assert!((stats.ratio() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn kway_hit_ratio_close_to_full_lru() {
        // The paper's core claim (Figures 4–13): 8-way ≈ fully associative.
        let trace = paper::build("oltp", 200_000, 3).unwrap();
        let capacity = 4096;
        let full = {
            let mut c = Config::FullLru { tlfu: false }.build(capacity, 0);
            run(c.as_mut(), &trace.keys).ratio()
        };
        let kway8 = {
            let mut c = Config::KWay {
                variant: Variant::Wfsc,
                ways: 8,
                policy: Policy::Lru,
                tlfu: false,
            }
            .build(capacity, 0);
            run(c.as_mut(), &trace.keys).ratio()
        };
        assert!(full > 0.3, "trace too cold for the comparison: {full}");
        assert!(
            (full - kway8).abs() < 0.05,
            "8-way LRU ({kway8:.3}) should be within 5pp of full LRU ({full:.3})"
        );
    }

    #[test]
    fn higher_associativity_monotone_ish() {
        let trace = paper::build("oltp", 100_000, 4).unwrap();
        let capacity = 2048;
        let ratio = |ways| {
            let mut c = Config::KWay {
                variant: Variant::Wfsc,
                ways,
                policy: Policy::Lru,
                tlfu: false,
            }
            .build(capacity, 0);
            run(c.as_mut(), &trace.keys).ratio()
        };
        let r4 = ratio(4);
        let r64 = ratio(64);
        // 64-way must not be *worse* than 4-way by more than noise.
        assert!(r64 >= r4 - 0.01, "r4={r4:.3} r64={r64:.3}");
    }

    #[test]
    fn variants_simulate_identically() {
        // Single-threaded, same policy/geometry => identical hit counts
        // for WFSC and LS; WFA too (same scan order).
        let trace = paper::build("multi1", 50_000, 5).unwrap();
        let mut results = Vec::new();
        for variant in Variant::ALL {
            let mut c = Config::KWay { variant, ways: 8, policy: Policy::Lru, tlfu: false }
                .build(1024, 0);
            results.push(run(c.as_mut(), &trace.keys).hits);
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn series_shapes() {
        assert_eq!(lru_series().len(), 13);
        assert_eq!(lfu_tlfu_series().len(), 13);
        assert_eq!(products_series(8).len(), 3);
        assert_eq!(hyperbolic_series(true).len(), 13);
    }

    #[test]
    fn sweep_produces_labeled_rows() {
        let trace = paper::build("sprite", 20_000, 6).unwrap();
        let rows = sweep(&trace, 512, &products_series(2), 1);
        assert_eq!(rows.len(), 3);
        for row in &rows {
            assert!(row.hit_ratio >= 0.0 && row.hit_ratio <= 1.0, "{row:?}");
        }
    }
}
