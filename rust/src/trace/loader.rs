//! Trace file loaders, so the *real* evaluation traces can replace the
//! synthetic models without touching the harness:
//!
//! * **ARC format** (Megiddo & Modha's OLTP/DS1/P*/S* distribution):
//!   whitespace-separated `start_block block_count ignored...` per line;
//!   each line expands to `block_count` sequential keys.
//! * **Plain format**: one integer key per line (the common normalized
//!   form for the Wikipedia / LIRS traces).
//! * **Binary format**: little-endian u64 keys, no header.

use super::Trace;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// Load an ARC-format trace (`start count ...` lines).
pub fn load_arc(path: impl AsRef<Path>) -> Result<Trace> {
    let name = stem(&path);
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut keys = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let start: u64 = it
            .next()
            .with_context(|| format!("line {}: missing start block", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad start block", lineno + 1))?;
        let count: u64 = match it.next() {
            Some(c) => c.parse().with_context(|| format!("line {}: bad count", lineno + 1))?,
            None => 1,
        };
        if count > 1_000_000 {
            bail!("line {}: implausible block count {count}", lineno + 1);
        }
        keys.extend(start..start + count.max(1));
    }
    Ok(Trace::new(name, keys))
}

/// Load a plain one-key-per-line trace.
pub fn load_plain(path: impl AsRef<Path>) -> Result<Trace> {
    let name = stem(&path);
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut keys = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        keys.push(
            line.parse::<u64>()
                .with_context(|| format!("line {}: bad key {line:?}", lineno + 1))?,
        );
    }
    Ok(Trace::new(name, keys))
}

/// Load a binary little-endian u64 trace.
pub fn load_binary(path: impl AsRef<Path>) -> Result<Trace> {
    let name = stem(&path);
    let mut file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)?;
    if bytes.len() % 8 != 0 {
        bail!("binary trace length {} is not a multiple of 8", bytes.len());
    }
    let keys = bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Trace::new(name, keys))
}

/// Resolve a trace argument: a known model name (`wiki_a`, `oltp`, ...)
/// or a path with an optional `arc:` / `plain:` / `bin:` prefix.
pub fn resolve(spec: &str, len: usize, seed: u64) -> Result<Trace> {
    if let Some(t) = super::paper::build(spec, len, seed) {
        return Ok(t);
    }
    if let Some(p) = spec.strip_prefix("arc:") {
        return load_arc(p);
    }
    if let Some(p) = spec.strip_prefix("plain:") {
        return load_plain(p);
    }
    if let Some(p) = spec.strip_prefix("bin:") {
        return load_binary(p);
    }
    bail!(
        "unknown trace {spec:?}: expected one of {:?} or arc:/plain:/bin: path",
        super::paper::ALL
    )
}

fn stem(path: &impl AsRef<Path>) -> String {
    path.as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "trace".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("kway-loader-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn arc_expands_block_ranges() {
        let p = tmp("a.arc", b"100 3 0 0\n200 1\n# comment\n\n300 2 junk\n");
        let t = load_arc(&p).unwrap();
        assert_eq!(t.keys, vec![100, 101, 102, 200, 300, 301]);
        assert_eq!(t.name, "a");
    }

    #[test]
    fn plain_and_binary_round_trip() {
        let p = tmp("b.txt", b"5\n6\n\n7\n");
        assert_eq!(load_plain(&p).unwrap().keys, vec![5, 6, 7]);

        let mut bytes = Vec::new();
        for k in [1u64, 2, 3] {
            bytes.extend_from_slice(&k.to_le_bytes());
        }
        let p = tmp("c.bin", &bytes);
        assert_eq!(load_binary(&p).unwrap().keys, vec![1, 2, 3]);
    }

    #[test]
    fn bad_inputs_error() {
        let p = tmp("bad.arc", b"notanumber 3\n");
        assert!(load_arc(&p).is_err());
        let p = tmp("bad.txt", b"12x\n");
        assert!(load_plain(&p).is_err());
        let p = tmp("bad.bin", &[1, 2, 3]);
        assert!(load_binary(&p).is_err());
    }

    #[test]
    fn resolve_models_and_paths() {
        assert!(resolve("oltp", 10_000, 1).is_ok());
        assert!(resolve("definitely-not-a-trace", 10_000, 1).is_err());
        let p = tmp("r.txt", b"9\n");
        let spec = format!("plain:{}", p.display());
        assert_eq!(resolve(&spec, 0, 0).unwrap().keys, vec![9]);
    }
}
