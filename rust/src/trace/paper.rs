//! Synthetic models of the paper's sixteen evaluation traces (§5.1).
//!
//! Each model documents what the real trace is and which structural
//! features the synthetic stand-in reproduces. Lengths default to 2M
//! accesses (1M for the small interactive traces), which is enough for
//! the hit-ratio comparisons to stabilize while keeping a full sweep fast.
//!
//! | model | real trace | structure reproduced |
//! |---|---|---|
//! | `wiki_a`/`wiki_b` | Wikipedia 10% sample, 2007 [43] | heavy Zipf head (α≈0.99) over a multi-million universe + slow diurnal drift |
//! | `sprite` | Sprite NFS, 2 days [26] | small hot working set, very high attainable hit ratio, strong recency |
//! | `multi1/2/3` | cs+cpp (+postgres, +glimpse) [26] | Zipf core + repeated sequential scans (loops) that flood LRU |
//! | `oltp` | ARC OLTP file system [33] | strong recency + skewed hot records |
//! | `ds1` | ARC DS1 database [33] | weak locality over a huge universe |
//! | `s1`/`s3` | ARC search engines [33] | weak skew, very large universe, scan-ish reads |
//! | `p8`/`p12`/`p14` | Windows server disks [33] | mixed: skew + bursts of sequential I/O |
//! | `f1`/`f2` | UMass financial OLTP [44] | sharp Zipf (hot accounts) + recency drift |
//! | `w2`/`w3` | UMass WebSearch [44] | near-uniform huge universe, low attainable hit ratio |

use super::synthetic::{drift, mix, scan_total, uniform, zipf, Component};
use super::Trace;
use crate::util::rng::Rng;

/// All model names, in the order the paper first shows them.
pub const ALL: [&str; 16] = [
    "wiki_a", "wiki_b", "sprite", "multi1", "multi2", "multi3", "oltp", "ds1", "s1", "s3",
    "p8", "p12", "p14", "f1", "f2", "w3",
];

/// Default access count per model.
pub fn default_len(name: &str) -> usize {
    match name {
        "sprite" | "multi1" | "multi2" | "multi3" | "oltp" | "f1" | "f2" | "wiki_a"
        | "wiki_b" | "p8" => 1_000_000,
        _ => 2_000_000,
    }
}

/// Build a named trace model. `len` scales the access count; the seed
/// fixes the instance. Unknown names return `None`.
pub fn build(name: &str, len: usize, seed: u64) -> Option<Trace> {
    let mut rng = Rng::new(seed ^ 0x7ACE_0000);
    let keys = match name {
        // Wikipedia: strong Zipf head + slow drift of the popular set.
        "wiki_a" => mix(
            vec![
                Component {
                    weight: 0.85,
                    keys: zipf(len * 85 / 100, 4_000_000, 0.99, 0, &mut rng),
                },
                Component {
                    weight: 0.15,
                    keys: drift(len * 15 / 100, 200_000, 0.9, 50_000, 20_000, 8_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        "wiki_b" => mix(
            vec![
                Component {
                    weight: 0.85,
                    keys: zipf(len * 85 / 100, 4_000_000, 0.96, 0, &mut rng),
                },
                Component {
                    weight: 0.15,
                    keys: drift(len * 15 / 100, 300_000, 0.9, 40_000, 30_000, 8_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        // Sprite: tiny drifting working set -> very high hit ratios, pure
        // recency (the trace where the paper's design *loses* on
        // throughput to sampled, Figure 24).
        "sprite" => drift(len, 6_000, 1.1, 25_000, 600, 0, &mut rng),
        // multiN: interactive tools + compiler/glimpse/postgres scans.
        "multi1" => mix(
            vec![
                Component { weight: 0.6, keys: zipf(len * 6 / 10, 60_000, 0.9, 0, &mut rng) },
                Component { weight: 0.4, keys: scan_total(20_000, len * 4 / 10, 1_000_000) },
            ],
            &mut rng,
        ),
        "multi2" => mix(
            vec![
                Component { weight: 0.5, keys: zipf(len / 2, 80_000, 0.9, 0, &mut rng) },
                Component { weight: 0.3, keys: scan_total(30_000, len * 3 / 10, 1_000_000) },
                Component { weight: 0.2, keys: uniform(len / 5, 150_000, 2_000_000, &mut rng) },
            ],
            &mut rng,
        ),
        "multi3" => mix(
            vec![
                Component { weight: 0.4, keys: zipf(len * 4 / 10, 100_000, 0.9, 0, &mut rng) },
                Component { weight: 0.3, keys: scan_total(40_000, len * 3 / 10, 1_000_000) },
                Component {
                    weight: 0.3,
                    keys: uniform(len * 3 / 10, 250_000, 2_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        // OLTP: hot records + recency.
        "oltp" => mix(
            vec![
                Component { weight: 0.7, keys: zipf(len * 7 / 10, 150_000, 1.0, 0, &mut rng) },
                Component {
                    weight: 0.3,
                    keys: drift(len * 3 / 10, 30_000, 1.0, 20_000, 4_000, 1_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        // DS1: big universe, weak locality.
        "ds1" => zipf(len, 6_000_000, 0.75, 0, &mut rng),
        // Search engines: weak skew over large universes.
        "s1" => mix(
            vec![
                Component { weight: 0.8, keys: zipf(len * 8 / 10, 3_000_000, 0.7, 0, &mut rng) },
                Component { weight: 0.2, keys: scan_total(100_000, len * 2 / 10, 10_000_000) },
            ],
            &mut rng,
        ),
        "s3" => mix(
            vec![
                Component { weight: 0.8, keys: zipf(len * 8 / 10, 3_500_000, 0.72, 0, &mut rng) },
                Component { weight: 0.2, keys: scan_total(150_000, len * 2 / 10, 10_000_000) },
            ],
            &mut rng,
        ),
        // Windows server disks: skew + sequential bursts.
        "p8" => mix(
            vec![
                Component { weight: 0.6, keys: zipf(len * 6 / 10, 400_000, 0.9, 0, &mut rng) },
                Component { weight: 0.4, keys: scan_total(25_000, len * 4 / 10, 5_000_000) },
            ],
            &mut rng,
        ),
        "p12" => mix(
            vec![
                Component { weight: 0.55, keys: zipf(len * 55 / 100, 700_000, 0.85, 0, &mut rng) },
                Component { weight: 0.45, keys: scan_total(60_000, len * 45 / 100, 5_000_000) },
            ],
            &mut rng,
        ),
        "p14" => mix(
            vec![
                Component { weight: 0.6, keys: zipf(len * 6 / 10, 500_000, 0.88, 0, &mut rng) },
                Component { weight: 0.4, keys: scan_total(40_000, len * 4 / 10, 5_000_000) },
            ],
            &mut rng,
        ),
        // Financial transaction processing: sharp skew + drift.
        "f1" => mix(
            vec![
                Component { weight: 0.8, keys: zipf(len * 8 / 10, 800_000, 1.05, 0, &mut rng) },
                Component {
                    weight: 0.2,
                    keys: drift(len * 2 / 10, 50_000, 1.0, 30_000, 10_000, 2_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        "f2" => mix(
            vec![
                Component { weight: 0.8, keys: zipf(len * 8 / 10, 1_000_000, 1.02, 0, &mut rng) },
                Component {
                    weight: 0.2,
                    keys: drift(len * 2 / 10, 60_000, 1.0, 25_000, 12_000, 2_500_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        // WebSearch: near-uniform over a huge universe.
        "w2" | "w3" => mix(
            vec![
                Component { weight: 0.3, keys: zipf(len * 3 / 10, 2_000_000, 0.6, 0, &mut rng) },
                Component {
                    weight: 0.7,
                    keys: uniform(len * 7 / 10, 8_000_000, 4_000_000, &mut rng),
                },
            ],
            &mut rng,
        ),
        _ => return None,
    };
    Some(Trace::new(name, keys))
}

/// Build with the model's default length.
pub fn build_default(name: &str, seed: u64) -> Option<Trace> {
    build(name, default_len(name), seed)
}

/// Cache sizes the paper uses per trace in the throughput study
/// (Figures 14–26): 2^11 for the small traces, 2^17/2^19 for the big ones.
pub fn paper_cache_size(name: &str) -> usize {
    match name {
        "s1" | "s3" | "w2" | "w3" => 1 << 19,
        "p12" | "p14" => 1 << 17,
        _ => 1 << 11,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build() {
        for name in ALL {
            let t = build(name, 50_000, 1).unwrap_or_else(|| panic!("{name} missing"));
            assert!(t.len() >= 45_000, "{name} too short: {}", t.len());
            assert!(t.unique_keys() > 100, "{name} degenerate");
        }
        assert!(build("nope", 1000, 1).is_none());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = build("oltp", 10_000, 7).unwrap();
        let b = build("oltp", 10_000, 7).unwrap();
        assert_eq!(a.keys, b.keys);
        let c = build("oltp", 10_000, 8).unwrap();
        assert_ne!(a.keys, c.keys);
    }

    #[test]
    fn sprite_is_high_locality() {
        // Sprite's model must be far more cacheable than websearch's.
        let sprite = build("sprite", 100_000, 1).unwrap();
        let w3 = build("w3", 100_000, 1).unwrap();
        let sprite_ratio = sprite.unique_keys() as f64 / sprite.len() as f64;
        let w3_ratio = w3.unique_keys() as f64 / w3.len() as f64;
        assert!(
            sprite_ratio * 10.0 < w3_ratio,
            "sprite {sprite_ratio:.3} vs w3 {w3_ratio:.3}"
        );
    }

    #[test]
    fn cache_sizes_match_paper() {
        assert_eq!(paper_cache_size("f1"), 2048);
        assert_eq!(paper_cache_size("s3"), 1 << 19);
        assert_eq!(paper_cache_size("p12"), 1 << 17);
    }
}
