//! Synthetic workload building blocks.
//!
//! Each generator is deterministic in its seed. The [`mix`] combinator
//! interleaves components with given weights, which is how the models in
//! [`super::paper`] compose skew (Zipf), recency (drifting working sets)
//! and scans (sequential sweeps) into trace shapes that reward the same
//! cache behaviours the corresponding real traces do.

use crate::util::rng::{Rng, Zipf};

/// Zipf-distributed accesses over `universe` keys with exponent `alpha`.
/// Rank r maps to key `base + permute(r)` so that popularity is not
/// correlated with key order (and therefore not with set placement).
pub fn zipf(n: usize, universe: u64, alpha: f64, base: u64, rng: &mut Rng) -> Vec<u64> {
    let dist = Zipf::new(universe, alpha);
    (0..n)
        .map(|_| {
            let rank = dist.sample(rng);
            base + scramble(rank, universe)
        })
        .collect()
}

/// Bijectively scramble a rank into the key space so that hot keys are
/// spread uniformly over sets (a multiplicative hash mod universe would
/// bias; we use a Feistel-ish mix and reject out-of-range).
fn scramble(rank: u64, universe: u64) -> u64 {
    // Cycle-walk a bijection over the next power of two until the image
    // lands inside [0, universe): xorshift and odd-multiply steps are each
    // invertible mod 2^bits, so their composition is a permutation and the
    // walk terminates.
    let bits = 64 - u32::min((universe - 1).leading_zeros(), 63);
    let mask = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut x = rank & mask;
    loop {
        x ^= x >> 7;
        x = x.wrapping_mul(0x9E37_79B9_7F4A_7C15) & mask;
        x ^= x >> 5;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9) & mask;
        x ^= x >> 11;
        if x < universe {
            return x;
        }
    }
}

/// Uniform accesses over `universe` keys.
pub fn uniform(n: usize, universe: u64, base: u64, rng: &mut Rng) -> Vec<u64> {
    (0..n).map(|_| base + rng.below(universe)).collect()
}

/// Sequential scan(s): `repeats` passes over `[base, base+span)` — the
/// glimpse / postgres-join pattern that LIRS-style traces contain and
/// that floods LRU.
pub fn scan(span: u64, repeats: usize, base: u64) -> Vec<u64> {
    let mut out = Vec::with_capacity(span as usize * repeats);
    for _ in 0..repeats {
        out.extend((0..span).map(|i| base + i));
    }
    out
}

/// Exactly `n` scan accesses: cyclic passes over `[base, base+span)`
/// truncated to length `n` (so short traces still contain a partial
/// scan instead of rounding down to nothing).
pub fn scan_total(span: u64, n: usize, base: u64) -> Vec<u64> {
    (0..n).map(|i| base + (i as u64 % span)).collect()
}

/// A drifting working set: Zipf over a window of `window` keys whose base
/// shifts by `shift` every `period` accesses — models diurnal drift
/// (Wikipedia) and session locality (Sprite).
pub fn drift(
    n: usize,
    window: u64,
    alpha: f64,
    period: usize,
    shift: u64,
    base: u64,
    rng: &mut Rng,
) -> Vec<u64> {
    let dist = Zipf::new(window, alpha);
    let mut out = Vec::with_capacity(n);
    let mut origin = base;
    for i in 0..n {
        if i > 0 && i % period == 0 {
            origin += shift;
        }
        out.push(origin + scramble(dist.sample(rng), window));
    }
    out
}

/// One weighted component of a [`mix`].
pub struct Component {
    /// Relative share of accesses drawn from this component.
    pub weight: f64,
    /// The component's access sequence.
    pub keys: Vec<u64>,
}

/// Interleave components by weight (without replacement: each component's
/// sequence order is preserved — scans stay sequential).
pub fn mix(components: Vec<Component>, rng: &mut Rng) -> Vec<u64> {
    let total_len: usize = components.iter().map(|c| c.keys.len()).sum();
    let total_weight: f64 = components.iter().map(|c| c.weight).sum();
    let mut cursors = vec![0usize; components.len()];
    let mut out = Vec::with_capacity(total_len);
    while out.len() < total_len {
        // Draw a component proportional to weight; skip exhausted ones.
        let mut pick = rng.f64() * total_weight;
        let mut chosen = None;
        for (i, c) in components.iter().enumerate() {
            pick -= c.weight;
            if pick <= 0.0 {
                chosen = Some(i);
                break;
            }
        }
        let mut i = chosen.unwrap_or(components.len() - 1);
        // Advance to a non-exhausted component.
        let mut tried = 0;
        while cursors[i] >= components[i].keys.len() {
            i = (i + 1) % components.len();
            tried += 1;
            if tried > components.len() {
                return out;
            }
        }
        out.push(components[i].keys[cursors[i]]);
        cursors[i] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_keys_in_range_and_skewed() {
        let mut rng = Rng::new(1);
        let keys = zipf(100_000, 10_000, 1.0, 0, &mut rng);
        assert!(keys.iter().all(|&k| k < 10_000));
        // The most common key should appear far more often than average.
        let mut counts = std::collections::HashMap::new();
        for &k in &keys {
            *counts.entry(k).or_insert(0u32) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        assert!(max > 50 * (100_000 / 10_000), "zipf not skewed enough: max={max}");
    }

    #[test]
    fn scramble_is_injective_in_range() {
        let universe = 1000u64;
        let mut seen = std::collections::HashSet::new();
        for r in 0..universe {
            let s = scramble(r, universe);
            assert!(s < universe);
            assert!(seen.insert(s), "scramble collided at rank {r}");
        }
    }

    #[test]
    fn scan_is_sequential() {
        let keys = scan(5, 2, 100);
        assert_eq!(keys, vec![100, 101, 102, 103, 104, 100, 101, 102, 103, 104]);
    }

    #[test]
    fn drift_moves_the_window() {
        let mut rng = Rng::new(2);
        let keys = drift(10_000, 100, 0.8, 1000, 1000, 0, &mut rng);
        let early_max = keys[..1000].iter().max().copied().unwrap();
        let late_min_origin = keys[9000..].iter().min().copied().unwrap();
        assert!(late_min_origin > early_max, "window should have drifted past the start");
    }

    #[test]
    fn mix_preserves_component_order_and_length() {
        let mut rng = Rng::new(3);
        let m = mix(
            vec![
                Component { weight: 1.0, keys: vec![1, 2, 3] },
                Component { weight: 1.0, keys: vec![10, 20] },
            ],
            &mut rng,
        );
        assert_eq!(m.len(), 5);
        let a: Vec<u64> = m.iter().copied().filter(|&k| k < 10).collect();
        assert_eq!(a, vec![1, 2, 3], "component order must be preserved");
    }

    #[test]
    fn uniform_covers_universe() {
        let mut rng = Rng::new(4);
        let keys = uniform(10_000, 100, 500, &mut rng);
        assert!(keys.iter().all(|&k| (500..600).contains(&k)));
        let distinct: std::collections::HashSet<_> = keys.iter().collect();
        assert!(distinct.len() > 90);
    }
}
