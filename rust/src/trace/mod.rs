//! Workload traces: synthetic generator combinators, models of the
//! paper's sixteen real traces, and loaders for on-disk trace formats so
//! the real traces can be dropped in unchanged.
//!
//! ## Substitution note (see DESIGN.md §Substitutions)
//!
//! The paper evaluates on proprietary/archived traces (Wikipedia 2007,
//! Sprite, UMass F1/F2/W2/W3, ARC's OLTP/DS1/S1/S3/P8/P12/P14, LIRS'
//! multi1-3). Those files are not redistributable and are not present in
//! this environment, so [`paper`] provides a *synthetic model* of each —
//! a documented mixture of Zipf skew, working-set drift and sequential
//! scans calibrated to the qualitative behaviour the paper reports
//! (relative hit-ratio levels and how much each trace rewards recency vs
//! frequency). All of the paper's claims are comparative across cache
//! designs on a fixed trace, so the comparisons survive the substitution;
//! [`loader`] keeps the harness byte-compatible with the real files.

pub mod loader;
pub mod paper;
pub mod synthetic;

/// A trace: a name plus the sequence of accessed keys.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Trace model name (reports and file naming).
    pub name: String,
    /// The accessed keys, in order.
    pub keys: Vec<u64>,
}

impl Trace {
    /// Wrap a key sequence as a named trace.
    pub fn new(name: impl Into<String>, keys: Vec<u64>) -> Self {
        Self { name: name.into(), keys }
    }

    /// Number of accesses.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the trace has no accesses.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Number of distinct keys (for reporting and sizing caches).
    pub fn unique_keys(&self) -> usize {
        let mut set = std::collections::HashSet::with_capacity(self.keys.len() / 4);
        for &k in &self.keys {
            set.insert(k);
        }
        set.len()
    }

    /// Infinite cyclic iterator used by the fixed-duration throughput runs.
    pub fn cycle_from(&self, start: usize) -> impl Iterator<Item = u64> + '_ {
        let n = self.keys.len();
        (0..).map(move |i| self.keys[(start + i) % n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_and_cycle() {
        let t = Trace::new("t", vec![1, 2, 2, 3]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.unique_keys(), 3);
        let looped: Vec<u64> = t.cycle_from(2).take(6).collect();
        assert_eq!(looped, vec![2, 3, 1, 2, 2, 3]);
    }
}
