//! The cache service coordinator — Layer 3's serving front.
//!
//! The paper's artifact is a library; to make it deployable (and to give
//! the end-to-end example something real to exercise) this module wraps
//! any [`crate::Cache`] in a small request-routing service in the style of
//! a vLLM-like router: clients submit get/put requests (singly or in
//! batches), a router shards them by key hash onto worker threads, and the
//! workers execute against the shared concurrent cache while recording
//! latency histograms and hit counters.
//!
//! Sharding by key is not needed for correctness (the k-way caches are
//! already concurrent) — it provides per-key FIFO ordering and models the
//! deployment the paper targets (§1: storage/database node caches serving
//! many client threads).

mod service;

pub use service::{
    drive_clients, drive_clients_batched, CacheService, DegradedPolicy, ServiceConfig,
    ServiceError, ServiceMetrics,
};
