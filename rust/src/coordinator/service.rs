//! Worker-pool cache service with key-hash routing.
//!
//! Requests are routed to a worker by key hash, so same-key requests are
//! FIFO-ordered per worker. Batched requests are *scattered* across the
//! workers that own their keys and the partial results *gathered* back
//! into input order — every worker probes its share of the batch in
//! parallel through the cache's own batched path
//! ([`crate::Cache::get_batch`]), instead of one worker serializing the
//! whole batch. See DESIGN.md §Batched access path.

use crate::fault::FaultPlan;
use crate::lifetime::{BatchEntry, EntryOpts};
use crate::metrics::{LatencyHistogram, OpCounters};
use crate::tinylfu::AdmissionMode;
use crate::util::hash;
use crate::Cache;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What the convenience ops ([`CacheService::get`] & co.) degrade to
/// when a worker or the whole service is down, and what the wire front
/// end answers for a degraded request. Never a panic — that was the
/// pre-resilience behaviour this enum replaces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DegradedPolicy {
    /// Serve a miss (gets → `None`, puts dropped): availability over
    /// accuracy — a cache miss is always a *correct* answer for a cache.
    /// The default.
    #[default]
    MissThrough,
    /// Surface the failure: the wire front end answers
    /// `SERVER_ERROR unavailable` / `-ERR unavailable` instead of a miss,
    /// for deployments that prefer visible errors to silent miss storms.
    Error,
}

impl DegradedPolicy {
    /// Parse `miss-through` / `error` (CLI `--degraded`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "miss-through" | "miss_through" | "miss" => Some(Self::MissThrough),
            "error" => Some(Self::Error),
            _ => None,
        }
    }
}

/// Why a routed operation could not be served ([`CacheService::try_get`]
/// & co.).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceError {
    /// The service has been halted / shut down (every op will fail).
    Stopped,
    /// The owning worker died mid-request (dropped the reply channel);
    /// the supervisor restarts it, so a retry usually succeeds.
    WorkerDown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Stopped => write!(f, "cache service stopped"),
            Self::WorkerDown => write!(f, "cache worker down (restarting)"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads executing cache operations.
    pub workers: usize,
    /// Admission filter layered over the supplied cache before the
    /// workers start ([`AdmissionMode::TinyLfu`] wraps it in a
    /// [`crate::tinylfu::TlfuCache`], so every routed get/put — batched
    /// or not — flows through the shared frequency sketch).
    pub admission: AdmissionMode,
    /// Default entry lifetime: every put routed through
    /// [`CacheService::put`] / [`CacheService::put_batch`] carries this
    /// TTL unless the caller passes explicit options via
    /// [`CacheService::put_with`]. `None` (the default) keeps entries
    /// immortal — the pre-lifetime behaviour.
    pub default_ttl: Option<Duration>,
    /// What degraded requests observe when a worker or the service is
    /// down (see [`DegradedPolicy`]).
    pub degraded: DegradedPolicy,
    /// Load-shedding threshold: when more than this many requests are
    /// queued across the worker channels, [`CacheService::overloaded`]
    /// reports `true` and the wire front end answers `busy` instead of
    /// queueing more work. `0` (the default) disables shedding — the
    /// pre-resilience unbounded-queue behaviour.
    pub shed_queue_depth: usize,
    /// Fault-injection plan for chaos testing (worker panics); `None`
    /// (the default) injects nothing. See [`crate::fault`].
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            admission: AdmissionMode::None,
            default_ttl: None,
            degraded: DegradedPolicy::MissThrough,
            shed_queue_depth: 0,
            faults: None,
        }
    }
}

/// Shared service metrics.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Queue-to-completion latency of routed gets (includes queueing).
    pub get_latency: LatencyHistogram,
    /// Queue-to-completion latency of routed puts (includes queueing).
    pub put_latency: LatencyHistogram,
    /// Operation and hit counters.
    pub ops: OpCounters,
    /// Accepted [`CacheService::resize`] admin operations.
    pub resizes: AtomicU64,
    /// Panicked workers restarted by the supervisor loop.
    pub worker_restarts: AtomicU64,
    /// Requests answered `busy` by load shedding instead of queueing.
    pub shed: AtomicU64,
    /// Connections evicted because their write queue exceeded the
    /// slow-client byte cap (`--max-wq-bytes`).
    pub evicted_slow: AtomicU64,
    /// Connections refused at accept because `--max-conns` was reached.
    pub rejected_conns: AtomicU64,
    /// Convenience ops that degraded (to a miss / dropped put) because a
    /// worker or the service was down.
    pub degraded_ops: AtomicU64,
    /// Socket/ring syscalls issued by the io threads (epoll waits +
    /// reads + writes in readiness mode; `io_uring_enter`s in
    /// completion mode). `syscalls_per_op` — this over `gets + puts` —
    /// is the number the io_uring backend exists to shrink.
    pub io_syscalls: AtomicU64,
    /// Which event-loop backend the server resolved to: 0 = none
    /// serving, 1 = epoll, 2 = io_uring (see
    /// [`ServiceMetrics::set_io_backend`]).
    pub io_backend: AtomicU64,
}

impl ServiceMetrics {
    /// Multi-line human-readable summary of all service metrics.
    pub fn report(&self) -> String {
        format!(
            "gets={} puts={} hit_ratio={:.3}\n  get latency: {}\n  put latency: {}\n  \
             resilience: shed={} evicted_slow={} rejected_conns={} worker_restarts={} \
             degraded_ops={}",
            self.ops.gets.load(Ordering::Relaxed),
            self.ops.puts.load(Ordering::Relaxed),
            self.ops.hit_ratio(),
            self.get_latency.summary(),
            self.put_latency.summary(),
            self.shed.load(Ordering::Relaxed),
            self.evicted_slow.load(Ordering::Relaxed),
            self.rejected_conns.load(Ordering::Relaxed),
            self.worker_restarts.load(Ordering::Relaxed),
            self.degraded_ops.load(Ordering::Relaxed),
        )
    }

    /// Record which event-loop backend is serving (`"epoll"` /
    /// `"uring"`); anything else resets to "none".
    pub fn set_io_backend(&self, name: &str) {
        let code = match name {
            "epoll" => 1,
            "uring" => 2,
            _ => 0,
        };
        self.io_backend.store(code, Ordering::Relaxed);
    }

    /// The serving backend's name, as recorded by
    /// [`ServiceMetrics::set_io_backend`].
    pub fn io_backend_name(&self) -> &'static str {
        match self.io_backend.load(Ordering::Relaxed) {
            1 => "epoll",
            2 => "uring",
            _ => "none",
        }
    }

    /// Syscalls per completed cache operation — the io_uring backend's
    /// headline number. `0` until traffic has been served.
    pub fn syscalls_per_op(&self) -> f64 {
        let ops = self.ops.gets.load(Ordering::Relaxed) + self.ops.puts.load(Ordering::Relaxed);
        if ops == 0 {
            return 0.0;
        }
        self.io_syscalls.load(Ordering::Relaxed) as f64 / ops as f64
    }

    /// `(name, value)` pairs of every counter, for the wire-level
    /// memcached `stats` / RESP `INFO` commands. Latencies are reported
    /// as nanosecond percentiles. Values are pre-rendered strings
    /// because not every stat is integral (`syscalls_per_op`) or
    /// numeric (`io_backend`); new pairs append at the end so clients
    /// that prefix-match keep working.
    pub fn stat_pairs(&self, queue_depth: usize) -> Vec<(&'static str, String)> {
        let int = |v: u64| v.to_string();
        vec![
            ("gets", int(self.ops.gets.load(Ordering::Relaxed))),
            ("puts", int(self.ops.puts.load(Ordering::Relaxed))),
            ("hits", int(self.ops.hits.load(Ordering::Relaxed))),
            ("get_p50_ns", int(self.get_latency.percentile(50.0))),
            ("get_p99_ns", int(self.get_latency.percentile(99.0))),
            ("put_p50_ns", int(self.put_latency.percentile(50.0))),
            ("put_p99_ns", int(self.put_latency.percentile(99.0))),
            ("resizes", int(self.resizes.load(Ordering::Relaxed))),
            ("queue_depth", int(queue_depth as u64)),
            ("shed", int(self.shed.load(Ordering::Relaxed))),
            ("evicted_slow_clients", int(self.evicted_slow.load(Ordering::Relaxed))),
            ("rejected_conns", int(self.rejected_conns.load(Ordering::Relaxed))),
            ("worker_restarts", int(self.worker_restarts.load(Ordering::Relaxed))),
            ("degraded_ops", int(self.degraded_ops.load(Ordering::Relaxed))),
            ("io_syscalls", int(self.io_syscalls.load(Ordering::Relaxed))),
            ("syscalls_per_op", format!("{:.4}", self.syscalls_per_op())),
            ("io_backend", self.io_backend_name().to_string()),
        ]
    }
}

enum Request {
    Get { key: u64, enqueued: Instant, reply: Sender<Option<u64>> },
    /// `opts` carries the entry lifetime/weight (the service default for
    /// plain puts, caller-supplied for `put_with`).
    Put { key: u64, value: u64, opts: EntryOpts, enqueued: Instant },
    /// One worker's share of a scattered batch; `worker` comes back with
    /// the reply so the gatherer knows which sub-batch arrived.
    GetBatch {
        keys: Vec<u64>,
        enqueued: Instant,
        worker: usize,
        reply: Sender<(usize, Vec<Option<u64>>)>,
    },
    /// One worker's share of a scattered batched put (fire-and-forget);
    /// `opts` applies to every item of the sub-batch.
    PutBatch { items: Vec<(u64, u64)>, opts: EntryOpts, enqueued: Instant },
    /// Byte-value get ([`crate::Cache::get_bytes`]); answers `None` on a
    /// word-only cache exactly like a miss.
    GetBytes { key: u64, enqueued: Instant, reply: Sender<Option<Vec<u8>>> },
    /// Byte-value put; the worker reports whether the cache accepted it.
    PutBytes { key: u64, value: Vec<u8>, opts: EntryOpts, enqueued: Instant },
    /// One worker's share of a scattered byte-value batched get.
    GetBytesBatch {
        keys: Vec<u64>,
        enqueued: Instant,
        worker: usize,
        reply: Sender<(usize, Vec<Option<Vec<u8>>>)>,
    },
    /// One worker's share of a scattered byte-value batched put.
    PutBytesBatch { items: Vec<(u64, Vec<u8>)>, opts: EntryOpts, enqueued: Instant },
    Shutdown,
}

/// How many source sets one background-migration increment moves. Small
/// enough that the driver never monopolizes a core, large enough that a
/// grow of 2^19 sets completes in a few thousand increments.
const RESIZE_STEP_SETS: usize = 64;

/// A running cache service: router + worker pool over a shared cache.
pub struct CacheService {
    cache: Arc<dyn Cache>,
    senders: Vec<Sender<Request>>,
    /// Worker handles, behind a mutex so [`CacheService::halt`] can join
    /// from `&self` (the wire front end holds the service in an `Arc`).
    workers: std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>,
    metrics: Arc<ServiceMetrics>,
    /// Background migration drivers spawned by [`CacheService::resize`];
    /// joined on shutdown (each terminates once its migration finishes).
    migrators: std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Options stamped on puts that do not carry their own (from
    /// [`ServiceConfig::default_ttl`]).
    default_opts: EntryOpts,
    /// Requests currently queued across all worker channels (incremented
    /// at send, decremented at dequeue) — the shedding signal.
    depth: Arc<AtomicUsize>,
    /// Set by [`CacheService::halt`]; once true every op degrades
    /// ([`ServiceError::Stopped`]) instead of panicking.
    stopped: Arc<AtomicBool>,
    degraded: DegradedPolicy,
    shed_queue_depth: usize,
    faults: Option<Arc<FaultPlan>>,
}

impl CacheService {
    /// Start `cfg.workers` workers over `cache` (layered behind the
    /// configured admission filter).
    ///
    /// ```
    /// use kway::coordinator::{CacheService, ServiceConfig};
    /// use kway::kway::KwWfsc;
    /// use kway::policy::Policy;
    /// use std::sync::Arc;
    ///
    /// let cache = Arc::new(KwWfsc::new(1 << 10, 8, Policy::Lru));
    /// let service = CacheService::start(cache, ServiceConfig::default());
    /// service.put(1, 10);
    /// // Routed puts are fire-and-forget; a same-key get is FIFO-ordered
    /// // behind the put, so it observes the write.
    /// assert_eq!(service.get(1), Some(10));
    /// service.shutdown();
    /// ```
    pub fn start(cache: Arc<dyn Cache>, cfg: ServiceConfig) -> Self {
        assert!(cfg.workers >= 1);
        let cache = cfg.admission.wrap(cache);
        let metrics = Arc::new(ServiceMetrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let stopped = Arc::new(AtomicBool::new(false));
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = channel::<Request>();
            senders.push(tx);
            let cache = cache.clone();
            let metrics = metrics.clone();
            let depth = depth.clone();
            let stopped = stopped.clone();
            let faults = cfg.faults.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cache-worker-{w}"))
                    .spawn(move || {
                        // Supervisor: a clean return (Shutdown received or
                        // the service dropped its sender) ends the thread;
                        // a panic is caught and the loop re-entered on the
                        // *same* receiver, so requests queued behind the
                        // poisoned one survive the restart. The shared
                        // cache is lock-free (atomics, no poisonable
                        // state), so the restarted worker serves the same
                        // shard safely.
                        loop {
                            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || worker_loop(&rx, &cache, &metrics, &depth, faults.as_deref()),
                            ));
                            match run {
                                Ok(()) => return,
                                Err(_) => {
                                    metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                                    if stopped.load(Ordering::Acquire) {
                                        return;
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        let default_opts = EntryOpts { ttl: cfg.default_ttl, weight: 1 };
        // A default TTL over a cache without lifetime support would be a
        // silent no-op (every entry immortal); say so rather than let
        // the operator believe the TTL bounds staleness.
        if default_opts.ttl.is_some() && !cache.supports_lifetime() {
            eprintln!(
                "warning: {} has no lifetime support; the service default TTL is ignored",
                cache.name()
            );
        }
        Self {
            cache,
            senders,
            workers: std::sync::Mutex::new(workers),
            metrics,
            migrators: std::sync::Mutex::new(Vec::new()),
            default_opts,
            depth,
            stopped,
            degraded: cfg.degraded,
            shed_queue_depth: cfg.shed_queue_depth,
            faults: cfg.faults,
        }
    }

    /// Admin operation: resize the cache online to `new_capacity`.
    /// Returns `false` (and changes nothing) when the underlying cache
    /// has no resize support. On acceptance the new geometry is installed
    /// immediately and a **background migration driver** thread is
    /// spawned to pump [`Cache::resize_step`] until the split watermark
    /// covers every source set; request traffic keeps flowing throughout
    /// (reads fall through old→new, writes help migrate their own sets).
    /// The driver joins at shutdown; a second resize issued while one is
    /// migrating serializes behind it inside [`Cache::resize`].
    pub fn resize(&self, new_capacity: usize) -> bool {
        if !self.cache.supports_resize() {
            eprintln!(
                "warning: {} has no resize support; the resize admin op is refused",
                self.cache.name()
            );
            return false;
        }
        if !self.cache.resize(new_capacity) {
            return false;
        }
        self.metrics.resizes.fetch_add(1, Ordering::Relaxed);
        let cache = self.cache.clone();
        let driver = std::thread::Builder::new()
            .name("cache-resize-driver".into())
            .spawn(move || {
                while cache.resize_pending() {
                    if cache.resize_step(RESIZE_STEP_SETS) == 0 {
                        // Another thread claimed the remaining sets (or a
                        // helping put is mid-drain): don't spin hot.
                        std::thread::yield_now();
                    }
                }
            })
            .expect("spawn resize driver");
        let mut migrators = self.migrators.lock().unwrap();
        // Reap drivers whose migrations already completed, so a
        // long-lived service resized periodically (the autoscaling
        // story) holds at most the in-flight handles, not one per
        // resize ever issued.
        migrators.retain(|h| !h.is_finished());
        migrators.push(driver);
        true
    }

    /// Block until no resize migration is pending (test/admin helper; the
    /// background driver keeps making progress on its own).
    pub fn wait_for_resize(&self) {
        while self.cache.resize_pending() {
            std::thread::yield_now();
        }
    }

    /// Which worker owns a key. Same hash for singles and batches, so
    /// per-key FIFO ordering holds across both paths.
    #[inline]
    fn worker_of(&self, key: u64) -> usize {
        (hash::xxh64_u64(key, 0x40F7E4) as usize) % self.senders.len()
    }

    /// Route one request to `worker`, tracking queue depth. Fails only
    /// once the service is halted (workers hold their receivers across
    /// panics, so a live service never loses its channel).
    fn route(&self, worker: usize, req: Request) -> Result<(), ServiceError> {
        if self.stopped.load(Ordering::Acquire) {
            return Err(ServiceError::Stopped);
        }
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.senders[worker].send(req).map_err(|_| {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            ServiceError::Stopped
        })
    }

    /// Synchronous get through the service (router → worker → reply),
    /// surfacing failure instead of degrading: `Err(Stopped)` after
    /// [`CacheService::halt`], `Err(WorkerDown)` when the owning worker
    /// panicked mid-request (the supervisor restarts it, so a retry
    /// usually succeeds).
    pub fn try_get(&self, key: u64) -> Result<Option<u64>, ServiceError> {
        let (reply, rx) = channel();
        self.route(self.worker_of(key), Request::Get { key, enqueued: Instant::now(), reply })?;
        rx.recv().map_err(|_| ServiceError::WorkerDown)
    }

    /// Synchronous get through the service (router → worker → reply).
    /// Degrades to a miss (never panics) when a worker or the service is
    /// down; use [`CacheService::try_get`] to observe the failure.
    pub fn get(&self, key: u64) -> Option<u64> {
        self.try_get(key).unwrap_or_else(|_| self.degraded(None))
    }

    /// Fire-and-forget put (the common cache-fill pattern). Carries the
    /// service's default entry lifetime ([`ServiceConfig::default_ttl`]).
    /// Dropped (never a panic) when the service is down.
    pub fn put(&self, key: u64, value: u64) {
        self.put_with(key, value, self.default_opts);
    }

    /// [`CacheService::put_with`] surfacing failure instead of silently
    /// dropping the put.
    pub fn try_put_with(&self, key: u64, value: u64, opts: EntryOpts) -> Result<(), ServiceError> {
        self.route(
            self.worker_of(key),
            Request::Put { key, value, opts, enqueued: Instant::now() },
        )
    }

    /// Fire-and-forget put with explicit lifetime/weight options,
    /// overriding the service default. Dropped (never a panic) when the
    /// service is down.
    pub fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        if self.try_put_with(key, value, opts).is_err() {
            self.degraded(());
        }
    }

    /// Count one degraded convenience op and produce its miss value.
    fn degraded<T>(&self, miss: T) -> T {
        self.metrics.degraded_ops.fetch_add(1, Ordering::Relaxed);
        miss
    }

    /// Does the underlying cache store byte values? When `false`, every
    /// byte op below degrades to a miss / dropped put (the same answer a
    /// word-only cache gives in-process).
    pub fn supports_values(&self) -> bool {
        self.cache.supports_values()
    }

    /// Synchronous byte-value get through the service, surfacing failure
    /// like [`CacheService::try_get`].
    pub fn try_get_bytes(&self, key: u64) -> Result<Option<Vec<u8>>, ServiceError> {
        let (reply, rx) = channel();
        self.route(
            self.worker_of(key),
            Request::GetBytes { key, enqueued: Instant::now(), reply },
        )?;
        rx.recv().map_err(|_| ServiceError::WorkerDown)
    }

    /// Synchronous byte-value get; degrades to a miss when a worker or
    /// the service is down.
    pub fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        self.try_get_bytes(key).unwrap_or_else(|_| self.degraded(None))
    }

    /// [`CacheService::put_bytes_with`] surfacing failure instead of
    /// silently dropping the put.
    pub fn try_put_bytes_with(
        &self,
        key: u64,
        value: Vec<u8>,
        opts: EntryOpts,
    ) -> Result<(), ServiceError> {
        self.route(
            self.worker_of(key),
            Request::PutBytes { key, value, opts, enqueued: Instant::now() },
        )
    }

    /// Fire-and-forget byte-value put carrying the service's default
    /// entry lifetime. Dropped (never a panic) when the service is down.
    pub fn put_bytes(&self, key: u64, value: Vec<u8>) {
        self.put_bytes_with(key, value, self.default_opts);
    }

    /// Fire-and-forget byte-value put with explicit options.
    pub fn put_bytes_with(&self, key: u64, value: Vec<u8>, opts: EntryOpts) {
        if self.try_put_bytes_with(key, value, opts).is_err() {
            self.degraded(());
        }
    }

    /// Byte-value batched get with scatter/gather, surfacing failure
    /// like [`CacheService::try_get_batch`].
    pub fn try_get_bytes_batch(
        &self,
        keys: Vec<u64>,
    ) -> Result<Vec<Option<Vec<u8>>>, ServiceError> {
        let n = keys.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.senders.len();
        let mut sub_keys: Vec<Vec<u64>> = vec![Vec::new(); workers];
        let mut sub_positions: Vec<Vec<usize>> = vec![Vec::new(); workers];
        for (pos, &key) in keys.iter().enumerate() {
            let w = self.worker_of(key);
            sub_keys[w].push(key);
            sub_positions[w].push(pos);
        }
        let (reply, rx) = channel();
        let mut outstanding = 0usize;
        for (w, sub) in sub_keys.iter_mut().enumerate() {
            if sub.is_empty() {
                continue;
            }
            outstanding += 1;
            self.route(
                w,
                Request::GetBytesBatch {
                    keys: std::mem::take(sub),
                    enqueued: Instant::now(),
                    worker: w,
                    reply: reply.clone(),
                },
            )?;
        }
        drop(reply);
        let mut out: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
        for _ in 0..outstanding {
            let (w, values) = rx.recv().map_err(|_| ServiceError::WorkerDown)?;
            debug_assert_eq!(values.len(), sub_positions[w].len());
            for (&pos, value) in sub_positions[w].iter().zip(values) {
                out[pos] = value;
            }
        }
        Ok(out)
    }

    /// Byte-value batched get; degrades to all-misses when a worker or
    /// the service is down.
    pub fn get_bytes_batch(&self, keys: Vec<u64>) -> Vec<Option<Vec<u8>>> {
        let n = keys.len();
        self.try_get_bytes_batch(keys)
            .unwrap_or_else(|_| self.degraded((0..n).map(|_| None).collect()))
    }

    /// [`CacheService::put_bytes_batch`] surfacing failure instead of
    /// silently dropping the remainder of the batch.
    pub fn try_put_bytes_batch_with(
        &self,
        items: Vec<(u64, Vec<u8>)>,
        opts: EntryOpts,
    ) -> Result<(), ServiceError> {
        if items.is_empty() {
            return Ok(());
        }
        let workers = self.senders.len();
        let mut sub: Vec<Vec<(u64, Vec<u8>)>> = (0..workers).map(|_| Vec::new()).collect();
        for (key, value) in items {
            sub[self.worker_of(key)].push((key, value));
        }
        for (w, items) in sub.into_iter().enumerate() {
            if items.is_empty() {
                continue;
            }
            self.route(w, Request::PutBytesBatch { items, opts, enqueued: Instant::now() })?;
        }
        Ok(())
    }

    /// Batched fire-and-forget byte-value put, scattered by owning
    /// worker and carrying the service's default entry lifetime.
    pub fn put_bytes_batch(&self, items: Vec<(u64, Vec<u8>)>) {
        if self.try_put_bytes_batch_with(items, self.default_opts).is_err() {
            self.degraded(());
        }
    }

    /// Batched get with scatter/gather, surfacing failure:
    /// `Err(Stopped)` when the service is halted before any sub-batch is
    /// sent, `Err(WorkerDown)` when a worker panicked before answering
    /// its sub-batch (partial results are discarded — the caller decides
    /// whether to retry or degrade).
    pub fn try_get_batch(&self, keys: Vec<u64>) -> Result<Vec<Option<u64>>, ServiceError> {
        let n = keys.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.senders.len();
        // Scatter: group keys by owning worker, remembering each key's
        // position in the input batch.
        let mut sub_keys: Vec<Vec<u64>> = vec![Vec::new(); workers];
        let mut sub_positions: Vec<Vec<usize>> = vec![Vec::new(); workers];
        for (pos, &key) in keys.iter().enumerate() {
            let w = self.worker_of(key);
            sub_keys[w].push(key);
            sub_positions[w].push(pos);
        }
        let (reply, rx) = channel();
        let mut outstanding = 0usize;
        for (w, sub) in sub_keys.iter_mut().enumerate() {
            if sub.is_empty() {
                continue;
            }
            outstanding += 1;
            self.route(
                w,
                Request::GetBatch {
                    keys: std::mem::take(sub),
                    enqueued: Instant::now(),
                    worker: w,
                    reply: reply.clone(),
                },
            )?;
        }
        drop(reply);
        // Gather: sub-results arrive in any order; positions restore the
        // input order exactly. A worker that panics mid-batch drops its
        // reply clone without sending; once every live sender is gone
        // `recv` errs and the missing sub-batch surfaces as WorkerDown.
        let mut out = vec![None; n];
        for _ in 0..outstanding {
            let (w, values) = rx.recv().map_err(|_| ServiceError::WorkerDown)?;
            debug_assert_eq!(values.len(), sub_positions[w].len());
            for (&pos, value) in sub_positions[w].iter().zip(values) {
                out[pos] = value;
            }
        }
        Ok(out)
    }

    /// Batched get with scatter/gather: keys are split by owning worker,
    /// every involved worker probes its sub-batch concurrently (through
    /// the cache's batched path), and the partial results are stitched
    /// back so `result[i]` always answers `keys[i]`. One queue crossing
    /// per worker instead of one per key. Degrades to all-misses (never
    /// panics) when a worker or the service is down; use
    /// [`CacheService::try_get_batch`] to observe the failure.
    pub fn get_batch(&self, keys: Vec<u64>) -> Vec<Option<u64>> {
        let n = keys.len();
        self.try_get_batch(keys).unwrap_or_else(|_| self.degraded(vec![None; n]))
    }

    /// Batched fire-and-forget put, scattered by owning worker like
    /// [`CacheService::get_batch`]. Carries the service's default entry
    /// lifetime; use [`CacheService::put_batch_with`] to override it.
    /// Dropped (never a panic) when the service is down.
    pub fn put_batch(&self, items: Vec<(u64, u64)>) {
        self.put_batch_with(items, self.default_opts);
    }

    /// [`CacheService::put_batch_with`] surfacing failure instead of
    /// silently dropping the remainder of the batch.
    pub fn try_put_batch_with(
        &self,
        items: Vec<(u64, u64)>,
        opts: EntryOpts,
    ) -> Result<(), ServiceError> {
        if items.is_empty() {
            return Ok(());
        }
        let workers = self.senders.len();
        let mut sub: Vec<Vec<(u64, u64)>> = vec![Vec::new(); workers];
        for &(key, value) in &items {
            sub[self.worker_of(key)].push((key, value));
        }
        for (w, items) in sub.into_iter().enumerate() {
            if items.is_empty() {
                continue;
            }
            self.route(w, Request::PutBatch { items, opts, enqueued: Instant::now() })?;
        }
        Ok(())
    }

    /// [`CacheService::put_batch`] with explicit lifetime/weight options
    /// applied to every item of the batch. Dropped (never a panic) when
    /// the service is down.
    pub fn put_batch_with(&self, items: Vec<(u64, u64)>, opts: EntryOpts) {
        if self.try_put_batch_with(items, opts).is_err() {
            self.degraded(());
        }
    }

    /// Requests currently queued across all worker channels.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Should new wire requests be shed right now? True when the queued
    /// request count exceeds [`ServiceConfig::shed_queue_depth`] (when
    /// enabled), or when a `shed_test` fault is armed.
    pub fn overloaded(&self) -> bool {
        if let Some(f) = &self.faults {
            if f.shed_forced() {
                return true;
            }
        }
        self.shed_queue_depth > 0 && self.queue_depth() > self.shed_queue_depth
    }

    /// The configured degraded-mode policy (the wire front end consults
    /// this to pick between serving misses and protocol errors).
    pub fn degraded_policy(&self) -> DegradedPolicy {
        self.degraded
    }

    /// Has [`CacheService::halt`] (or shutdown) been called?
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Service-level metrics (latencies include queueing).
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The underlying cache (for direct, non-routed access in tests).
    pub fn cache(&self) -> &Arc<dyn Cache> {
        &self.cache
    }

    /// The entry options un-optioned puts receive (from
    /// [`ServiceConfig::default_ttl`]). The wire front end uses this so
    /// a plain `set` stores exactly like an in-process `put`.
    pub fn default_opts(&self) -> EntryOpts {
        self.default_opts
    }

    /// Stop all workers (and any background migration drivers) and join
    /// them.
    pub fn shutdown(self) {
        self.halt();
    }

    /// [`CacheService::shutdown`] callable through a shared reference
    /// (the wire front end holds the service in an `Arc`). Idempotent;
    /// after it returns every op degrades per [`DegradedPolicy`] instead
    /// of panicking.
    pub fn halt(&self) {
        // Release-publish the stop before the Shutdown messages so a
        // restarting supervisor that catches a concurrent panic observes
        // it and exits instead of re-entering its loop.
        self.stopped.store(true, Ordering::Release);
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        for h in self.migrators.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for CacheService {
    fn drop(&mut self) {
        self.halt();
    }
}

fn worker_loop(
    rx: &Receiver<Request>,
    cache: &Arc<dyn Cache>,
    metrics: &Arc<ServiceMetrics>,
    depth: &AtomicUsize,
    faults: Option<&FaultPlan>,
) {
    while let Ok(req) = rx.recv() {
        if matches!(req, Request::Shutdown) {
            return;
        }
        // Dequeued: this request no longer occupies the shed budget
        // (Shutdown messages are never counted, see `route`).
        depth.fetch_sub(1, Ordering::Relaxed);
        if let Some(f) = faults {
            if f.worker_should_panic() {
                // The panic unwinds out of this frame holding `req` — the
                // reply sender drops unsent, so the blocked caller sees
                // WorkerDown, and the supervisor restarts the loop.
                panic!("injected fault: worker_panic");
            }
        }
        match req {
            Request::Get { key, enqueued, reply } => {
                let value = cache.get(key);
                metrics.ops.gets.fetch_add(1, Ordering::Relaxed);
                if value.is_some() {
                    metrics.ops.hits.fetch_add(1, Ordering::Relaxed);
                }
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send(value);
            }
            Request::Put { key, value, opts, enqueued } => {
                if opts.is_plain() {
                    cache.put(key, value);
                } else {
                    cache.put_with(key, value, opts);
                }
                metrics.ops.puts.fetch_add(1, Ordering::Relaxed);
                metrics.put_latency.record(enqueued.elapsed().as_nanos() as u64);
            }
            Request::GetBatch { keys, enqueued, worker, reply } => {
                let mut values = Vec::with_capacity(keys.len());
                cache.get_batch(&keys, &mut values);
                let hits = values.iter().filter(|v| v.is_some()).count() as u64;
                metrics.ops.gets.fetch_add(keys.len() as u64, Ordering::Relaxed);
                metrics.ops.hits.fetch_add(hits, Ordering::Relaxed);
                // One latency sample per sub-batch: the latency a batched
                // client actually observes from this worker.
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send((worker, values));
            }
            Request::PutBatch { items, opts, enqueued } => {
                if opts.is_plain() {
                    cache.put_batch(&items);
                } else {
                    let entries: Vec<BatchEntry> = items
                        .iter()
                        .map(|&(key, value)| BatchEntry::new(key, value, opts))
                        .collect();
                    cache.put_batch_with(&entries);
                }
                metrics.ops.puts.fetch_add(items.len() as u64, Ordering::Relaxed);
                metrics.put_latency.record(enqueued.elapsed().as_nanos() as u64);
            }
            Request::GetBytes { key, enqueued, reply } => {
                let value = cache.get_bytes(key);
                metrics.ops.gets.fetch_add(1, Ordering::Relaxed);
                if value.is_some() {
                    metrics.ops.hits.fetch_add(1, Ordering::Relaxed);
                }
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send(value);
            }
            Request::PutBytes { key, value, opts, enqueued } => {
                cache.put_bytes_with(key, &value, opts);
                metrics.ops.puts.fetch_add(1, Ordering::Relaxed);
                metrics.put_latency.record(enqueued.elapsed().as_nanos() as u64);
            }
            Request::GetBytesBatch { keys, enqueued, worker, reply } => {
                // No batched byte probe on the trait (handles resolve
                // per-key through the slab anyway): the worker loops, so
                // the batch still costs one queue crossing, not one per
                // key.
                let values: Vec<Option<Vec<u8>>> =
                    keys.iter().map(|&k| cache.get_bytes(k)).collect();
                let hits = values.iter().filter(|v| v.is_some()).count() as u64;
                metrics.ops.gets.fetch_add(keys.len() as u64, Ordering::Relaxed);
                metrics.ops.hits.fetch_add(hits, Ordering::Relaxed);
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send((worker, values));
            }
            Request::PutBytesBatch { items, opts, enqueued } => {
                for (key, value) in &items {
                    cache.put_bytes_with(*key, value, opts);
                }
                metrics.ops.puts.fetch_add(items.len() as u64, Ordering::Relaxed);
                metrics.put_latency.record(enqueued.elapsed().as_nanos() as u64);
            }
            Request::Shutdown => unreachable!("handled before dequeue accounting"),
        }
    }
}

/// A tiny helper for examples: run `clients` client threads, each issuing
/// `requests` get-or-fill operations against the service, and return the
/// total wall-clock seconds.
pub fn drive_clients(
    service: &CacheService,
    clients: usize,
    requests: usize,
    keyspace: u64,
    seed: u64,
) -> f64 {
    let start = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let service = &*service;
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(seed ^ c as u64);
                let zipf = crate::util::rng::Zipf::new(keyspace, 0.99);
                for _ in 0..requests {
                    let key = zipf.sample(&mut rng);
                    if service.get(key).is_none() {
                        service.put(key, key.wrapping_mul(31));
                    }
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

/// Like [`drive_clients`] but each client issues `requests / batch`
/// batched gets of size `batch`, filling misses with a batched put.
/// Returns the total wall-clock seconds.
pub fn drive_clients_batched(
    service: &CacheService,
    clients: usize,
    requests: usize,
    batch: usize,
    keyspace: u64,
    seed: u64,
) -> f64 {
    let batch = batch.max(1);
    let start = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let service = &*service;
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(seed ^ (c as u64) << 8);
                let zipf = crate::util::rng::Zipf::new(keyspace, 0.99);
                let rounds = requests.div_ceil(batch);
                for _ in 0..rounds {
                    let keys: Vec<u64> =
                        (0..batch).map(|_| zipf.sample(&mut rng)).collect();
                    let results = service.get_batch(keys.clone());
                    let fills: Vec<(u64, u64)> = keys
                        .iter()
                        .zip(&results)
                        .filter(|(_, r)| r.is_none())
                        .map(|(&k, _)| (k, k.wrapping_mul(31)))
                        .collect();
                    if !fills.is_empty() {
                        service.put_batch(fills);
                    }
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;

    fn service(workers: usize) -> CacheService {
        let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        CacheService::start(cache, ServiceConfig { workers, ..Default::default() })
    }

    #[test]
    fn get_put_round_trip() {
        let s = service(2);
        assert_eq!(s.get(5), None);
        s.put(5, 55);
        // Put is async; poll briefly.
        let mut got = None;
        for _ in 0..1000 {
            got = s.get(5);
            if got.is_some() {
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(got, Some(55));
        assert!(s.metrics().ops.gets.load(Ordering::Relaxed) >= 2);
        s.shutdown();
    }

    #[test]
    fn batch_get() {
        let s = service(2);
        for k in 0..10u64 {
            s.put(k, k + 100);
        }
        // Ensure puts landed (route-ordered per key, so poll one key per worker).
        for k in 0..10u64 {
            for _ in 0..1000 {
                if s.get(k).is_some() {
                    break;
                }
                std::thread::yield_now();
            }
        }
        let out = s.get_batch((0..10u64).collect());
        assert_eq!(out.len(), 10);
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, Some(k as u64 + 100));
        }
        s.shutdown();
    }

    #[test]
    fn batch_get_scatters_across_workers() {
        // With 4 workers and 100 distinct keys, the hash router must
        // involve more than one worker; results still arrive input-ordered.
        // (100 keys over 128 sets stay clear of the 8-way eviction bound.)
        let s = service(4);
        for k in 0..100u64 {
            s.put(k, k * 2);
        }
        for k in 0..100u64 {
            assert_eq!(s.get(k), Some(k * 2)); // per-key FIFO: put landed
        }
        let keys: Vec<u64> = (0..100u64).rev().collect();
        let out = s.get_batch(keys.clone());
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(out[i], Some(k * 2), "position {i}");
        }
        s.shutdown();
    }

    #[test]
    fn batch_put_then_batch_get() {
        let s = service(3);
        let items: Vec<(u64, u64)> = (0..100u64).map(|k| (k, k + 7)).collect();
        s.put_batch(items.clone());
        // Per-key ordering: a single get of each key flushes its worker.
        for &(k, v) in &items {
            let mut got = None;
            for _ in 0..1000 {
                got = s.get(k);
                if got.is_some() {
                    break;
                }
                std::thread::yield_now();
            }
            assert_eq!(got, Some(v), "key {k}");
        }
        let out = s.get_batch(items.iter().map(|&(k, _)| k).collect());
        for (i, &(_, v)) in items.iter().enumerate() {
            assert_eq!(out[i], Some(v));
        }
        assert!(s.metrics().ops.puts.load(Ordering::Relaxed) >= 100);
        s.shutdown();
    }

    #[test]
    fn empty_batches_are_noops() {
        let s = service(2);
        assert!(s.get_batch(Vec::new()).is_empty());
        s.put_batch(Vec::new());
        s.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let s = service(4);
        let secs = drive_clients(&s, 4, 2_000, 4096, 11);
        assert!(secs > 0.0);
        let m = s.metrics();
        assert!(m.ops.gets.load(Ordering::Relaxed) >= 8_000);
        assert!(m.get_latency.count() > 0);
        assert!(m.ops.hit_ratio() > 0.1, "zipf working set should yield hits");
        s.shutdown();
    }

    #[test]
    fn concurrent_batched_clients() {
        let s = service(4);
        let secs = drive_clients_batched(&s, 4, 2_000, 32, 4096, 12);
        assert!(secs > 0.0);
        let m = s.metrics();
        assert!(m.ops.gets.load(Ordering::Relaxed) >= 8_000);
        assert!(m.ops.hit_ratio() > 0.1, "zipf working set should yield hits");
        s.shutdown();
    }

    #[test]
    fn admission_wrapped_service_serves() {
        let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let s = CacheService::start(
            cache,
            ServiceConfig { workers: 2, admission: AdmissionMode::TinyLfu, ..Default::default() },
        );
        assert_eq!(s.cache().name(), "KW-WFSC+TLFU");
        let secs = drive_clients(&s, 2, 2_000, 2048, 3);
        assert!(secs > 0.0);
        // The Zipf head builds frequency through the routed gets, gets
        // admitted, and starts hitting.
        assert!(
            s.metrics().ops.hit_ratio() > 0.05,
            "no hits through admission: {}",
            s.metrics().ops.hit_ratio()
        );
        s.shutdown();
    }

    #[test]
    fn resize_admin_op_migrates_in_the_background() {
        let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let s = CacheService::start(cache, ServiceConfig { workers: 2, ..Default::default() });
        // 60 keys over 128 sets: no set ever overflows, so the grow must
        // preserve every one of them.
        for k in 0..60u64 {
            s.put(k, k + 1);
        }
        for k in 0..60u64 {
            assert_eq!(s.get(k), Some(k + 1)); // per-key FIFO: puts landed
        }
        assert!(s.resize(2048));
        assert_eq!(s.metrics().resizes.load(Ordering::Relaxed), 1);
        s.wait_for_resize();
        assert_eq!(s.cache().capacity(), 2048);
        for k in 0..60u64 {
            assert_eq!(s.get(k), Some(k + 1), "key {k} lost across the grow");
        }
        s.shutdown();
        // A fixed-geometry cache refuses the admin op instead of lying.
        let fixed: Arc<dyn Cache> = Arc::new(crate::products::CaffeineLike::new(256));
        let s2 = CacheService::start(fixed, ServiceConfig { workers: 1, ..Default::default() });
        assert!(!s2.resize(512));
        assert_eq!(s2.metrics().resizes.load(Ordering::Relaxed), 0);
        s2.shutdown();
    }

    #[test]
    fn byte_values_route_and_scatter() {
        let cache: Arc<dyn Cache> =
            Arc::new(KwWfsc::with_value_store(4096, 8, Policy::Lru, 1 << 22));
        let s = CacheService::start(cache, ServiceConfig { workers: 3, ..Default::default() });
        assert!(s.supports_values());
        // Per-key FIFO: the get queues behind the put on the same worker.
        s.put_bytes(1, b"routed blob".to_vec());
        assert_eq!(s.get_bytes(1).as_deref(), Some(&b"routed blob"[..]));
        assert_eq!(s.get_bytes(2), None);
        // Scattered byte batches come back input-ordered.
        let items: Vec<(u64, Vec<u8>)> =
            (0..50u64).map(|k| (k, vec![k as u8; 1 + k as usize])).collect();
        s.put_bytes_batch(items.clone());
        for &(k, _) in &items {
            assert!(s.get_bytes(k).is_some(), "key {k}"); // flush worker FIFO
        }
        let out = s.get_bytes_batch((0..50u64).rev().collect());
        for (i, k) in (0..50u64).rev().enumerate() {
            assert_eq!(out[i].as_deref(), Some(&vec![k as u8; 1 + k as usize][..]), "key {k}");
        }
        s.shutdown();
    }

    #[test]
    fn byte_ops_on_word_cache_degrade_to_misses() {
        let s = service(2);
        assert!(!s.supports_values());
        s.put_bytes(1, b"dropped".to_vec());
        assert_eq!(s.get_bytes(1), None);
        assert!(s.get_bytes_batch(vec![1, 2]).iter().all(|v| v.is_none()));
        s.shutdown();
    }

    #[test]
    fn drop_joins_workers() {
        let s = service(2);
        s.put(1, 1);
        drop(s); // must not hang
    }

    #[test]
    fn default_ttl_applies_to_routed_puts() {
        use std::time::Duration;
        let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let s = CacheService::start(
            cache,
            ServiceConfig { workers: 2, default_ttl: Some(Duration::ZERO), ..Default::default() },
        );
        // Per-key FIFO: the get queues behind the put on the same worker.
        s.put(5, 55);
        assert_eq!(s.get(5), None, "default-TTL'd entries expire (TTL 0 = at birth)");
        // Explicit options override the service default.
        s.put_with(6, 66, crate::lifetime::EntryOpts::default());
        assert_eq!(s.get(6), Some(66));
        // Batched puts inherit the default too.
        s.put_batch(vec![(7, 77), (8, 88)]);
        assert_eq!(s.get(7), None);
        assert_eq!(s.get(8), None);
        s.put_batch_with(vec![(9, 99)], crate::lifetime::EntryOpts::default());
        assert_eq!(s.get(9), Some(99));
        s.shutdown();
    }
}
