//! Worker-pool cache service with key-hash routing.

use crate::metrics::{LatencyHistogram, OpCounters};
use crate::util::hash;
use crate::Cache;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads executing cache operations.
    pub workers: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 4 }
    }
}

/// Shared service metrics.
#[derive(Default)]
pub struct ServiceMetrics {
    pub get_latency: LatencyHistogram,
    pub put_latency: LatencyHistogram,
    pub ops: OpCounters,
}

impl ServiceMetrics {
    pub fn report(&self) -> String {
        format!(
            "gets={} puts={} hit_ratio={:.3}\n  get latency: {}\n  put latency: {}",
            self.ops.gets.load(Ordering::Relaxed),
            self.ops.puts.load(Ordering::Relaxed),
            self.ops.hit_ratio(),
            self.get_latency.summary(),
            self.put_latency.summary(),
        )
    }
}

enum Request {
    Get { key: u64, enqueued: Instant, reply: Sender<Option<u64>> },
    Put { key: u64, value: u64, enqueued: Instant },
    GetBatch { keys: Vec<u64>, enqueued: Instant, reply: Sender<Vec<Option<u64>>> },
    Shutdown,
}

/// A running cache service: router + worker pool over a shared cache.
pub struct CacheService {
    cache: Arc<dyn Cache>,
    senders: Vec<Sender<Request>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<ServiceMetrics>,
}

impl CacheService {
    /// Start `cfg.workers` workers over `cache`.
    pub fn start(cache: Arc<dyn Cache>, cfg: ServiceConfig) -> Self {
        assert!(cfg.workers >= 1);
        let metrics = Arc::new(ServiceMetrics::default());
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = channel::<Request>();
            senders.push(tx);
            let cache = cache.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cache-worker-{w}"))
                    .spawn(move || worker_loop(rx, cache, metrics))
                    .expect("spawn worker"),
            );
        }
        Self { cache, senders, workers, metrics }
    }

    #[inline]
    fn route(&self, key: u64) -> &Sender<Request> {
        let w = (hash::xxh64_u64(key, 0x40F7E4) as usize) % self.senders.len();
        &self.senders[w]
    }

    /// Synchronous get through the service (router → worker → reply).
    pub fn get(&self, key: u64) -> Option<u64> {
        let (reply, rx) = channel();
        self.route(key)
            .send(Request::Get { key, enqueued: Instant::now(), reply })
            .expect("service stopped");
        rx.recv().expect("worker dropped reply")
    }

    /// Fire-and-forget put (the common cache-fill pattern).
    pub fn put(&self, key: u64, value: u64) {
        self.route(key)
            .send(Request::Put { key, value, enqueued: Instant::now() })
            .expect("service stopped");
    }

    /// Batched get: one round trip for many keys (all executed by the
    /// batch's routing worker; batching amortizes queue crossings exactly
    /// like batched serving systems do).
    pub fn get_batch(&self, keys: Vec<u64>) -> Vec<Option<u64>> {
        if keys.is_empty() {
            return Vec::new();
        }
        let (reply, rx) = channel();
        self.route(keys[0])
            .send(Request::GetBatch { keys, enqueued: Instant::now(), reply })
            .expect("service stopped");
        rx.recv().expect("worker dropped reply")
    }

    /// Service-level metrics (latencies include queueing).
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The underlying cache (for direct, non-routed access in tests).
    pub fn cache(&self) -> &Arc<dyn Cache> {
        &self.cache
    }

    /// Stop all workers and join them.
    pub fn shutdown(mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for CacheService {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Request>, cache: Arc<dyn Cache>, metrics: Arc<ServiceMetrics>) {
    while let Ok(req) = rx.recv() {
        match req {
            Request::Get { key, enqueued, reply } => {
                let value = cache.get(key);
                metrics.ops.gets.fetch_add(1, Ordering::Relaxed);
                if value.is_some() {
                    metrics.ops.hits.fetch_add(1, Ordering::Relaxed);
                }
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send(value);
            }
            Request::Put { key, value, enqueued } => {
                cache.put(key, value);
                metrics.ops.puts.fetch_add(1, Ordering::Relaxed);
                metrics.put_latency.record(enqueued.elapsed().as_nanos() as u64);
            }
            Request::GetBatch { keys, enqueued, reply } => {
                let mut out = Vec::with_capacity(keys.len());
                for key in keys {
                    let value = cache.get(key);
                    metrics.ops.gets.fetch_add(1, Ordering::Relaxed);
                    if value.is_some() {
                        metrics.ops.hits.fetch_add(1, Ordering::Relaxed);
                    }
                    out.push(value);
                }
                metrics.get_latency.record(enqueued.elapsed().as_nanos() as u64);
                let _ = reply.send(out);
            }
            Request::Shutdown => return,
        }
    }
}

/// A tiny helper for examples: run `clients` client threads, each issuing
/// `requests` get-or-fill operations against the service, and return the
/// total wall-clock seconds.
pub fn drive_clients(
    service: &CacheService,
    clients: usize,
    requests: usize,
    keyspace: u64,
    seed: u64,
) -> f64 {
    let start = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let service = &*service;
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(seed ^ c as u64);
                let zipf = crate::util::rng::Zipf::new(keyspace, 0.99);
                for _ in 0..requests {
                    let key = zipf.sample(&mut rng);
                    if service.get(key).is_none() {
                        service.put(key, key.wrapping_mul(31));
                    }
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;

    fn service(workers: usize) -> CacheService {
        let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        CacheService::start(cache, ServiceConfig { workers })
    }

    #[test]
    fn get_put_round_trip() {
        let s = service(2);
        assert_eq!(s.get(5), None);
        s.put(5, 55);
        // Put is async; poll briefly.
        let mut got = None;
        for _ in 0..1000 {
            got = s.get(5);
            if got.is_some() {
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(got, Some(55));
        assert!(s.metrics().ops.gets.load(Ordering::Relaxed) >= 2);
        s.shutdown();
    }

    #[test]
    fn batch_get() {
        let s = service(2);
        for k in 0..10u64 {
            s.put(k, k + 100);
        }
        // Ensure puts landed (route-ordered per key, so poll one key per worker).
        for k in 0..10u64 {
            for _ in 0..1000 {
                if s.get(k).is_some() {
                    break;
                }
                std::thread::yield_now();
            }
        }
        let out = s.get_batch((0..10u64).collect());
        assert_eq!(out.len(), 10);
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, Some(k as u64 + 100));
        }
        s.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let s = service(4);
        let secs = drive_clients(&s, 4, 2_000, 4096, 11);
        assert!(secs > 0.0);
        let m = s.metrics();
        assert!(m.ops.gets.load(Ordering::Relaxed) >= 8_000);
        assert!(m.get_latency.count() > 0);
        assert!(m.ops.hit_ratio() > 0.1, "zipf working set should yield hits");
        s.shutdown();
    }

    #[test]
    fn drop_joins_workers() {
        let s = service(2);
        s.put(1, 1);
        drop(s); // must not hang
    }
}
