//! The paper's experiment registry: one entry per evaluation figure,
//! mapping it to the trace model, cache size and series that regenerate
//! it. The bench binaries (`rust/benches/`) iterate this table; DESIGN.md
//! §Per-experiment index mirrors it.

use crate::policy::Policy;

/// Which hit-ratio subfigure-(d) series a figure shows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtraSeries {
    /// Subfigure (d) shows plain Hyperbolic.
    Hyperbolic,
    /// Subfigure (d) shows Hyperbolic + TinyLFU.
    HyperbolicTlfu,
    /// No extra series on this figure.
    None,
}

/// A hit-ratio figure (Figures 4–13): four subfigures on one trace.
#[derive(Debug, Clone)]
pub struct HitRatioFigure {
    /// Figure id (fig4..fig13).
    pub id: &'static str,
    /// Trace model name (see `trace::paper`).
    pub trace: &'static str,
    /// Cache sizes for the x-axis sweep.
    pub sizes: &'static [usize],
    /// Which subfigure-(d) series the figure shows.
    pub extra: ExtraSeries,
}

/// All hit-ratio figures.
#[rustfmt::skip]
pub const HITRATIO_FIGURES: &[HitRatioFigure] = &[
    HitRatioFigure { id: "fig4", trace: "wiki_a", sizes: &[512, 2048, 8192], extra: ExtraSeries::Hyperbolic },
    HitRatioFigure { id: "fig5", trace: "p8", sizes: &[1024, 4096, 16384], extra: ExtraSeries::None },
    HitRatioFigure { id: "fig6", trace: "p12", sizes: &[4096, 16384, 65536], extra: ExtraSeries::Hyperbolic },
    HitRatioFigure { id: "fig7", trace: "s1", sizes: &[16384, 65536, 262144], extra: ExtraSeries::None },
    HitRatioFigure { id: "fig8", trace: "s3", sizes: &[16384, 65536, 262144], extra: ExtraSeries::HyperbolicTlfu },
    HitRatioFigure { id: "fig9", trace: "oltp", sizes: &[512, 2048, 8192], extra: ExtraSeries::None },
    HitRatioFigure { id: "fig10", trace: "multi2", sizes: &[1024, 4096, 16384], extra: ExtraSeries::None },
    HitRatioFigure { id: "fig11", trace: "multi3", sizes: &[1024, 4096, 16384], extra: ExtraSeries::None },
    HitRatioFigure { id: "fig12", trace: "ds1", sizes: &[16384, 65536, 262144], extra: ExtraSeries::Hyperbolic },
    HitRatioFigure { id: "fig13", trace: "w3", sizes: &[16384, 65536, 262144], extra: ExtraSeries::None },
];

/// A trace-replay throughput figure (Figures 14–26).
#[derive(Debug, Clone)]
pub struct ThroughputFigure {
    /// Figure id (fig14..fig26).
    pub id: &'static str,
    /// Trace model name (see `trace::paper`).
    pub trace: &'static str,
    /// Cache size from the figure caption (2^11 / 2^17 / 2^19).
    pub capacity: usize,
    /// Paper run duration in seconds (we scale down; see benches).
    pub paper_duration_s: u32,
    /// Which platform the paper ran it on (reporting only).
    pub platform: &'static str,
}

/// All trace-replay throughput figures.
#[rustfmt::skip]
pub const THROUGHPUT_FIGURES: &[ThroughputFigure] = &[
    ThroughputFigure { id: "fig14", trace: "f1", capacity: 1 << 11, paper_duration_s: 1, platform: "AMD" },
    ThroughputFigure { id: "fig15", trace: "s3", capacity: 1 << 19, paper_duration_s: 4, platform: "AMD" },
    ThroughputFigure { id: "fig16", trace: "s1", capacity: 1 << 19, paper_duration_s: 4, platform: "AMD" },
    ThroughputFigure { id: "fig17", trace: "wiki_a", capacity: 1 << 11, paper_duration_s: 1, platform: "AMD" },
    ThroughputFigure { id: "fig18", trace: "oltp", capacity: 1 << 11, paper_duration_s: 1, platform: "AMD" },
    ThroughputFigure { id: "fig19", trace: "f2", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
    ThroughputFigure { id: "fig20", trace: "w3", capacity: 1 << 19, paper_duration_s: 4, platform: "Intel" },
    ThroughputFigure { id: "fig21", trace: "multi1", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
    ThroughputFigure { id: "fig22", trace: "multi2", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
    ThroughputFigure { id: "fig23", trace: "multi3", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
    ThroughputFigure { id: "fig24", trace: "sprite", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
    ThroughputFigure { id: "fig25", trace: "p12", capacity: 1 << 17, paper_duration_s: 2, platform: "Intel" },
    ThroughputFigure { id: "fig26", trace: "wiki_b", capacity: 1 << 11, paper_duration_s: 1, platform: "Intel" },
];

/// A synthetic-mix throughput figure (Figures 27–30).
#[derive(Debug, Clone)]
pub struct SyntheticFigure {
    /// Figure id (fig27..fig30).
    pub id: &'static str,
    /// Mix label as the paper prints it.
    pub label: &'static str,
    /// gets per put; None = all-miss (27) / all-hit (28) special cases.
    pub gets_per_put: Option<u32>,
    /// True for the 100%-miss special case (Figure 27).
    pub all_miss: bool,
}

/// All synthetic figures (cache size 2^21 in the paper).
pub const SYNTHETIC_FIGURES: &[SyntheticFigure] = &[
    SyntheticFigure { id: "fig27", label: "100% miss", gets_per_put: None, all_miss: true },
    SyntheticFigure { id: "fig28", label: "100% hit", gets_per_put: None, all_miss: false },
    SyntheticFigure { id: "fig29", label: "95% hit", gets_per_put: Some(19), all_miss: false },
    SyntheticFigure { id: "fig30", label: "90% hit", gets_per_put: Some(9), all_miss: false },
];

/// A batched-access throughput figure (the batching extension, not from
/// the paper): Mops/s and per-batch latency vs `get_batch` size, for the
/// k-way variants over a resident working set. `benches/batched.rs`
/// iterates this table; the `kway batch` subcommand sweeps the same
/// dimension interactively.
#[derive(Debug, Clone)]
pub struct BatchedFigure {
    /// Figure id (figB*).
    pub id: &'static str,
    /// Keys per `get_batch` call.
    pub batch: usize,
}

/// All batched figures (batch 1 isolates the batched-path overhead; the
/// scalar one-by-one baseline is printed alongside by the bench).
pub const BATCHED_FIGURES: &[BatchedFigure] = &[
    BatchedFigure { id: "figB1", batch: 1 },
    BatchedFigure { id: "figB8", batch: 8 },
    BatchedFigure { id: "figB32", batch: 32 },
    BatchedFigure { id: "figB128", batch: 128 },
];

/// An admission-throughput figure (the TinyLFU-admission extension, not
/// from the paper): trace-replay Mops/s vs thread count for the three
/// k-way variants with and without TinyLFU admission, against the
/// Caffeine-like baseline (whose W-TinyLFU admission is built in).
/// `benches/admission.rs` iterates this table; `kway throughput
/// --admission tlfu` sweeps the same dimension interactively.
#[derive(Debug, Clone)]
pub struct AdmissionFigure {
    /// Figure id (figT*).
    pub id: &'static str,
    /// Trace model name (see `trace::paper`).
    pub trace: &'static str,
    /// Cache size (paper-style power of two).
    pub capacity: usize,
    /// Eviction policy the k-way variants run under admission — figT1/3
    /// are the concurrent realizations of the paper's subfigure (b)
    /// "LFU + TinyLFU" and subfigure (d) "Hyperbolic + TinyLFU".
    pub policy: Policy,
}

/// All admission figures.
#[rustfmt::skip]
pub const ADMISSION_FIGURES: &[AdmissionFigure] = &[
    AdmissionFigure { id: "figT1", trace: "oltp", capacity: 1 << 11, policy: Policy::Lfu },
    AdmissionFigure { id: "figT2", trace: "wiki_a", capacity: 1 << 11, policy: Policy::Lru },
    AdmissionFigure { id: "figT3", trace: "multi2", capacity: 1 << 11, policy: Policy::Hyperbolic },
];

/// An expiration / weighted-capacity figure (the lifetime extension, not
/// from the paper): the [`crate::throughput::Workload::Expiring`]
/// get-or-fill loop under a given TTL and weight distribution, for the
/// three k-way variants against the sampled baseline.
/// `benches/expiry.rs` iterates this table; `kway synthetic --workload
/// expiring --ttl ... --weight-dist ...` sweeps the same dimension
/// interactively.
#[derive(Debug, Clone)]
pub struct ExpiryFigure {
    /// Figure id (figE*).
    pub id: &'static str,
    /// TTL stamped on every fill, in milliseconds; 0 = immortal (the
    /// baseline row, which must be bit-identical to the pre-lifetime
    /// path).
    pub ttl_ms: u64,
    /// Weight distribution spec (parsed by
    /// [`crate::lifetime::WeightDist::parse`]).
    pub weight_dist: &'static str,
}

/// All expiration/weighted figures. The TTL sweep brackets the expected
/// re-reference interval of the expiring workload (entries die between
/// touches at 50 ms, mostly survive at 1 s), and the weighted rows rerun
/// the immortal and 250 ms points under Pareto-skewed entry sizes.
#[rustfmt::skip]
pub const EXPIRY_FIGURES: &[ExpiryFigure] = &[
    ExpiryFigure { id: "figE0",   ttl_ms: 0,    weight_dist: "unit" },
    ExpiryFigure { id: "figE1s",  ttl_ms: 1000, weight_dist: "unit" },
    ExpiryFigure { id: "figE250", ttl_ms: 250,  weight_dist: "unit" },
    ExpiryFigure { id: "figE50",  ttl_ms: 50,   weight_dist: "unit" },
    ExpiryFigure { id: "figEW",   ttl_ms: 0,    weight_dist: "zipf:8" },
    ExpiryFigure { id: "figEWT",  ttl_ms: 250,  weight_dist: "zipf:8" },
];

/// An elastic-resize figure (the online-resizing extension, not from the
/// paper): the [`crate::throughput::measure_resize`] phased measurement —
/// steady-state throughput and hit ratio before / during / after an
/// online resize from `from_capacity` to `to_capacity`, against a twin
/// cache built directly at the target. `benches/resize.rs` iterates this
/// table; the `kway resize` subcommand sweeps the same dimension
/// interactively, and `--resize-at/--resize-to` fire the same migration
/// inside the `throughput`/`synthetic` harness runs.
#[derive(Debug, Clone)]
pub struct ResizeFigure {
    /// Figure id (figR*).
    pub id: &'static str,
    /// Capacity the cache is built at.
    pub from_capacity: usize,
    /// Capacity the online resize targets.
    pub to_capacity: usize,
    /// Uniform get-or-fill working set driven through every phase. Sized
    /// between the two capacities so the hit ratio is capped before a
    /// grow and recovers to the twin's after it.
    pub working_set: u64,
}

/// All resize figures: a 2× grow (the acceptance scenario: hit ratio
/// must recover to the twin's), a 4× grow, and a 2× shrink (eviction by
/// policy order; the twin shows the honest post-shrink ceiling).
#[rustfmt::skip]
pub const RESIZE_FIGURES: &[ResizeFigure] = &[
    ResizeFigure { id: "figR2x",   from_capacity: 1 << 14, to_capacity: 1 << 15, working_set: 3 << 13 },
    ResizeFigure { id: "figR4x",   from_capacity: 1 << 14, to_capacity: 1 << 16, working_set: 3 << 14 },
    ResizeFigure { id: "figRhalf", from_capacity: 1 << 15, to_capacity: 1 << 14, working_set: 3 << 13 },
];

/// Quick-mode flag shared by every bench: set `KWAY_BENCH_QUICK=1` to run
/// an abbreviated pass (shorter traces, fewer repeats, fewer threads).
pub fn quick_mode() -> bool {
    std::env::var("KWAY_BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::paper;

    #[test]
    fn every_figure_trace_exists() {
        for f in HITRATIO_FIGURES {
            assert!(paper::build(f.trace, 1000, 1).is_some(), "{} trace {}", f.id, f.trace);
        }
        for f in THROUGHPUT_FIGURES {
            assert!(paper::build(f.trace, 1000, 1).is_some(), "{} trace {}", f.id, f.trace);
        }
        for f in ADMISSION_FIGURES {
            assert!(paper::build(f.trace, 1000, 1).is_some(), "{} trace {}", f.id, f.trace);
        }
    }

    #[test]
    fn admission_figures_cover_the_paper_pairings() {
        // Subfigure (b) LFU+TLFU and subfigure (d) Hyperbolic+TLFU must
        // both be represented, and ids must be unique.
        assert!(ADMISSION_FIGURES.iter().any(|f| f.policy == Policy::Lfu));
        assert!(ADMISSION_FIGURES.iter().any(|f| f.policy == Policy::Hyperbolic));
        let mut ids: Vec<&str> = ADMISSION_FIGURES.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), ADMISSION_FIGURES.len());
    }

    #[test]
    fn figure_counts_match_paper() {
        assert_eq!(HITRATIO_FIGURES.len(), 10); // Figures 4-13
        assert_eq!(THROUGHPUT_FIGURES.len(), 13); // Figures 14-26
        assert_eq!(SYNTHETIC_FIGURES.len(), 4); // Figures 27-30
    }

    #[test]
    fn expiry_figures_are_well_formed() {
        use crate::lifetime::WeightDist;
        let mut ids: Vec<&str> = EXPIRY_FIGURES.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), EXPIRY_FIGURES.len(), "figE ids must be unique");
        for f in EXPIRY_FIGURES {
            assert!(WeightDist::parse(f.weight_dist).is_some(), "{}: bad dist", f.id);
        }
        // The immortal baseline and at least one TTL + one weighted row
        // must be present (the acceptance scenarios).
        assert!(EXPIRY_FIGURES.iter().any(|f| f.ttl_ms == 0 && f.weight_dist == "unit"));
        assert!(EXPIRY_FIGURES.iter().any(|f| f.ttl_ms > 0));
        assert!(EXPIRY_FIGURES.iter().any(|f| f.weight_dist != "unit"));
    }

    #[test]
    fn resize_figures_are_well_formed() {
        let mut ids: Vec<&str> = RESIZE_FIGURES.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), RESIZE_FIGURES.len(), "figR ids must be unique");
        for f in RESIZE_FIGURES {
            assert_ne!(f.from_capacity, f.to_capacity, "{}: a no-op resize measures nothing", f.id);
            let (lo, hi) = (
                f.from_capacity.min(f.to_capacity) as u64,
                f.from_capacity.max(f.to_capacity) as u64,
            );
            assert!(
                f.working_set > lo && f.working_set <= hi,
                "{}: working set {} must sit between the capacities ({lo}, {hi}]",
                f.id,
                f.working_set
            );
        }
        // The acceptance scenario — a 2× grow — must be present, and at
        // least one shrink keeps the reverse direction honest.
        assert!(RESIZE_FIGURES.iter().any(|f| f.to_capacity == 2 * f.from_capacity));
        assert!(RESIZE_FIGURES.iter().any(|f| f.to_capacity < f.from_capacity));
    }

    #[test]
    fn batched_figures_are_distinct_and_ascending() {
        assert!(!BATCHED_FIGURES.is_empty());
        for pair in BATCHED_FIGURES.windows(2) {
            assert!(pair[0].batch < pair[1].batch, "{} vs {}", pair[0].id, pair[1].id);
        }
        assert!(BATCHED_FIGURES.iter().any(|f| f.batch == 32), "acceptance batch size");
    }

    #[test]
    fn throughput_capacities_match_captions() {
        for f in THROUGHPUT_FIGURES {
            assert_eq!(f.capacity, paper::paper_cache_size(f.trace), "{}", f.id);
        }
    }
}
