//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (which writes it) and the rust runtime (which reads it).
//!
//! Each entry records the artifact file plus the static shapes the module
//! was lowered with, so the rust side can size its buffers without
//! re-deriving anything from python.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One AOT-lowered entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct EntrySpec {
    /// Entry-point name, e.g. `"victim_select_lru_k8"`.
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Kernel family, e.g. `"victim_select"`, `"cache_sim"`, `"sketch"`.
    pub kind: String,
    /// Static integer parameters the module was lowered with
    /// (`k`, `num_sets`, `batch`, `chunk`, ... — keys vary by kind).
    pub params: Vec<(String, i64)>,
}

impl EntrySpec {
    /// Look up a static parameter by name.
    pub fn param(&self, key: &str) -> Option<i64> {
        self.params.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }

    /// Like [`EntrySpec::param`] but an error when missing.
    pub fn require(&self, key: &str) -> Result<i64> {
        self.param(key)
            .ok_or_else(|| anyhow!("entry {} has no param {key:?}", self.name))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    /// Version stamp written by aot.py (jax/jaxlib versions).
    pub producer: String,
    /// All lowered entry points.
    pub entries: Vec<EntrySpec>,
}

impl ArtifactManifest {
    /// Parse `manifest.json` from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let root = json::parse(text).context("parsing manifest json")?;
        let obj = root.as_object().ok_or_else(|| anyhow!("manifest root must be an object"))?;
        let producer = obj
            .iter()
            .find(|(k, _)| k == "producer")
            .and_then(|(_, v)| v.as_str())
            .unwrap_or("unknown")
            .to_string();
        let entries_json = obj
            .iter()
            .find(|(k, _)| k == "entries")
            .and_then(|(_, v)| v.as_array())
            .ok_or_else(|| anyhow!("manifest must have an `entries` array"))?;
        let mut entries = Vec::new();
        for e in entries_json {
            let eo = e.as_object().ok_or_else(|| anyhow!("entry must be an object"))?;
            let get_str = |key: &str| -> Result<String> {
                eo.iter()
                    .find(|(k, _)| k == key)
                    .and_then(|(_, v)| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("entry missing string field {key:?}"))
            };
            let mut params = Vec::new();
            if let Some(p) = eo.iter().find(|(k, _)| k == "params").map(|(_, v)| v) {
                let po = p.as_object().ok_or_else(|| anyhow!("params must be an object"))?;
                for (k, v) in po {
                    let n = v
                        .as_i64()
                        .ok_or_else(|| anyhow!("param {k:?} must be an integer"))?;
                    params.push((k.clone(), n));
                }
            }
            entries.push(EntrySpec {
                name: get_str("name")?,
                file: get_str("file")?,
                kind: get_str("kind")?,
                params,
            });
        }
        Ok(Self { producer, entries })
    }

    /// Find an entry by exact name.
    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries of a given kind.
    pub fn entries_of_kind(&self, kind: &str) -> Vec<&EntrySpec> {
        self.entries.iter().filter(|e| e.kind == kind).collect()
    }

    /// Serialize back to JSON (used by tests to round-trip).
    pub fn to_json(&self) -> String {
        let mut entries = Vec::new();
        for e in &self.entries {
            let params = Json::Object(
                e.params.iter().map(|(k, v)| (k.clone(), Json::Int(*v))).collect(),
            );
            entries.push(Json::Object(vec![
                ("name".into(), Json::Str(e.name.clone())),
                ("file".into(), Json::Str(e.file.clone())),
                ("kind".into(), Json::Str(e.kind.clone())),
                ("params".into(), params),
            ]));
        }
        Json::Object(vec![
            ("producer".into(), Json::Str(self.producer.clone())),
            ("entries".into(), Json::Array(entries)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "producer": "jax 0.8.2",
        "entries": [
            {"name": "victim_select_lru_k8", "file": "victim_select_lru_k8.hlo.txt",
             "kind": "victim_select", "params": {"k": 8, "batch": 4096}},
            {"name": "cache_sim_k8", "file": "cache_sim_k8.hlo.txt",
             "kind": "cache_sim", "params": {"k": 8, "num_sets": 1024, "chunk": 4096}}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.producer, "jax 0.8.2");
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("victim_select_lru_k8").unwrap();
        assert_eq!(e.kind, "victim_select");
        assert_eq!(e.param("k"), Some(8));
        assert_eq!(e.param("batch"), Some(4096));
        assert_eq!(e.param("missing"), None);
        assert!(e.require("missing").is_err());
    }

    #[test]
    fn kind_filter() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries_of_kind("cache_sim").len(), 1);
        assert_eq!(m.entries_of_kind("nope").len(), 0);
    }

    #[test]
    fn round_trips() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        let again = ArtifactManifest::parse(&m.to_json()).unwrap();
        assert_eq!(again.entries, m.entries);
        assert_eq!(again.producer, m.producer);
    }

    #[test]
    fn rejects_bad_root() {
        assert!(ArtifactManifest::parse("[1,2,3]").is_err());
        assert!(ArtifactManifest::parse("{}").is_err());
    }
}
