//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Layer-2 (`python/compile/aot.py`) lowers every jitted entry point to HLO
//! *text* (the xla_extension 0.5.1 bundled with the `xla` crate rejects
//! jax>=0.5 serialized protos whose instruction ids exceed `INT_MAX`; the
//! text parser reassigns ids, so text round-trips cleanly). This module is
//! the only place that touches PJRT; everything above it deals in plain
//! slices.

mod manifest;

pub use manifest::{ArtifactManifest, EntrySpec};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT client plus the set of compiled executables from `artifacts/`.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: ArtifactManifest,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and eagerly compile every artifact listed in
    /// `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(Self { client, executables, manifest, dir })
    }

    /// Directory the artifacts were loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Platform name reported by PJRT (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The manifest describing every compiled entry point.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Names of all compiled entry points.
    pub fn entry_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Execute entry `name` with the given literals; returns the elements of
    /// the result tuple (aot.py always lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable named {name:?}"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {name}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        literal
            .to_tuple()
            .with_context(|| format!("decomposing result tuple of {name}"))
    }
}

/// Build a rank-n `i32` literal from a flat slice.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a rank-n `u32` literal from a flat slice.
pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a rank-n `f32` literal from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an `i32` scalar literal.
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a `Vec<T>` from a literal.
pub fn to_vec<T: xla::ArrayElement>(lit: &xla::Literal) -> Result<Vec<T>> {
    Ok(lit.to_vec::<T>()?)
}
