//! `kway` — launcher for the limited-associativity cache system.
//!
//! Subcommands:
//!   hitratio    hit-ratio sweep on a trace (Figures 4–13 series)
//!   throughput  multi-threaded trace-replay throughput (Figures 14–26)
//!   synthetic   synthetic-mix throughput (Figures 27–30)
//!   serve       run the cache service demo (router + workers + metrics)
//!   validate    cross-check the XLA artifacts against the native engine
//!   ballsbins   Theorem 4.1 bound vs Monte-Carlo
//!   info        list trace models, implementations and artifacts

use anyhow::{anyhow, bail, Result};
use kway::policy::Policy;
use kway::sim::{self, Config};
use kway::throughput::{impl_factory, measure, RunConfig, Workload, IMPLS};
use kway::trace::{loader, paper};
use kway::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("hitratio") => cmd_hitratio(&args),
        Some("throughput") => cmd_throughput(&args),
        Some("synthetic") => cmd_synthetic(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("ballsbins") => cmd_ballsbins(&args),
        Some("info") => cmd_info(),
        other => {
            eprintln!("unknown or missing subcommand {other:?}\n");
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "usage: kway <subcommand> [--options]
  hitratio   --trace oltp --capacity 2048 [--series lru|lfu|products|hyperbolic|all] [--len N]
  throughput --trace f1 [--impls KW-WFSC,sampled,...] [--threads 1,2,4,8] [--duration-ms 500] [--repeats 5]
  synthetic  --workload miss100|hit100|hit95|hit90 [--capacity 2097152] [--threads ...]
  serve      [--capacity 65536] [--workers 4] [--clients 8] [--requests 20000]
  validate   [--artifacts artifacts] [--trace oltp]
  ballsbins  [--trials 500]
  info";

fn cmd_hitratio(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "oltp");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = loader::resolve(&trace_name, len, seed)?;
    let capacity = args.get_parsed_or("capacity", 2048usize)?;
    let series = args.get_or("series", "lru");

    let mut configs: Vec<Config> = Vec::new();
    match series.as_str() {
        "lru" => configs.extend(sim::lru_series()),
        "lfu" => configs.extend(sim::lfu_tlfu_series()),
        "products" => configs.extend(sim::products_series(8)),
        "hyperbolic" => configs.extend(sim::hyperbolic_series(false)),
        "hyperbolic-tlfu" => configs.extend(sim::hyperbolic_series(true)),
        "all" => {
            configs.extend(sim::lru_series());
            configs.extend(sim::lfu_tlfu_series());
            configs.extend(sim::products_series(8));
            configs.extend(sim::hyperbolic_series(false));
        }
        other => bail!("unknown series {other:?}"),
    }

    println!(
        "# hit-ratio: trace={} len={} unique={} capacity={}",
        trace.name,
        trace.len(),
        trace.unique_keys(),
        capacity
    );
    for row in sim::sweep(&trace, capacity, &configs, seed) {
        println!("{:32} {:.4}", row.label, row.hit_ratio);
    }
    Ok(())
}

fn parse_threads(args: &Args) -> Result<Vec<usize>> {
    args.get_list_or("threads", &[1, 2, 4, 8])
}

fn cmd_throughput(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "f1");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = Arc::new(loader::resolve(&trace_name, len, seed)?);
    let capacity =
        args.get_parsed_or("capacity", paper::paper_cache_size(&trace_name))?;
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;

    println!(
        "# throughput: trace={} capacity={} duration={:?} repeats={} (Mops/s)",
        trace.name, capacity, duration, repeats
    );
    print!("{:14}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!();
    for name in &impls {
        let workload = Workload::TraceReplay(trace.clone());
        print!("{name:14}");
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, policy)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig { threads: t, duration, repeats, seed };
            let r = measure(&*factory, &workload, &cfg);
            print!(" {:10.2}", r.mops.mean());
        }
        println!();
    }
    Ok(())
}

fn cmd_synthetic(args: &Args) -> Result<()> {
    let which = args.get_or("workload", "miss100");
    let capacity = args.get_parsed_or("capacity", 1usize << 21)?;
    let working_set = (capacity / 2) as u64;
    let workload = match which.as_str() {
        "miss100" => Workload::AllMiss,
        "hit100" => Workload::AllHit { working_set },
        "hit95" => Workload::HitRatio { working_set, gets_per_put: 19 },
        "hit90" => Workload::HitRatio { working_set, gets_per_put: 9 },
        other => bail!("unknown workload {other:?} (miss100|hit100|hit95|hit90)"),
    };
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let seed = args.get_parsed_or("seed", 42u64)?;

    println!(
        "# synthetic {}: capacity={} duration={:?} repeats={} (Mops/s)",
        workload.label(),
        capacity,
        duration,
        repeats
    );
    print!("{:14}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!();
    for name in &impls {
        print!("{name:14}");
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, Policy::Lru)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig { threads: t, duration, repeats, seed };
            let r = measure(&*factory, &workload, &cfg);
            print!(" {:10.2}", r.mops.mean());
        }
        println!();
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use kway::coordinator::{CacheService, ServiceConfig};
    use kway::kway::KwWfsc;
    let capacity = args.get_parsed_or("capacity", 65_536usize)?;
    let workers = args.get_parsed_or("workers", 4usize)?;
    let clients = args.get_parsed_or("clients", 8usize)?;
    let requests = args.get_parsed_or("requests", 20_000usize)?;
    let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(capacity, 8, Policy::Lru));
    println!(
        "serving: cache={} capacity={} workers={workers} clients={clients} x {requests} reqs",
        cache.name(),
        cache.capacity()
    );
    let service = CacheService::start(cache, ServiceConfig { workers });
    let secs = kway::coordinator::drive_clients(&service, clients, requests, (capacity * 4) as u64, 7);
    let total = (clients * requests) as f64;
    println!(
        "done in {secs:.2}s — {:.0} req/s\n{}",
        total / secs,
        service.metrics().report()
    );
    service.shutdown();
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use kway::runtime::XlaRuntime;
    use kway::sim::xla::{NativeSetSim, XlaSim};
    let dir = args.get_or("artifacts", "artifacts");
    let trace_name = args.get_or("trace", "oltp");
    let rt = XlaRuntime::load(&dir)?;
    println!("platform: {}; artifacts: {:?}", rt.platform(), rt.entry_names());
    let sim = XlaSim::new(&rt, "cache_sim_k8")?;
    let trace = loader::resolve(&trace_name, 4 * sim.chunk, 42)?;
    let xla = sim.run(&trace)?;
    let native = NativeSetSim::new(sim.num_sets, sim.ways).run(&trace.keys);
    println!(
        "trace={} accesses={} xla_hits={} native_hits={} -> {}",
        trace.name,
        xla.accesses,
        xla.hits,
        native.hits,
        if xla.hits == native.hits { "MATCH" } else { "MISMATCH" }
    );
    if xla.hits != native.hits {
        bail!("XLA / native divergence");
    }
    Ok(())
}

fn cmd_ballsbins(args: &Args) -> Result<()> {
    use kway::analysis::{monte_carlo_overflow, theorem41_bound};
    let trials = args.get_parsed_or("trials", 500u32)?;
    println!("# Theorem 4.1: bound vs Monte-Carlo ({} trials)", trials);
    println!("{:>10} {:>10} {:>6} {:>12} {:>12}", "C", "C'", "k", "bound", "empirical");
    for (c, cp, k) in [
        (2048u64, 4096u64, 16u64),
        (4096, 8192, 32),
        (4096, 8192, 64),
        (100_000, 200_000, 64),
        (1_000_000, 2_000_000, 128),
    ] {
        let bound = theorem41_bound(cp, k);
        let mc = monte_carlo_overflow(c, cp, k, trials, 7);
        println!("{c:>10} {cp:>10} {k:>6} {bound:>12.3e} {mc:>12.4}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("trace models: {}", paper::ALL.join(", "));
    println!("implementations: {}", IMPLS.join(", "));
    println!("policies: lru, lfu, fifo, random, hyperbolic");
    match kway::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => println!("artifacts ({}): {:?}", rt.platform(), rt.entry_names()),
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
