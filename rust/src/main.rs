//! `kway` — launcher for the limited-associativity cache system.
//!
//! Subcommands:
//!   hitratio    hit-ratio sweep on a trace (Figures 4–13 series)
//!   throughput  multi-threaded trace-replay throughput (Figures 14–26)
//!   synthetic   synthetic-mix throughput (Figures 27–30)
//!   batch       batched-get sweep: Mops/s + per-batch p50/p99 vs batch size
//!   resize      online elastic-resize sweep: before/during/after phases vs a twin
//!   bench       named benchmark suite; --json writes BENCH_<name>.json
//!   serve       run the cache service demo (router + workers + metrics);
//!               with --listen <addr>, serve memcached text + RESP over TCP
//!   loadgen     pipelined TCP load generator against a running server
//!   chaos       fault-injection drill: availability before/during/after
//!               worker panics, conn drops, io stalls, forced shedding
//!   validate    cross-check the XLA artifacts against the native engine
//!   ballsbins   Theorem 4.1 bound vs Monte-Carlo
//!   info        list trace models, implementations and artifacts
//!
//! The global `--hugepages` flag asks the kernel (via
//! `madvise(MADV_HUGEPAGE)`) to back every subsequently allocated cache
//! table with transparent huge pages; bench JSON records the setting.
//!
//! `throughput`, `synthetic`, `batch`, `bench` and `serve` all take
//! `--admission none|tlfu`: `tlfu` layers the concurrent TinyLFU
//! admission filter (`kway::tinylfu::TlfuCache`) over every cache they
//! build. They also take the lifetime options `--ttl <dur>` (every fill
//! carries that TTL; on `serve` it becomes the service-wide default) and
//! `--weight-dist unit|uniform[:MAX]|zipf[:MAX]` (deterministic per-key
//! entry weights against the weight-based capacity); `synthetic
//! --workload expiring` is the dedicated TTL-churn scenario.
//!
//! `throughput`, `synthetic` and `serve` additionally take `--resize-at
//! N --resize-to C`: after N operations the cache is resized online to
//! capacity C mid-run (the harness — or, on `serve`, the service's
//! background driver — pumps the migration while traffic keeps flowing);
//! the dedicated `resize` subcommand measures the before/during/after
//! phases explicitly against a twin built at the target capacity.
//!
//! Byte values (DESIGN.md §Value store): `serve --value-bytes N` backs
//! the cache with an N-byte slab value store, turning wire payloads into
//! binary-safe blobs; `loadgen --value-dist fixed:N|uniform:MAX|zipf:MAX`
//! drives it with deterministic key-stamped byte payloads (`word`, the
//! default, keeps the decimal-`u64` workload).

use anyhow::{anyhow, bail, Result};
use kway::coordinator::DegradedPolicy;
use kway::fault::FaultPlan;
use kway::lifetime::{parse_duration, WeightDist};
use kway::policy::Policy;
use kway::sim::{self, Config};
use kway::throughput::{impl_factory, measure, FillSpec, RunConfig, Workload, IMPLS};
use kway::tinylfu::AdmissionMode;
use kway::trace::{loader, paper};
use kway::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if args.has_flag("hugepages") {
        kway::kway::set_hugepages(true);
    }
    let result = match args.command.as_deref() {
        Some("hitratio") => cmd_hitratio(&args),
        Some("throughput") => cmd_throughput(&args),
        Some("synthetic") => cmd_synthetic(&args),
        Some("batch") => cmd_batch(&args),
        Some("resize") => cmd_resize(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("validate") => cmd_validate(&args),
        Some("ballsbins") => cmd_ballsbins(&args),
        Some("info") => cmd_info(),
        other => {
            eprintln!("unknown or missing subcommand {other:?}\n");
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "usage: kway <subcommand> [--options]
  hitratio   --trace oltp --capacity 2048 [--series lru|lfu|products|hyperbolic|all] [--len N]
  throughput --trace f1 [--impls KW-WFSC,sampled,...] [--threads 1,2,4,8] [--duration-ms 500] [--repeats 5] [--policy lru] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8] [--resize-at N --resize-to C] [--pin] [--numa-interleave]
  synthetic  --workload miss100|hit100|hit95|hit90|expiring [--capacity 2097152] [--threads ...] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8] [--resize-at N --resize-to C] [--pin] [--numa-interleave]
  batch      [--batch 1,8,32,128] [--impls KW-WFA,KW-WFSC,KW-LS] [--threads 4] [--capacity 262144] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8] [--pin] [--numa-interleave]
  resize     [--from 16384] [--to 32768] [--working-set N] [--impls KW-WFA,KW-WFSC,KW-LS,sampled] [--threads 4] [--phase-ms 300] [--policy lru] [--admission none|tlfu]
  bench      [--name oltp] [--trace oltp] [--impls KW-WFA,KW-WFSC,KW-LS] [--threads 1,4] [--policy lru] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8] [--pin] [--numa-interleave] [--json]
  serve      [--capacity 65536] [--workers 4] [--clients 8] [--requests 20000] [--batch 0] [--admission none|tlfu] [--ttl 100ms] [--value-bytes N] [--resize-at N --resize-to C] [--degraded miss|error] [--shed-depth N] [--faults SPEC]
             [--listen 127.0.0.1:11211 [--backend auto|epoll|uring] [--io-threads 2] [--max-conns N] [--max-wq-bytes N] [--idle-timeout 30s] [--request-deadline 5s]]  (memcached text + RESP over TCP)
  loadgen    [--addr 127.0.0.1:11211] [--proto memcached|resp] [--connections 8] [--pipeline 16] [--threads 2] [--duration-ms 1000] [--keyspace 65536] [--set-every 10] [--zipf 0.99] [--ttl 100ms] [--value-dist word|fixed:N|uniform:MAX|zipf:MAX] [--seed 42] [--max-reconnects 1024] [--pin] [--smoke] [--json]
  chaos      [--smoke] [--seed 42] [--phase-ms 600] [--faults SPEC]  (fault drill; writes BENCH_chaos.json)
             SPEC e.g. worker_panic@5s,io_stall:3ms:p0.01,conn_drop:p0.001,shed_test
  validate   [--artifacts artifacts] [--trace oltp]
  ballsbins  [--trials 500]
  info";

/// Parse the shared `--admission none|tlfu` option.
fn parse_admission(args: &Args) -> Result<AdmissionMode> {
    let raw = args.get_or("admission", "none");
    AdmissionMode::parse(&raw).ok_or_else(|| anyhow!("bad --admission {raw:?} (none|tlfu)"))
}

/// Parse the shared `--ttl <dur>` / `--weight-dist <dist>` /
/// `--value-dist <dist>` fill options (e.g. `--ttl 100ms --weight-dist
/// zipf:8 --value-dist zipf:4096`). Absent options leave the fill
/// plain: immortal word entries of weight 1, the pre-lifetime
/// behaviour.
fn parse_fill(args: &Args) -> Result<FillSpec> {
    let ttl = match args.get("ttl") {
        None => None,
        Some(raw) => Some(
            parse_duration(raw)
                .ok_or_else(|| anyhow!("bad --ttl {raw:?} (e.g. 100ms, 2s, 250us)"))?,
        ),
    };
    let weight_dist = match args.get("weight-dist") {
        None => WeightDist::Unit,
        Some(raw) => WeightDist::parse(raw)
            .ok_or_else(|| anyhow!("bad --weight-dist {raw:?} (unit|uniform[:MAX]|zipf[:MAX])"))?,
    };
    Ok(FillSpec { ttl, weight_dist, value_dist: parse_value_dist(args)? })
}

/// Parse the shared `--pin` / `--numa-interleave` measurement toggles:
/// `--pin` pins worker `t` to core `t mod num_cores`, `--numa-interleave`
/// spreads table pages round-robin across NUMA nodes before each
/// repeat's cache is built. Both are best-effort (see
/// `kway::util::affinity`).
fn parse_pinning(args: &Args) -> (bool, bool) {
    (args.has_flag("pin"), args.has_flag("numa-interleave"))
}

/// Parse the shared `--resize-at N --resize-to C` pair (both or
/// neither) into the harness's mid-run [`ResizeSpec`] trigger.
fn parse_resize(args: &Args) -> Result<Option<kway::throughput::ResizeSpec>> {
    match (args.get("resize-at"), args.get("resize-to")) {
        (None, None) => Ok(None),
        (Some(at), Some(to)) => {
            let at_ops: u64 = at.parse().map_err(|_| anyhow!("bad --resize-at {at:?}"))?;
            let to_capacity: usize = to.parse().map_err(|_| anyhow!("bad --resize-to {to:?}"))?;
            if to_capacity == 0 {
                bail!("--resize-to must be positive");
            }
            Ok(Some(kway::throughput::ResizeSpec { at_ops, to_capacity }))
        }
        _ => bail!("--resize-at and --resize-to must be given together"),
    }
}

/// Parse the shared resilience options of `serve` (both the in-process
/// demo and `--listen`): `--degraded miss|error` (what a request sees
/// while its worker is down — a served miss, or an explicit error),
/// `--shed-depth N` (answer `busy` once more than N requests are queued;
/// 0 = never shed) and `--faults SPEC` (a [`FaultPlan`] for chaos
/// drills; armed immediately so the spec is live from process start).
fn parse_resilience(args: &Args) -> Result<(DegradedPolicy, usize, Option<Arc<FaultPlan>>)> {
    let raw = args.get_or("degraded", "miss");
    let degraded = DegradedPolicy::parse(&raw)
        .ok_or_else(|| anyhow!("bad --degraded {raw:?} (miss|error)"))?;
    let shed_queue_depth = args.get_parsed_or("shed-depth", 0usize)?;
    let faults = match args.get("faults") {
        None => None,
        Some(spec) => Some(Arc::new(FaultPlan::parse(spec)?)),
    };
    Ok((degraded, shed_queue_depth, faults))
}

/// Parse `--value-dist word|fixed:N|uniform:MAX|zipf:MAX` (loadgen's
/// store-payload axis); absent means decimal words.
fn parse_value_dist(args: &Args) -> Result<kway::lifetime::ValueDist> {
    match args.get("value-dist") {
        None => Ok(kway::lifetime::ValueDist::Word),
        Some(raw) => kway::lifetime::ValueDist::parse(raw).ok_or_else(|| {
            anyhow!("bad --value-dist {raw:?} (word|fixed:N|uniform:MAX|zipf:MAX)")
        }),
    }
}

/// Build the serving cache: plain KW-WFSC, or — with `--value-bytes N`
/// — the same variant over an N-byte slab value store (DESIGN.md §Value
/// store), which makes the wire protocols binary-safe.
fn build_serve_cache(capacity: usize, value_bytes: usize) -> Arc<dyn kway::Cache> {
    use kway::kway::{build_with_values, KwWfsc, Variant};
    if value_bytes > 0 {
        Arc::from(build_with_values(Variant::Wfsc, capacity, 8, Policy::Lru, value_bytes))
    } else {
        Arc::new(KwWfsc::new(capacity, 8, Policy::Lru))
    }
}

/// Parse an optional duration-valued option (e.g. `--idle-timeout 30s`);
/// absent means the guard is off.
fn parse_opt_duration(args: &Args, key: &str) -> Result<Option<Duration>> {
    match args.get(key) {
        None => Ok(None),
        Some(raw) => Ok(Some(parse_duration(raw).ok_or_else(|| {
            anyhow!("bad --{key} {raw:?} (e.g. 500ms, 30s, 2m)")
        })?)),
    }
}

fn cmd_hitratio(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "oltp");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = loader::resolve(&trace_name, len, seed)?;
    let capacity = args.get_parsed_or("capacity", 2048usize)?;
    let series = args.get_or("series", "lru");

    let mut configs: Vec<Config> = Vec::new();
    match series.as_str() {
        "lru" => configs.extend(sim::lru_series()),
        "lfu" => configs.extend(sim::lfu_tlfu_series()),
        "products" => configs.extend(sim::products_series(8)),
        "hyperbolic" => configs.extend(sim::hyperbolic_series(false)),
        "hyperbolic-tlfu" => configs.extend(sim::hyperbolic_series(true)),
        "all" => {
            configs.extend(sim::lru_series());
            configs.extend(sim::lfu_tlfu_series());
            configs.extend(sim::products_series(8));
            configs.extend(sim::hyperbolic_series(false));
        }
        other => bail!("unknown series {other:?}"),
    }

    println!(
        "# hit-ratio: trace={} len={} unique={} capacity={}",
        trace.name,
        trace.len(),
        trace.unique_keys(),
        capacity
    );
    for row in sim::sweep(&trace, capacity, &configs, seed) {
        println!("{:32} {:.4}", row.label, row.hit_ratio);
    }
    Ok(())
}

fn parse_threads(args: &Args) -> Result<Vec<usize>> {
    args.get_list_or("threads", &[1, 2, 4, 8])
}

fn cmd_throughput(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "f1");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = Arc::new(loader::resolve(&trace_name, len, seed)?);
    let capacity =
        args.get_parsed_or("capacity", paper::paper_cache_size(&trace_name))?;
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;
    let resize = parse_resize(args)?;
    let (pin, numa_interleave) = parse_pinning(args);

    println!(
        "# throughput: trace={} capacity={} duration={:?} repeats={} admission={} fill={}{}{} (Mops/s)",
        trace.name,
        capacity,
        duration,
        repeats,
        admission.name(),
        fill.label(),
        if pin { " pinned" } else { "" },
        match resize {
            Some(spec) => format!(" resize@{}ops->{}", spec.at_ops, spec.to_capacity),
            None => String::new(),
        }
    );
    print!("{:20}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!("   p50/p99(ns)");
    for name in &impls {
        let workload = Workload::TraceReplay(trace.clone());
        let label = format!("{name}{}", admission.label());
        print!("{label:20}");
        let mut last_lat = (0u64, 0u64);
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, policy, admission)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig {
                threads: t,
                duration,
                repeats,
                seed,
                fill: fill.clone(),
                resize,
                pin,
                numa_interleave,
            };
            let r = measure(&*factory, &workload, &cfg);
            last_lat = (r.lat_p50_ns, r.lat_p99_ns);
            print!(" {:10.2}", r.mops.mean());
        }
        // Latency of the highest thread count (sampled per access).
        println!("   {}/{}", last_lat.0, last_lat.1);
    }
    Ok(())
}

fn cmd_synthetic(args: &Args) -> Result<()> {
    let which = args.get_or("workload", "miss100");
    let capacity = args.get_parsed_or("capacity", 1usize << 21)?;
    let working_set = (capacity / 2) as u64;
    let workload = match which.as_str() {
        "miss100" => Workload::AllMiss,
        "hit100" => Workload::AllHit { working_set },
        "hit95" => Workload::HitRatio { working_set, gets_per_put: 19 },
        "hit90" => Workload::HitRatio { working_set, gets_per_put: 9 },
        "expiring" => Workload::Expiring { working_set },
        other => bail!("unknown workload {other:?} (miss100|hit100|hit95|hit90|expiring)"),
    };
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let seed = args.get_parsed_or("seed", 42u64)?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;
    let resize = parse_resize(args)?;
    let (pin, numa_interleave) = parse_pinning(args);

    println!(
        "# synthetic {}: capacity={} duration={:?} repeats={} admission={} fill={}{}{} (Mops/s)",
        workload.label(),
        capacity,
        duration,
        repeats,
        admission.name(),
        fill.label(),
        if pin { " pinned" } else { "" },
        match resize {
            Some(spec) => format!(" resize@{}ops->{}", spec.at_ops, spec.to_capacity),
            None => String::new(),
        }
    );
    print!("{:20}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!("   p50/p99(ns)");
    for name in &impls {
        let label = format!("{name}{}", admission.label());
        print!("{label:20}");
        let mut last_lat = (0u64, 0u64);
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, Policy::Lru, admission)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig {
                threads: t,
                duration,
                repeats,
                seed,
                fill: fill.clone(),
                resize,
                pin,
                numa_interleave,
            };
            let r = measure(&*factory, &workload, &cfg);
            last_lat = (r.lat_p50_ns, r.lat_p99_ns);
            print!(" {:10.2}", r.mops.mean());
        }
        println!("   {}/{}", last_lat.0, last_lat.1);
    }
    Ok(())
}

/// The batched-access sweep: Mops/s and per-batch latency percentiles vs
/// batch size, for the k-way variants. The `1-by-1` row is the scalar
/// path over the same key distribution, as the baseline.
fn cmd_batch(args: &Args) -> Result<()> {
    let capacity = args.get_parsed_or("capacity", 1usize << 18)?;
    let working_set = (capacity / 2) as u64;
    let batches: Vec<usize> = args.get_list_or("batch", &[1, 8, 32, 128])?;
    let default_impls: Vec<String> =
        ["KW-WFA", "KW-WFSC", "KW-LS"].iter().map(|s| s.to_string()).collect();
    let impls: Vec<String> = args.get_list_or("impls", &default_impls)?;
    let threads = args.get_parsed_or("threads", 4usize)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 300u64)?);
    let repeats = args.get_parsed_or("repeats", 3usize)?;
    let seed = args.get_parsed_or("seed", 42u64)?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;
    let (pin, numa_interleave) = parse_pinning(args);

    println!(
        "# batch sweep: capacity={capacity} working_set={working_set} threads={threads} \
         duration={duration:?} repeats={repeats} admission={} fill={}{}",
        admission.name(),
        fill.label(),
        if pin { " pinned" } else { "" }
    );
    println!(
        "{:20} {:>8} {:>10} {:>12} {:>12} {:>8}",
        "impl", "batch", "Mops/s", "p50(ns)", "p99(ns)", "hit"
    );
    for name in &impls {
        let factory = impl_factory(name, capacity, threads, Policy::Lru, admission)
            .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
        let label = format!("{name}{}", admission.label());
        let cfg = RunConfig {
            threads,
            duration,
            repeats,
            seed,
            fill: fill.clone(),
            resize: None,
            pin,
            numa_interleave,
        };
        // Baseline: the same resident-set gets, one key per call.
        let base = measure(&*factory, &Workload::AllHit { working_set }, &cfg);
        println!(
            "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
            label, "1-by-1", base.mops.mean(), base.lat_p50_ns, base.lat_p99_ns, base.hit_ratio
        );
        for &batch in &batches {
            let r = measure(&*factory, &Workload::Batched { working_set, batch }, &cfg);
            println!(
                "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
                label, batch, r.mops.mean(), r.lat_p50_ns, r.lat_p99_ns, r.hit_ratio
            );
        }
    }
    println!(
        "\nReading: batched rows amortize hashing and prefetch set lines a\n\
         chunk at a time; p50/p99 are per get_batch call (one whole batch),\n\
         the 1-by-1 row per single get."
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use kway::coordinator::{CacheService, ServiceConfig};
    use std::sync::atomic::{AtomicBool, Ordering};
    let capacity = args.get_parsed_or("capacity", 65_536usize)?;
    // --value-bytes N > 0 backs the cache with an N-byte slab value
    // store: wire payloads become binary-safe byte blobs.
    let value_bytes = args.get_parsed_or("value-bytes", 0usize)?;
    let workers = args.get_parsed_or("workers", 4usize)?;
    let clients = args.get_parsed_or("clients", 8usize)?;
    let requests = args.get_parsed_or("requests", 20_000usize)?;
    // --batch N > 0 switches the clients to scatter/gather get_batch calls
    // of N keys (misses refilled with put_batch).
    let batch = args.get_parsed_or("batch", 0usize)?;
    let admission = parse_admission(args)?;
    // --ttl <dur> becomes the service-wide default entry lifetime: every
    // routed put carries it unless the caller passes explicit options.
    let default_ttl = parse_fill(args)?.ttl;
    // --resize-at N --resize-to C: once the service has executed N
    // operations, issue the online-resize admin op; the service's
    // background driver migrates while the clients keep hammering.
    let resize = parse_resize(args)?;
    // --listen <addr> switches from the in-process demo clients to the
    // TCP wire front end (memcached text + RESP); it serves until killed.
    if let Some(listen) = args.get("listen") {
        return serve_tcp(
            args, listen, capacity, value_bytes, workers, admission, default_ttl, resize,
        );
    }
    let (degraded, shed_queue_depth, faults) = parse_resilience(args)?;
    if let Some(plan) = &faults {
        plan.arm();
    }
    let cache = build_serve_cache(capacity, value_bytes);
    println!(
        "serving: cache={}{} capacity={} workers={workers} clients={clients} x {requests} reqs{}{}{}{}",
        cache.name(),
        admission.label(),
        cache.capacity(),
        if value_bytes > 0 { format!(" (values {value_bytes}B slab)") } else { String::new() },
        if batch > 0 { format!(" (batched x{batch})") } else { String::new() },
        match default_ttl {
            Some(ttl) => format!(" (ttl {ttl:?})"),
            None => String::new(),
        },
        match resize {
            Some(spec) => format!(" (resize@{}ops->{})", spec.at_ops, spec.to_capacity),
            None => String::new(),
        }
    );
    let service = CacheService::start(
        cache,
        ServiceConfig { workers, admission, default_ttl, degraded, shed_queue_depth, faults },
    );
    let keyspace = (capacity * 4) as u64;
    let done = AtomicBool::new(false);
    let secs = std::thread::scope(|scope| {
        if let Some(spec) = resize {
            let service = &service;
            let done = &done;
            scope.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    let m = service.metrics();
                    let total =
                        m.ops.gets.load(Ordering::Relaxed) + m.ops.puts.load(Ordering::Relaxed);
                    if total >= spec.at_ops {
                        service.resize(spec.to_capacity);
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
        }
        let secs = if batch > 0 {
            kway::coordinator::drive_clients_batched(
                &service, clients, requests, batch, keyspace, 7,
            )
        } else {
            kway::coordinator::drive_clients(&service, clients, requests, keyspace, 7)
        };
        done.store(true, Ordering::Relaxed);
        secs
    });
    // Batched clients round the request count up to whole batches.
    let per_client = if batch > 0 { requests.div_ceil(batch) * batch } else { requests };
    let total = (clients * per_client) as f64;
    println!(
        "done in {secs:.2}s — {:.0} req/s\n{}",
        total / secs,
        service.metrics().report()
    );
    if resize.is_some() {
        service.wait_for_resize();
        println!(
            "resize admin ops: {} (final capacity {}, requested {})",
            service.metrics().resizes.load(Ordering::Relaxed),
            service.cache().capacity(),
            service.cache().requested_capacity()
        );
    }
    service.shutdown();
    Ok(())
}

/// `kway serve --listen <addr>`: the TCP wire front end. One port speaks
/// both the memcached text protocol and the RESP subset (sniffed from the
/// first byte of each connection); pipelined requests are fused into
/// `get_batch`/`put_batch` calls against the [`CacheService`]. Serves
/// until the process is killed. `--resize-at N --resize-to C` still
/// works: a poll loop fires the online resize once the service's op
/// counters cross the threshold, while connections keep flowing.
#[allow(clippy::too_many_arguments)]
fn serve_tcp(
    args: &Args,
    listen: &str,
    capacity: usize,
    value_bytes: usize,
    workers: usize,
    admission: AdmissionMode,
    default_ttl: Option<Duration>,
    resize: Option<kway::throughput::ResizeSpec>,
) -> Result<()> {
    use kway::coordinator::{CacheService, ServiceConfig};
    use kway::net::{BackendChoice, Server, ServerConfig};
    use std::sync::atomic::Ordering;
    let io_threads = args.get_parsed_or("io-threads", 2usize)?;
    let backend_raw = args.get_or("backend", "auto");
    let backend = BackendChoice::parse(&backend_raw)
        .ok_or_else(|| anyhow!("bad --backend {backend_raw:?} (auto|epoll|uring)"))?;
    let (degraded, shed_queue_depth, faults) = parse_resilience(args)?;
    let max_conns = args.get_parsed_or("max-conns", 0usize)?;
    let max_wq_bytes = args.get_parsed_or("max-wq-bytes", 0usize)?;
    let idle_timeout = parse_opt_duration(args, "idle-timeout")?;
    let request_deadline = parse_opt_duration(args, "request-deadline")?;
    if let Some(plan) = &faults {
        plan.arm();
    }
    let cache = build_serve_cache(capacity, value_bytes);
    let service = Arc::new(CacheService::start(
        cache,
        ServiceConfig {
            workers,
            admission,
            default_ttl,
            degraded,
            shed_queue_depth,
            faults: faults.clone(),
        },
    ));
    let listener =
        std::net::TcpListener::bind(listen).map_err(|e| anyhow!("binding {listen}: {e}"))?;
    let server = Server::start(
        listener,
        Arc::clone(&service),
        ServerConfig {
            io_threads,
            max_conns,
            max_wq_bytes,
            idle_timeout,
            request_deadline,
            faults,
            backend,
        },
    )
    .map_err(|e| anyhow!("starting the wire front end: {e}"))?;
    println!(
        "kway: listening on {} (memcached text + RESP; backend={} workers={workers} \
         io-threads={io_threads})",
        server.local_addr(),
        server.backend().name()
    );
    println!(
        "kway: cache={}{} capacity={}{}{}",
        service.cache().name(),
        admission.label(),
        service.cache().capacity(),
        if value_bytes > 0 {
            format!(" value-store={value_bytes}B (binary-safe payloads)")
        } else {
            String::new()
        },
        match default_ttl {
            Some(ttl) => format!(" default-ttl={ttl:?}"),
            None => String::new(),
        }
    );
    if max_conns > 0 || max_wq_bytes > 0 || idle_timeout.is_some() || request_deadline.is_some() {
        println!(
            "kway: guards max-conns={max_conns} max-wq-bytes={max_wq_bytes} \
             idle-timeout={idle_timeout:?} request-deadline={request_deadline:?} (0/None = off)"
        );
    }
    let mut resize_pending = resize;
    loop {
        std::thread::sleep(Duration::from_millis(100));
        if let Some(spec) = resize_pending {
            let m = service.metrics();
            let total = m.ops.gets.load(Ordering::Relaxed) + m.ops.puts.load(Ordering::Relaxed);
            if total >= spec.at_ops {
                println!(
                    "kway: resize trigger hit ({total} ops) — resizing to {}",
                    spec.to_capacity
                );
                service.resize(spec.to_capacity);
                resize_pending = None;
            }
        }
    }
}

/// `kway loadgen`: pipelined TCP load generator for a running
/// `kway serve --listen` instance. Reuses the crate's Zipf/uniform key
/// machinery and `--pin` affinity, reports Mops/s, hit ratio and
/// reservoir-sampled per-op latency percentiles; `--json` writes a
/// `kway-serve-v2` document to `BENCH_serve-<proto>.json`, with the
/// serving backend and measured `syscalls_per_op` pulled from the
/// server's `stats` deltas around the run.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use kway::net::loadgen::{self, LoadgenConfig, WireProto};
    use kway::util::json::{check_serve_schema, Json, SERVE_SCHEMA};
    let addr = args.get_or("addr", "127.0.0.1:11211");
    let proto_raw = args.get_or("proto", "memcached");
    let proto = WireProto::parse(&proto_raw)
        .ok_or_else(|| anyhow!("bad --proto {proto_raw:?} (memcached|resp)"))?;
    let value_dist = parse_value_dist(args)?;
    let mut cfg = if args.has_flag("smoke") {
        LoadgenConfig::smoke(&addr, proto)
    } else {
        LoadgenConfig {
            addr: addr.clone(),
            proto,
            connections: args.get_parsed_or("connections", 8usize)?,
            pipeline: args.get_parsed_or("pipeline", 16usize)?,
            threads: args.get_parsed_or("threads", 2usize)?,
            duration: Duration::from_millis(args.get_parsed_or("duration-ms", 1000u64)?),
            keyspace: args.get_parsed_or("keyspace", 65_536u64)?,
            set_every: args.get_parsed_or("set-every", 10u64)?,
            ttl: parse_fill(args)?.ttl,
            zipf_alpha: match args.get("zipf") {
                None => None,
                Some(raw) => Some(raw.parse::<f64>().map_err(|_| anyhow!("bad --zipf {raw:?}"))?),
            },
            value_dist,
            seed: args.get_parsed_or("seed", 42u64)?,
            pin: args.has_flag("pin"),
            max_reconnects: args.get_parsed_or("max-reconnects", 1024u64)?,
            faults: None,
        }
    };
    // --value-dist applies even under --smoke (smoke defaults to words).
    cfg.value_dist = value_dist;
    println!(
        "loadgen: addr={} proto={} connections={} pipeline={} threads={} duration={:?} values={}",
        cfg.addr,
        cfg.proto.name(),
        cfg.connections,
        cfg.pipeline,
        cfg.threads,
        cfg.duration,
        cfg.value_dist.name()
    );
    // Server-side stats snapshots bracket the run so the JSON row can
    // carry a *measured* syscalls/op for the serving backend (both
    // best-effort: an old server without these stats still loadgens).
    let stats_before = loadgen::fetch_stats(&cfg.addr).ok();
    let r = loadgen::run(&cfg)?;
    let stats_after = loadgen::fetch_stats(&cfg.addr).ok();
    println!(
        "{:.3} Mops/s — ops={} hits={}/{} gets ({:.3}) errors={} reconnects={} p50={}ns \
         p99={}ns mean={:.0}ns",
        r.mops(),
        r.ops,
        r.hits,
        r.gets,
        r.hit_ratio(),
        r.errors,
        r.reconnects,
        r.p50_ns,
        r.p99_ns,
        r.mean_ns
    );
    if args.has_flag("json") {
        let (backend, syscalls_per_op) = serve_stats_delta(&stats_before, &stats_after);
        let row = Json::Object(vec![
            ("proto".into(), Json::Str(cfg.proto.name().into())),
            ("backend".into(), Json::Str(backend)),
            ("connections".into(), Json::Int(cfg.connections as i64)),
            ("pipeline".into(), Json::Int(cfg.pipeline as i64)),
            ("threads".into(), Json::Int(cfg.threads as i64)),
            ("ops".into(), Json::Int(r.ops as i64)),
            ("mops".into(), Json::Float(r.mops())),
            ("hit_ratio".into(), Json::Float(r.hit_ratio())),
            ("p50_ns".into(), Json::Int(r.p50_ns as i64)),
            ("p99_ns".into(), Json::Int(r.p99_ns as i64)),
            ("errors".into(), Json::Int(r.errors as i64)),
            ("syscalls_per_op".into(), Json::Float(syscalls_per_op)),
        ]);
        let doc = Json::Object(vec![
            ("schema".into(), Json::Str(SERVE_SCHEMA.into())),
            ("addr".into(), Json::Str(cfg.addr.clone())),
            ("duration_ms".into(), Json::Int(cfg.duration.as_millis() as i64)),
            ("keyspace".into(), Json::Int(cfg.keyspace as i64)),
            ("seed".into(), Json::Int(cfg.seed as i64)),
            ("pinned".into(), Json::Bool(cfg.pin)),
            (
                "provenance".into(),
                Json::Str(format!("kway loadgen against {}", cfg.addr)),
            ),
            ("results".into(), Json::Array(vec![row])),
        ]);
        check_serve_schema(&doc)?;
        let path = format!("BENCH_serve-{}.json", cfg.proto.name());
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Derive `(backend, syscalls_per_op)` for a loadgen JSON row from the
/// `stats` snapshots taken around the run: the serving backend comes
/// from the after-snapshot, and syscalls/op is the io-syscall delta
/// over the op-count delta (so only *this run's* traffic counts).
/// Degrades to `("unknown", 0.0)` when either snapshot is missing —
/// e.g. an older server without these stats.
#[allow(clippy::type_complexity)]
fn serve_stats_delta(
    before: &Option<Vec<(String, String)>>,
    after: &Option<Vec<(String, String)>>,
) -> (String, f64) {
    fn stat_u64(stats: &[(String, String)], name: &str) -> Option<u64> {
        stats.iter().find(|(n, _)| n == name).and_then(|(_, v)| v.parse().ok())
    }
    fn ops(stats: &[(String, String)]) -> Option<u64> {
        Some(stat_u64(stats, "gets")? + stat_u64(stats, "puts")?)
    }
    let (Some(before), Some(after)) = (before, after) else {
        return ("unknown".into(), 0.0);
    };
    let backend = after
        .iter()
        .find(|(n, _)| n == "io_backend")
        .map(|(_, v)| v.clone())
        .unwrap_or_else(|| "unknown".into());
    let syscalls = stat_u64(after, "io_syscalls")
        .zip(stat_u64(before, "io_syscalls"))
        .map(|(a, b)| a.saturating_sub(b));
    let ops_delta = ops(after).zip(ops(before)).map(|(a, b)| a.saturating_sub(b));
    let spo = match (syscalls, ops_delta) {
        (Some(s), Some(o)) if o > 0 => s as f64 / o as f64,
        _ => 0.0,
    };
    (backend, spo)
}

/// `kway chaos`: the availability-under-faults drill. For each scenario
/// — a fault-free baseline, one per injection point, plus `--faults
/// SPEC` as a custom extra — it boots a loopback serving stack
/// (KW-WFSC behind the [`kway::coordinator::CacheService`] router
/// behind the TCP front end) and drives three loadgen phases: `before`
/// (plan disarmed), `during` (armed) and `after` (disarmed again).
/// Writes `BENCH_chaos.json` (`kway-chaos-v1`, schema-checked before
/// writing) with per-phase ops/errors/reconnects/availability, the
/// service's resilience counters, and a `recovered` verdict — the
/// after-phase served without a single error. `--smoke` shortens the
/// phases for CI. Without the `fault-inject` feature the drill still
/// runs, but the injection points are compiled-out no-ops, so every
/// scenario degenerates to the baseline.
fn cmd_chaos(args: &Args) -> Result<()> {
    use kway::coordinator::{CacheService, ServiceConfig};
    use kway::kway::KwWfsc;
    use kway::net::loadgen::{self, LoadgenConfig, LoadgenResult, WireProto};
    use kway::net::{Server, ServerConfig};
    use kway::util::json::{check_chaos_schema, Json, CHAOS_SCHEMA};
    use std::sync::atomic::Ordering;

    // Fraction of sent requests answered successfully. Conservative:
    // io-level failures count against it even though those requests
    // never completed a round trip.
    fn availability(r: &LoadgenResult) -> f64 {
        if r.ops == 0 {
            return 0.0;
        }
        r.ops.saturating_sub(r.errors) as f64 / r.ops as f64
    }
    fn phase_row(name: &str, r: &LoadgenResult) -> Json {
        Json::Object(vec![
            ("phase".into(), Json::Str(name.into())),
            ("ops".into(), Json::Int(r.ops as i64)),
            ("errors".into(), Json::Int(r.errors as i64)),
            ("reconnects".into(), Json::Int(r.reconnects as i64)),
            ("availability".into(), Json::Float(availability(r))),
        ])
    }

    let smoke = args.has_flag("smoke");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let phase_ms = args.get_parsed_or("phase-ms", if smoke { 150u64 } else { 600u64 })?;
    let mut scenarios: Vec<(&str, Arc<FaultPlan>)> = vec![
        ("baseline", Arc::new(FaultPlan::empty(""))),
        ("worker_panic", Arc::new(FaultPlan::parse("worker_panic@20ms")?)),
        ("conn_drop", Arc::new(FaultPlan::parse("conn_drop:p0.05")?)),
        ("io_stall", Arc::new(FaultPlan::parse("io_stall:1ms:p0.05")?)),
        ("shed", Arc::new(FaultPlan::parse("shed_test")?)),
    ];
    if let Some(spec) = args.get("faults") {
        scenarios.push(("custom", Arc::new(FaultPlan::parse(spec)?)));
    }

    println!(
        "# chaos drill: {} scenarios, 3 x {phase_ms}ms phases each, seed {seed}{}",
        scenarios.len(),
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:14} {:>7} {:>7} {:>7} {:>9} {:>6} {:>9} {:>10}",
        "scenario", "before", "during", "after", "restarts", "shed", "degraded", "recovered"
    );
    let mut rows: Vec<Json> = Vec::new();
    for (name, plan) in &scenarios {
        let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(16_384, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, faults: Some(Arc::clone(plan)), ..Default::default() },
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0")
            .map_err(|e| anyhow!("binding a loopback port: {e}"))?;
        let server = Server::start(
            listener,
            Arc::clone(&service),
            ServerConfig { io_threads: 2, faults: Some(Arc::clone(plan)), ..Default::default() },
        )
        .map_err(|e| anyhow!("starting the {name} scenario server: {e}"))?;
        let mut cfg = LoadgenConfig::smoke(&server.local_addr().to_string(), WireProto::Memcached);
        cfg.duration = Duration::from_millis(phase_ms);
        cfg.seed = seed;
        cfg.max_reconnects = 10_000;
        cfg.faults = Some(Arc::clone(plan));
        let before = loadgen::run(&cfg)?;
        plan.arm();
        let during = loadgen::run(&cfg)?;
        plan.disarm();
        let after = loadgen::run(&cfg)?;
        server.stop();
        service.halt();
        let m = service.metrics();
        let restarts = m.worker_restarts.load(Ordering::Relaxed);
        let shed = m.shed.load(Ordering::Relaxed);
        let degraded_ops = m.degraded_ops.load(Ordering::Relaxed);
        let rejected = m.rejected_conns.load(Ordering::Relaxed);
        let evicted = m.evicted_slow.load(Ordering::Relaxed);
        let recovered = after.errors == 0 && after.ops > 0;
        println!(
            "{name:14} {:>7.3} {:>7.3} {:>7.3} {restarts:>9} {shed:>6} {degraded_ops:>9} \
             {recovered:>10}",
            availability(&before),
            availability(&during),
            availability(&after),
        );
        rows.push(Json::Object(vec![
            ("name".into(), Json::Str((*name).into())),
            ("faults".into(), Json::Str(plan.spec().into())),
            (
                "phases".into(),
                Json::Array(vec![
                    phase_row("before", &before),
                    phase_row("during", &during),
                    phase_row("after", &after),
                ]),
            ),
            ("worker_restarts".into(), Json::Int(restarts as i64)),
            ("shed".into(), Json::Int(shed as i64)),
            ("degraded_ops".into(), Json::Int(degraded_ops as i64)),
            ("rejected_conns".into(), Json::Int(rejected as i64)),
            ("evicted_slow_clients".into(), Json::Int(evicted as i64)),
            ("recovered".into(), Json::Bool(recovered)),
        ]));
    }
    let doc = Json::Object(vec![
        ("schema".into(), Json::Str(CHAOS_SCHEMA.into())),
        ("smoke".into(), Json::Bool(smoke)),
        ("seed".into(), Json::Int(seed as i64)),
        (
            "provenance".into(),
            Json::Str("kway chaos: loopback serve + loadgen fault drill".into()),
        ),
        ("scenarios".into(), Json::Array(rows)),
    ]);
    // A document that fails its own schema check is a bug, not an
    // artifact: refuse to write it.
    check_chaos_schema(&doc)
        .map_err(|e| anyhow!("chaos JSON failed the {CHAOS_SCHEMA} check: {e}"))?;
    let path = "BENCH_chaos.json";
    std::fs::write(path, format!("{doc}\n")).map_err(|e| anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// The elastic-resize sweep: for each implementation, measure the same
/// uniform get-or-fill workload before / during / after an online resize
/// from `--from` to `--to`, next to a *twin* cache built directly at the
/// target capacity. A grow passes when the after-phase hit ratio reaches
/// the twin's (the figR acceptance criterion); the during-phase column
/// quantifies the migration's throughput dip.
fn cmd_resize(args: &Args) -> Result<()> {
    use kway::throughput::measure_resize;
    let from = args.get_parsed_or("from", 1usize << 14)?;
    let to = args.get_parsed_or("to", 1usize << 15)?;
    if from == 0 || to == 0 {
        bail!("--from/--to must be positive");
    }
    let working_set = args.get_parsed_or("working-set", (from.max(to) / 4 * 3) as u64)?;
    let threads = args.get_parsed_or("threads", 4usize)?;
    let phase = Duration::from_millis(args.get_parsed_or("phase-ms", 300u64)?);
    let seed = args.get_parsed_or("seed", 42u64)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let admission = parse_admission(args)?;
    let default_impls: Vec<String> =
        ["KW-WFA", "KW-WFSC", "KW-LS", "sampled"].iter().map(|s| s.to_string()).collect();
    let impls: Vec<String> = args.get_list_or("impls", &default_impls)?;

    println!(
        "# resize sweep: {from} -> {to} working_set={working_set} threads={threads} \
         phase={phase:?} policy={} admission={}",
        policy.name(),
        admission.name()
    );
    println!(
        "{:16} {:>10} {:>10} {:>10} {:>11} {:>7} {:>7} {:>7} {:>7}",
        "impl", "before", "during", "after", "migrate(ms)", "hit0", "hitM", "hitR", "twin"
    );
    for name in &impls {
        let factory = impl_factory(name, from, threads, policy, admission)
            .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
        let twin = impl_factory(name, to, threads, policy, admission)
            .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
        let probe = factory();
        if !probe.supports_resize() {
            println!("{:16} (no online-resize support; skipped)", probe.name());
            continue;
        }
        let label = format!("{name}{}", admission.label());
        let r = measure_resize(&*factory, &*twin, to, working_set, threads, phase, seed);
        println!(
            "{:16} {:>10.2} {:>10.2} {:>10.2} {:>11.1} {:>7.3} {:>7.3} {:>7.3} {:>7.3}",
            label,
            r.before.mops,
            r.during.mops,
            r.after.mops,
            r.migrate_ms,
            r.before.hit_ratio,
            r.during.hit_ratio,
            r.after.hit_ratio,
            r.twin_hit
        );
    }
    println!(
        "\nReading: Mops/s columns are the before/during/after phases of the\n\
         online resize; hit0/hitM/hitR the matching hit ratios; `twin` is a\n\
         cache built at the target capacity outright. A grow recovers when\n\
         hitR reaches twin; `during` vs `before` is the migration's cost to\n\
         the serving path. Requested capacities are honest figures — the\n\
         k-way set count rounds to a power of two (see `kway bench --json`\n\
         requested vs effective capacity)."
    );
    Ok(())
}

/// A small named benchmark suite: trace-replay throughput for a list of
/// implementations × thread counts. Always prints the table; with
/// `--json`, also writes `BENCH_<name>.json` (schema: DESIGN.md §Bench
/// JSON) so the repo can accumulate a perf trajectory over time.
fn cmd_bench(args: &Args) -> Result<()> {
    use kway::util::json::Json;
    let trace_name = args.get_or("trace", "oltp");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = Arc::new(loader::resolve(&trace_name, len, seed)?);
    let capacity =
        args.get_parsed_or("capacity", paper::paper_cache_size(&trace_name))?;
    let default_impls: Vec<String> =
        ["KW-WFA", "KW-WFSC", "KW-LS"].iter().map(|s| s.to_string()).collect();
    let impls: Vec<String> = args.get_list_or("impls", &default_impls)?;
    let threads: Vec<usize> = args.get_list_or("threads", &[1, 4])?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 300u64)?);
    let repeats = args.get_parsed_or("repeats", 3usize)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;
    let (pin, numa_interleave) = parse_pinning(args);
    // Sanitize the run name: it becomes part of the BENCH_<name>.json
    // path, and trace specs may carry ':' / '/' (e.g. plain:/data/t.txt).
    let name: String = args
        .get_or("name", &trace_name)
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect();

    println!(
        "# bench {name}: trace={} capacity={capacity} policy={} admission={} fill={} \
         duration={duration:?} repeats={repeats} probe={}{}",
        trace.name,
        policy.name(),
        admission.name(),
        fill.label(),
        kway::kway::simd::active_kind().name(),
        if pin { " pinned" } else { "" }
    );
    println!(
        "{:20} {:>8} {:>10} {:>12} {:>12} {:>10} {:>8}",
        "impl", "threads", "Mops/s", "p50(ns)", "p99(ns)", "cyc/op", "hit"
    );
    let mut rows: Vec<Json> = Vec::new();
    for impl_name in &impls {
        // The capacity the built cache actually holds: power-of-two set
        // rounding can inflate the request up to ~2×, and the JSON
        // reports both so resize targets stay honest. Probed once per
        // implementation — it depends on (capacity, ways), not threads.
        let mut effective_capacity = 0usize;
        for &t in &threads {
            let factory = impl_factory(impl_name, capacity, t, policy, admission)
                .ok_or_else(|| anyhow!("unknown impl {impl_name:?}"))?;
            if effective_capacity == 0 {
                effective_capacity = factory().capacity();
            }
            let cfg = RunConfig {
                threads: t,
                duration,
                repeats,
                seed,
                fill: fill.clone(),
                resize: None,
                pin,
                numa_interleave,
            };
            let r = measure(&*factory, &Workload::TraceReplay(trace.clone()), &cfg);
            let label = format!("{impl_name}{}", admission.label());
            println!(
                "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>10.1} {:>8.3}",
                label,
                t,
                r.mops.mean(),
                r.lat_p50_ns,
                r.lat_p99_ns,
                r.cycles_per_op,
                r.hit_ratio
            );
            rows.push(Json::Object(vec![
                ("impl".to_string(), Json::Str(label)),
                ("threads".to_string(), Json::Int(t as i64)),
                ("effective_capacity".to_string(), Json::Int(effective_capacity as i64)),
                ("mops_mean".to_string(), Json::Float(r.mops.mean())),
                ("mops_stddev".to_string(), Json::Float(r.mops.stddev())),
                ("p50_ns".to_string(), Json::Int(r.lat_p50_ns as i64)),
                ("p99_ns".to_string(), Json::Int(r.lat_p99_ns as i64)),
                ("cycles_per_op".to_string(), Json::Float(r.cycles_per_op)),
                ("hit_ratio".to_string(), Json::Float(r.hit_ratio)),
            ]));
        }
    }
    if args.has_flag("json") {
        // Schema v4 = v3 plus the hot-path figures: per-row
        // `cycles_per_op` and top-level `probe_kind`/`pinned`, so a
        // bench artifact records which probe kernel produced it; see
        // DESIGN.md §Bench JSON. `capacity` stays for v2-reader
        // continuity, `requested_capacity`/`effective_capacity` from v3.
        let ttl_ms = fill.ttl.map_or(0, |d| d.as_millis() as i64);
        let doc = Json::Object(vec![
            ("schema".to_string(), Json::Str(kway::util::json::BENCH_SCHEMA.to_string())),
            ("name".to_string(), Json::Str(name.clone())),
            ("trace".to_string(), Json::Str(trace.name.clone())),
            ("capacity".to_string(), Json::Int(capacity as i64)),
            ("requested_capacity".to_string(), Json::Int(capacity as i64)),
            ("policy".to_string(), Json::Str(policy.name().to_string())),
            ("admission".to_string(), Json::Str(admission.name().to_string())),
            ("ttl_ms".to_string(), Json::Int(ttl_ms)),
            ("weight_dist".to_string(), Json::Str(fill.weight_dist.name())),
            ("duration_ms".to_string(), Json::Int(duration.as_millis() as i64)),
            ("repeats".to_string(), Json::Int(repeats as i64)),
            ("seed".to_string(), Json::Int(seed as i64)),
            (
                "probe_kind".to_string(),
                Json::Str(kway::kway::simd::active_kind().name().to_string()),
            ),
            ("pinned".to_string(), Json::Bool(pin)),
            ("results".to_string(), Json::Array(rows)),
        ]);
        // A document that fails its own schema check is a bug, not an
        // artifact: refuse to write it.
        kway::util::json::check_bench_schema(&doc)
            .map_err(|e| anyhow!("bench JSON failed the {} check: {e}", "kway-bench-v4"))?;
        let path = format!("BENCH_{name}.json");
        std::fs::write(&path, format!("{doc}\n"))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use kway::runtime::XlaRuntime;
    use kway::sim::xla::{NativeSetSim, XlaSim};
    let dir = args.get_or("artifacts", "artifacts");
    let trace_name = args.get_or("trace", "oltp");
    let rt = XlaRuntime::load(&dir)?;
    println!("platform: {}; artifacts: {:?}", rt.platform(), rt.entry_names());
    let sim = XlaSim::new(&rt, "cache_sim_k8")?;
    let trace = loader::resolve(&trace_name, 4 * sim.chunk, 42)?;
    let xla = sim.run(&trace)?;
    let native = NativeSetSim::new(sim.num_sets, sim.ways).run(&trace.keys);
    println!(
        "trace={} accesses={} xla_hits={} native_hits={} -> {}",
        trace.name,
        xla.accesses,
        xla.hits,
        native.hits,
        if xla.hits == native.hits { "MATCH" } else { "MISMATCH" }
    );
    if xla.hits != native.hits {
        bail!("XLA / native divergence");
    }
    Ok(())
}

fn cmd_ballsbins(args: &Args) -> Result<()> {
    use kway::analysis::{monte_carlo_overflow, theorem41_bound};
    let trials = args.get_parsed_or("trials", 500u32)?;
    println!("# Theorem 4.1: bound vs Monte-Carlo ({} trials)", trials);
    println!("{:>10} {:>10} {:>6} {:>12} {:>12}", "C", "C'", "k", "bound", "empirical");
    for (c, cp, k) in [
        (2048u64, 4096u64, 16u64),
        (4096, 8192, 32),
        (4096, 8192, 64),
        (100_000, 200_000, 64),
        (1_000_000, 2_000_000, 128),
    ] {
        let bound = theorem41_bound(cp, k);
        let mc = monte_carlo_overflow(c, cp, k, trials, 7);
        println!("{c:>10} {cp:>10} {k:>6} {bound:>12.3e} {mc:>12.4}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("trace models: {}", paper::ALL.join(", "));
    println!("implementations: {}", IMPLS.join(", "));
    println!("policies: lru, lfu, fifo, random, hyperbolic");
    println!(
        "probe kernel: {} (available: {})",
        kway::kway::simd::active_kind().name(),
        kway::kway::simd::ProbeKind::available()
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    match kway::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => println!("artifacts ({}): {:?}", rt.platform(), rt.entry_names()),
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
