//! `kway` — launcher for the limited-associativity cache system.
//!
//! Subcommands:
//!   hitratio    hit-ratio sweep on a trace (Figures 4–13 series)
//!   throughput  multi-threaded trace-replay throughput (Figures 14–26)
//!   synthetic   synthetic-mix throughput (Figures 27–30)
//!   batch       batched-get sweep: Mops/s + per-batch p50/p99 vs batch size
//!   bench       named benchmark suite; --json writes BENCH_<name>.json
//!   serve       run the cache service demo (router + workers + metrics)
//!   validate    cross-check the XLA artifacts against the native engine
//!   ballsbins   Theorem 4.1 bound vs Monte-Carlo
//!   info        list trace models, implementations and artifacts
//!
//! `throughput`, `synthetic`, `batch`, `bench` and `serve` all take
//! `--admission none|tlfu`: `tlfu` layers the concurrent TinyLFU
//! admission filter (`kway::tinylfu::TlfuCache`) over every cache they
//! build. They also take the lifetime options `--ttl <dur>` (every fill
//! carries that TTL; on `serve` it becomes the service-wide default) and
//! `--weight-dist unit|uniform[:MAX]|zipf[:MAX]` (deterministic per-key
//! entry weights against the weight-based capacity); `synthetic
//! --workload expiring` is the dedicated TTL-churn scenario.

use anyhow::{anyhow, bail, Result};
use kway::lifetime::{parse_duration, WeightDist};
use kway::policy::Policy;
use kway::sim::{self, Config};
use kway::throughput::{impl_factory, measure, FillSpec, RunConfig, Workload, IMPLS};
use kway::tinylfu::AdmissionMode;
use kway::trace::{loader, paper};
use kway::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("hitratio") => cmd_hitratio(&args),
        Some("throughput") => cmd_throughput(&args),
        Some("synthetic") => cmd_synthetic(&args),
        Some("batch") => cmd_batch(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("ballsbins") => cmd_ballsbins(&args),
        Some("info") => cmd_info(),
        other => {
            eprintln!("unknown or missing subcommand {other:?}\n");
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "usage: kway <subcommand> [--options]
  hitratio   --trace oltp --capacity 2048 [--series lru|lfu|products|hyperbolic|all] [--len N]
  throughput --trace f1 [--impls KW-WFSC,sampled,...] [--threads 1,2,4,8] [--duration-ms 500] [--repeats 5] [--policy lru] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8]
  synthetic  --workload miss100|hit100|hit95|hit90|expiring [--capacity 2097152] [--threads ...] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8]
  batch      [--batch 1,8,32,128] [--impls KW-WFA,KW-WFSC,KW-LS] [--threads 4] [--capacity 262144] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8]
  bench      [--name oltp] [--trace oltp] [--impls KW-WFA,KW-WFSC,KW-LS] [--threads 1,4] [--policy lru] [--admission none|tlfu] [--ttl 100ms] [--weight-dist zipf:8] [--json]
  serve      [--capacity 65536] [--workers 4] [--clients 8] [--requests 20000] [--batch 0] [--admission none|tlfu] [--ttl 100ms]
  validate   [--artifacts artifacts] [--trace oltp]
  ballsbins  [--trials 500]
  info";

/// Parse the shared `--admission none|tlfu` option.
fn parse_admission(args: &Args) -> Result<AdmissionMode> {
    let raw = args.get_or("admission", "none");
    AdmissionMode::parse(&raw).ok_or_else(|| anyhow!("bad --admission {raw:?} (none|tlfu)"))
}

/// Parse the shared `--ttl <dur>` / `--weight-dist <dist>` fill options
/// (e.g. `--ttl 100ms --weight-dist zipf:8`). Absent options leave the
/// fill plain: immortal entries of weight 1, the pre-lifetime behaviour.
fn parse_fill(args: &Args) -> Result<FillSpec> {
    let ttl = match args.get("ttl") {
        None => None,
        Some(raw) => Some(
            parse_duration(raw)
                .ok_or_else(|| anyhow!("bad --ttl {raw:?} (e.g. 100ms, 2s, 250us)"))?,
        ),
    };
    let weight_dist = match args.get("weight-dist") {
        None => WeightDist::Unit,
        Some(raw) => WeightDist::parse(raw)
            .ok_or_else(|| anyhow!("bad --weight-dist {raw:?} (unit|uniform[:MAX]|zipf[:MAX])"))?,
    };
    Ok(FillSpec { ttl, weight_dist })
}

fn cmd_hitratio(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "oltp");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = loader::resolve(&trace_name, len, seed)?;
    let capacity = args.get_parsed_or("capacity", 2048usize)?;
    let series = args.get_or("series", "lru");

    let mut configs: Vec<Config> = Vec::new();
    match series.as_str() {
        "lru" => configs.extend(sim::lru_series()),
        "lfu" => configs.extend(sim::lfu_tlfu_series()),
        "products" => configs.extend(sim::products_series(8)),
        "hyperbolic" => configs.extend(sim::hyperbolic_series(false)),
        "hyperbolic-tlfu" => configs.extend(sim::hyperbolic_series(true)),
        "all" => {
            configs.extend(sim::lru_series());
            configs.extend(sim::lfu_tlfu_series());
            configs.extend(sim::products_series(8));
            configs.extend(sim::hyperbolic_series(false));
        }
        other => bail!("unknown series {other:?}"),
    }

    println!(
        "# hit-ratio: trace={} len={} unique={} capacity={}",
        trace.name,
        trace.len(),
        trace.unique_keys(),
        capacity
    );
    for row in sim::sweep(&trace, capacity, &configs, seed) {
        println!("{:32} {:.4}", row.label, row.hit_ratio);
    }
    Ok(())
}

fn parse_threads(args: &Args) -> Result<Vec<usize>> {
    args.get_list_or("threads", &[1, 2, 4, 8])
}

fn cmd_throughput(args: &Args) -> Result<()> {
    let trace_name = args.get_or("trace", "f1");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = Arc::new(loader::resolve(&trace_name, len, seed)?);
    let capacity =
        args.get_parsed_or("capacity", paper::paper_cache_size(&trace_name))?;
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;

    println!(
        "# throughput: trace={} capacity={} duration={:?} repeats={} admission={} fill={} (Mops/s)",
        trace.name,
        capacity,
        duration,
        repeats,
        admission.name(),
        fill.label()
    );
    print!("{:20}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!("   p50/p99(ns)");
    for name in &impls {
        let workload = Workload::TraceReplay(trace.clone());
        let label = format!("{name}{}", admission.label());
        print!("{label:20}");
        let mut last_lat = (0u64, 0u64);
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, policy, admission)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig { threads: t, duration, repeats, seed, fill: fill.clone() };
            let r = measure(&*factory, &workload, &cfg);
            last_lat = (r.lat_p50_ns, r.lat_p99_ns);
            print!(" {:10.2}", r.mops.mean());
        }
        // Latency of the highest thread count (sampled per access).
        println!("   {}/{}", last_lat.0, last_lat.1);
    }
    Ok(())
}

fn cmd_synthetic(args: &Args) -> Result<()> {
    let which = args.get_or("workload", "miss100");
    let capacity = args.get_parsed_or("capacity", 1usize << 21)?;
    let working_set = (capacity / 2) as u64;
    let workload = match which.as_str() {
        "miss100" => Workload::AllMiss,
        "hit100" => Workload::AllHit { working_set },
        "hit95" => Workload::HitRatio { working_set, gets_per_put: 19 },
        "hit90" => Workload::HitRatio { working_set, gets_per_put: 9 },
        "expiring" => Workload::Expiring { working_set },
        other => bail!("unknown workload {other:?} (miss100|hit100|hit95|hit90|expiring)"),
    };
    let impls: Vec<String> = args.get_list_or("impls", &IMPLS.map(String::from))?;
    let threads = parse_threads(args)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 500u64)?);
    let repeats = args.get_parsed_or("repeats", 5usize)?;
    let seed = args.get_parsed_or("seed", 42u64)?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;

    println!(
        "# synthetic {}: capacity={} duration={:?} repeats={} admission={} fill={} (Mops/s)",
        workload.label(),
        capacity,
        duration,
        repeats,
        admission.name(),
        fill.label()
    );
    print!("{:20}", "impl\\threads");
    for t in &threads {
        print!(" {t:>10}");
    }
    println!("   p50/p99(ns)");
    for name in &impls {
        let label = format!("{name}{}", admission.label());
        print!("{label:20}");
        let mut last_lat = (0u64, 0u64);
        for &t in &threads {
            let factory = impl_factory(name, capacity, t, Policy::Lru, admission)
                .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
            let cfg = RunConfig { threads: t, duration, repeats, seed, fill: fill.clone() };
            let r = measure(&*factory, &workload, &cfg);
            last_lat = (r.lat_p50_ns, r.lat_p99_ns);
            print!(" {:10.2}", r.mops.mean());
        }
        println!("   {}/{}", last_lat.0, last_lat.1);
    }
    Ok(())
}

/// The batched-access sweep: Mops/s and per-batch latency percentiles vs
/// batch size, for the k-way variants. The `1-by-1` row is the scalar
/// path over the same key distribution, as the baseline.
fn cmd_batch(args: &Args) -> Result<()> {
    let capacity = args.get_parsed_or("capacity", 1usize << 18)?;
    let working_set = (capacity / 2) as u64;
    let batches: Vec<usize> = args.get_list_or("batch", &[1, 8, 32, 128])?;
    let default_impls: Vec<String> =
        ["KW-WFA", "KW-WFSC", "KW-LS"].iter().map(|s| s.to_string()).collect();
    let impls: Vec<String> = args.get_list_or("impls", &default_impls)?;
    let threads = args.get_parsed_or("threads", 4usize)?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 300u64)?);
    let repeats = args.get_parsed_or("repeats", 3usize)?;
    let seed = args.get_parsed_or("seed", 42u64)?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;

    println!(
        "# batch sweep: capacity={capacity} working_set={working_set} threads={threads} \
         duration={duration:?} repeats={repeats} admission={} fill={}",
        admission.name(),
        fill.label()
    );
    println!(
        "{:20} {:>8} {:>10} {:>12} {:>12} {:>8}",
        "impl", "batch", "Mops/s", "p50(ns)", "p99(ns)", "hit"
    );
    for name in &impls {
        let factory = impl_factory(name, capacity, threads, Policy::Lru, admission)
            .ok_or_else(|| anyhow!("unknown impl {name:?}"))?;
        let label = format!("{name}{}", admission.label());
        let cfg = RunConfig { threads, duration, repeats, seed, fill: fill.clone() };
        // Baseline: the same resident-set gets, one key per call.
        let base = measure(&*factory, &Workload::AllHit { working_set }, &cfg);
        println!(
            "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
            label, "1-by-1", base.mops.mean(), base.lat_p50_ns, base.lat_p99_ns, base.hit_ratio
        );
        for &batch in &batches {
            let r = measure(&*factory, &Workload::Batched { working_set, batch }, &cfg);
            println!(
                "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
                label, batch, r.mops.mean(), r.lat_p50_ns, r.lat_p99_ns, r.hit_ratio
            );
        }
    }
    println!(
        "\nReading: batched rows amortize hashing and prefetch set lines a\n\
         chunk at a time; p50/p99 are per get_batch call (one whole batch),\n\
         the 1-by-1 row per single get."
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use kway::coordinator::{CacheService, ServiceConfig};
    use kway::kway::KwWfsc;
    let capacity = args.get_parsed_or("capacity", 65_536usize)?;
    let workers = args.get_parsed_or("workers", 4usize)?;
    let clients = args.get_parsed_or("clients", 8usize)?;
    let requests = args.get_parsed_or("requests", 20_000usize)?;
    // --batch N > 0 switches the clients to scatter/gather get_batch calls
    // of N keys (misses refilled with put_batch).
    let batch = args.get_parsed_or("batch", 0usize)?;
    let admission = parse_admission(args)?;
    // --ttl <dur> becomes the service-wide default entry lifetime: every
    // routed put carries it unless the caller passes explicit options.
    let default_ttl = parse_fill(args)?.ttl;
    let cache: Arc<dyn kway::Cache> = Arc::new(KwWfsc::new(capacity, 8, Policy::Lru));
    println!(
        "serving: cache={}{} capacity={} workers={workers} clients={clients} x {requests} reqs{}{}",
        cache.name(),
        admission.label(),
        cache.capacity(),
        if batch > 0 { format!(" (batched x{batch})") } else { String::new() },
        match default_ttl {
            Some(ttl) => format!(" (ttl {ttl:?})"),
            None => String::new(),
        }
    );
    let service = CacheService::start(cache, ServiceConfig { workers, admission, default_ttl });
    let keyspace = (capacity * 4) as u64;
    let secs = if batch > 0 {
        kway::coordinator::drive_clients_batched(&service, clients, requests, batch, keyspace, 7)
    } else {
        kway::coordinator::drive_clients(&service, clients, requests, keyspace, 7)
    };
    // Batched clients round the request count up to whole batches.
    let per_client = if batch > 0 { requests.div_ceil(batch) * batch } else { requests };
    let total = (clients * per_client) as f64;
    println!(
        "done in {secs:.2}s — {:.0} req/s\n{}",
        total / secs,
        service.metrics().report()
    );
    service.shutdown();
    Ok(())
}

/// A small named benchmark suite: trace-replay throughput for a list of
/// implementations × thread counts. Always prints the table; with
/// `--json`, also writes `BENCH_<name>.json` (schema: DESIGN.md §Bench
/// JSON) so the repo can accumulate a perf trajectory over time.
fn cmd_bench(args: &Args) -> Result<()> {
    use kway::util::json::Json;
    let trace_name = args.get_or("trace", "oltp");
    let seed = args.get_parsed_or("seed", 42u64)?;
    let len = args.get_parsed_or("len", 0usize)?;
    let len = if len == 0 { paper::default_len(&trace_name) } else { len };
    let trace = Arc::new(loader::resolve(&trace_name, len, seed)?);
    let capacity =
        args.get_parsed_or("capacity", paper::paper_cache_size(&trace_name))?;
    let default_impls: Vec<String> =
        ["KW-WFA", "KW-WFSC", "KW-LS"].iter().map(|s| s.to_string()).collect();
    let impls: Vec<String> = args.get_list_or("impls", &default_impls)?;
    let threads: Vec<usize> = args.get_list_or("threads", &[1, 4])?;
    let duration = Duration::from_millis(args.get_parsed_or("duration-ms", 300u64)?);
    let repeats = args.get_parsed_or("repeats", 3usize)?;
    let policy = Policy::parse(&args.get_or("policy", "lru"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let admission = parse_admission(args)?;
    let fill = parse_fill(args)?;
    // Sanitize the run name: it becomes part of the BENCH_<name>.json
    // path, and trace specs may carry ':' / '/' (e.g. plain:/data/t.txt).
    let name: String = args
        .get_or("name", &trace_name)
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect();

    println!(
        "# bench {name}: trace={} capacity={capacity} policy={} admission={} fill={} \
         duration={duration:?} repeats={repeats}",
        trace.name,
        policy.name(),
        admission.name(),
        fill.label()
    );
    println!(
        "{:20} {:>8} {:>10} {:>12} {:>12} {:>8}",
        "impl", "threads", "Mops/s", "p50(ns)", "p99(ns)", "hit"
    );
    let mut rows: Vec<Json> = Vec::new();
    for impl_name in &impls {
        for &t in &threads {
            let factory = impl_factory(impl_name, capacity, t, policy, admission)
                .ok_or_else(|| anyhow!("unknown impl {impl_name:?}"))?;
            let cfg = RunConfig { threads: t, duration, repeats, seed, fill: fill.clone() };
            let r = measure(&*factory, &Workload::TraceReplay(trace.clone()), &cfg);
            let label = format!("{impl_name}{}", admission.label());
            println!(
                "{:20} {:>8} {:>10.2} {:>12} {:>12} {:>8.3}",
                label,
                t,
                r.mops.mean(),
                r.lat_p50_ns,
                r.lat_p99_ns,
                r.hit_ratio
            );
            rows.push(Json::Object(vec![
                ("impl".to_string(), Json::Str(label)),
                ("threads".to_string(), Json::Int(t as i64)),
                ("mops_mean".to_string(), Json::Float(r.mops.mean())),
                ("mops_stddev".to_string(), Json::Float(r.mops.stddev())),
                ("p50_ns".to_string(), Json::Int(r.lat_p50_ns as i64)),
                ("p99_ns".to_string(), Json::Int(r.lat_p99_ns as i64)),
                ("hit_ratio".to_string(), Json::Float(r.hit_ratio)),
            ]));
        }
    }
    if args.has_flag("json") {
        // Schema v2 = v1 plus the fill options (ttl_ms 0 = immortal);
        // see DESIGN.md §Bench JSON.
        let ttl_ms = fill.ttl.map_or(0, |d| d.as_millis() as i64);
        let doc = Json::Object(vec![
            ("schema".to_string(), Json::Str("kway-bench-v2".to_string())),
            ("name".to_string(), Json::Str(name.clone())),
            ("trace".to_string(), Json::Str(trace.name.clone())),
            ("capacity".to_string(), Json::Int(capacity as i64)),
            ("policy".to_string(), Json::Str(policy.name().to_string())),
            ("admission".to_string(), Json::Str(admission.name().to_string())),
            ("ttl_ms".to_string(), Json::Int(ttl_ms)),
            ("weight_dist".to_string(), Json::Str(fill.weight_dist.name())),
            ("duration_ms".to_string(), Json::Int(duration.as_millis() as i64)),
            ("repeats".to_string(), Json::Int(repeats as i64)),
            ("seed".to_string(), Json::Int(seed as i64)),
            ("results".to_string(), Json::Array(rows)),
        ]);
        let path = format!("BENCH_{name}.json");
        std::fs::write(&path, format!("{doc}\n"))
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use kway::runtime::XlaRuntime;
    use kway::sim::xla::{NativeSetSim, XlaSim};
    let dir = args.get_or("artifacts", "artifacts");
    let trace_name = args.get_or("trace", "oltp");
    let rt = XlaRuntime::load(&dir)?;
    println!("platform: {}; artifacts: {:?}", rt.platform(), rt.entry_names());
    let sim = XlaSim::new(&rt, "cache_sim_k8")?;
    let trace = loader::resolve(&trace_name, 4 * sim.chunk, 42)?;
    let xla = sim.run(&trace)?;
    let native = NativeSetSim::new(sim.num_sets, sim.ways).run(&trace.keys);
    println!(
        "trace={} accesses={} xla_hits={} native_hits={} -> {}",
        trace.name,
        xla.accesses,
        xla.hits,
        native.hits,
        if xla.hits == native.hits { "MATCH" } else { "MISMATCH" }
    );
    if xla.hits != native.hits {
        bail!("XLA / native divergence");
    }
    Ok(())
}

fn cmd_ballsbins(args: &Args) -> Result<()> {
    use kway::analysis::{monte_carlo_overflow, theorem41_bound};
    let trials = args.get_parsed_or("trials", 500u32)?;
    println!("# Theorem 4.1: bound vs Monte-Carlo ({} trials)", trials);
    println!("{:>10} {:>10} {:>6} {:>12} {:>12}", "C", "C'", "k", "bound", "empirical");
    for (c, cp, k) in [
        (2048u64, 4096u64, 16u64),
        (4096, 8192, 32),
        (4096, 8192, 64),
        (100_000, 200_000, 64),
        (1_000_000, 2_000_000, 128),
    ] {
        let bound = theorem41_bound(cp, k);
        let mc = monte_carlo_overflow(c, cp, k, trials, 7);
        println!("{c:>10} {cp:>10} {k:>6} {bound:>12.3e} {mc:>12.4}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("trace models: {}", paper::ALL.join(", "));
    println!("implementations: {}", IMPLS.join(", "));
    println!("policies: lru, lfu, fifo, random, hyperbolic");
    match kway::runtime::XlaRuntime::load("artifacts") {
        Ok(rt) => println!("artifacts ({}): {:?}", rt.platform(), rt.entry_names()),
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
