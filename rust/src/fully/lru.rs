//! Exact fully-associative LRU: intrusive doubly-linked list over a slab
//! plus a key→slot index. This is the paper's "fully associative"
//! hit-ratio line and the textbook structure whose head-of-list contention
//! motivates the whole work (§1, §2.4).
//!
//! TTL support (so expiring-workload comparisons against the k-way
//! designs stay apples-to-apples) is a side deadline map consulted on
//! access — note the contrast with the k-way caches, where the deadline
//! rides *inside* the set and reclamation folds into the probe: a
//! fully-associative design has no set to scan, so it pays an extra map
//! lookup per access instead (DESIGN.md §Expiration).

use super::SimVictimPeek;
use crate::lifetime::{self, EntryOpts};
use crate::SimCache;
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
}

/// Exact linked-list LRU cache (single-threaded; simulator baseline).
pub struct LruList {
    capacity: usize,
    map: HashMap<u64, u32>,
    nodes: Vec<Node>,
    head: u32,
    tail: u32,
    free: Vec<u32>,
    /// Expiry deadlines (coarse ms) for the keys that carry a TTL;
    /// immortal keys stay out of the map entirely, so TTL-free
    /// simulations never pay for it.
    deadlines: HashMap<u64, u64>,
}

impl LruList {
    /// Build an LRU list holding at most `capacity` keys.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            map: HashMap::with_capacity(capacity),
            nodes: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            deadlines: HashMap::new(),
        }
    }

    /// Number of resident keys (expired-but-unreclaimed keys included).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Maximum number of resident keys.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Is `key` resident but past its deadline?
    fn expired(&self, key: u64) -> bool {
        self.deadlines.get(&key).is_some_and(|&d| d <= lifetime::now_ms())
    }

    /// Drop a resident key entirely (expire-on-access reclamation).
    fn remove(&mut self, key: u64) {
        if let Some(idx) = self.map.remove(&key) {
            self.unlink(idx);
            self.free.push(idx);
        }
        self.deadlines.remove(&key);
    }

    fn unlink(&mut self, idx: u32) {
        let node = self.nodes[idx as usize];
        match node.prev {
            NIL => self.head = node.next,
            p => self.nodes[p as usize].next = node.next,
        }
        match node.next {
            NIL => self.tail = node.prev,
            n => self.nodes[n as usize].prev = node.prev,
        }
    }

    fn push_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let node = &mut self.nodes[idx as usize];
            node.prev = NIL;
            node.next = old_head;
        }
        if old_head != NIL {
            self.nodes[old_head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn touch(&mut self, idx: u32) {
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
    }

    /// The key currently at the LRU position.
    pub fn lru_key(&self) -> Option<u64> {
        (self.tail != NIL).then(|| self.nodes[self.tail as usize].key)
    }

    fn evict_lru(&mut self) -> u32 {
        let idx = self.tail;
        debug_assert_ne!(idx, NIL);
        let key = self.nodes[idx as usize].key;
        self.unlink(idx);
        self.map.remove(&key);
        self.deadlines.remove(&key);
        idx
    }

    fn insert(&mut self, key: u64) {
        debug_assert!(!self.map.contains_key(&key));
        let idx = if self.map.len() >= self.capacity {
            self.evict_lru()
        } else if let Some(idx) = self.free.pop() {
            idx
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.nodes[idx as usize].key = key;
        self.push_front(idx);
        self.map.insert(key, idx);
    }
}

impl SimCache for LruList {
    fn sim_get(&mut self, key: u64) -> bool {
        if self.expired(key) {
            self.remove(key); // expire-on-access; an expired key never hits
            return false;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.touch(idx);
            true
        } else {
            false
        }
    }

    fn sim_put(&mut self, key: u64) {
        self.sim_put_with(key, EntryOpts::default())
    }

    fn sim_put_with(&mut self, key: u64, opts: EntryOpts) {
        if let Some(&idx) = self.map.get(&key) {
            self.touch(idx);
        } else {
            self.insert(key);
        }
        // A (re-)insert restarts the lifetime: record the new deadline,
        // or clear a stale one when the entry becomes immortal.
        match opts.ttl {
            Some(_) => {
                let d = lifetime::deadline_ms(opts.ttl, lifetime::now_ms());
                self.deadlines.insert(key, d);
            }
            None => {
                self.deadlines.remove(&key);
            }
        }
    }

    fn sim_name(&self) -> String {
        "full-LRU".into()
    }
}

impl SimVictimPeek for LruList {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        if self.map.len() >= self.capacity {
            self.lru_key()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_lru_order() {
        let mut c = LruList::new(3);
        c.sim_put(1);
        c.sim_put(2);
        c.sim_put(3);
        assert!(c.sim_get(1)); // order now: 1,3,2 (MRU..LRU)
        c.sim_put(4); // evicts 2
        assert!(!c.sim_get(2));
        assert!(c.sim_get(1));
        assert!(c.sim_get(3));
        assert!(c.sim_get(4));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn repeated_put_does_not_duplicate() {
        let mut c = LruList::new(2);
        c.sim_put(7);
        c.sim_put(7);
        c.sim_put(7);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn peek_matches_actual_eviction() {
        let mut c = LruList::new(3);
        for k in 0..3 {
            c.sim_put(k);
        }
        let victim = c.sim_peek_victim(99).unwrap();
        c.sim_put(99);
        assert!(!c.sim_get(victim), "peeked victim {victim} must be evicted");
    }

    #[test]
    fn expired_keys_never_hit_and_are_reclaimed() {
        use std::time::Duration;
        let mut c = LruList::new(4);
        c.sim_put_with(1, EntryOpts::ttl(Duration::ZERO));
        c.sim_put_with(2, EntryOpts::ttl(Duration::from_secs(3600)));
        c.sim_put(3);
        assert!(!c.sim_get(1), "zero-TTL key is born expired");
        assert_eq!(c.len(), 2, "expire-on-access reclaims the slot");
        assert!(c.sim_get(2));
        assert!(c.sim_get(3));
        // Re-inserting an expired key revives it (immortal this time).
        c.sim_put(1);
        assert!(c.sim_get(1));
        // Eviction of a TTL'd key must not leak its deadline: key 2's
        // deadline is gone once LRU pressure pushes it out.
        for k in 10..14 {
            c.sim_put(k);
        }
        assert!(c.deadlines.is_empty(), "evicted keys must drop deadlines");
    }

    #[test]
    fn capacity_one() {
        let mut c = LruList::new(1);
        c.sim_put(1);
        c.sim_put(2);
        assert!(!c.sim_get(1));
        assert!(c.sim_get(2));
    }

    #[test]
    fn model_equivalence_property() {
        // Compare against a naive O(n) vector model of LRU.
        crate::util::check::check("lru-vs-naive", 20, |rng| {
            let cap = 1 + rng.index(20);
            let mut c = LruList::new(cap);
            let mut model: Vec<u64> = Vec::new(); // front = MRU
            for _ in 0..1000 {
                let key = rng.below(60);
                if rng.chance(0.5) {
                    let hit = c.sim_get(key);
                    let mhit = model.contains(&key);
                    assert_eq!(hit, mhit, "get({key}) mismatch");
                    if mhit {
                        model.retain(|&k| k != key);
                        model.insert(0, key);
                    }
                } else {
                    c.sim_put(key);
                    if model.contains(&key) {
                        model.retain(|&k| k != key);
                    } else if model.len() >= cap {
                        model.pop();
                    }
                    model.insert(0, key);
                }
            }
        });
    }
}
