//! Exact fully-associative LRU: intrusive doubly-linked list over a slab
//! plus a key→slot index. This is the paper's "fully associative"
//! hit-ratio line and the textbook structure whose head-of-list contention
//! motivates the whole work (§1, §2.4).

use super::SimVictimPeek;
use crate::SimCache;
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
}

/// Exact linked-list LRU cache (single-threaded; simulator baseline).
pub struct LruList {
    capacity: usize,
    map: HashMap<u64, u32>,
    nodes: Vec<Node>,
    head: u32,
    tail: u32,
    free: Vec<u32>,
}

impl LruList {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            map: HashMap::with_capacity(capacity),
            nodes: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn unlink(&mut self, idx: u32) {
        let node = self.nodes[idx as usize];
        match node.prev {
            NIL => self.head = node.next,
            p => self.nodes[p as usize].next = node.next,
        }
        match node.next {
            NIL => self.tail = node.prev,
            n => self.nodes[n as usize].prev = node.prev,
        }
    }

    fn push_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let node = &mut self.nodes[idx as usize];
            node.prev = NIL;
            node.next = old_head;
        }
        if old_head != NIL {
            self.nodes[old_head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn touch(&mut self, idx: u32) {
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
    }

    /// The key currently at the LRU position.
    pub fn lru_key(&self) -> Option<u64> {
        (self.tail != NIL).then(|| self.nodes[self.tail as usize].key)
    }

    fn evict_lru(&mut self) -> u32 {
        let idx = self.tail;
        debug_assert_ne!(idx, NIL);
        let key = self.nodes[idx as usize].key;
        self.unlink(idx);
        self.map.remove(&key);
        idx
    }

    fn insert(&mut self, key: u64) {
        debug_assert!(!self.map.contains_key(&key));
        let idx = if self.map.len() >= self.capacity {
            self.evict_lru()
        } else if let Some(idx) = self.free.pop() {
            idx
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.nodes[idx as usize].key = key;
        self.push_front(idx);
        self.map.insert(key, idx);
    }
}

impl SimCache for LruList {
    fn sim_get(&mut self, key: u64) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.touch(idx);
            true
        } else {
            false
        }
    }

    fn sim_put(&mut self, key: u64) {
        if let Some(&idx) = self.map.get(&key) {
            self.touch(idx);
        } else {
            self.insert(key);
        }
    }

    fn sim_name(&self) -> String {
        "full-LRU".into()
    }
}

impl SimVictimPeek for LruList {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        if self.map.len() >= self.capacity {
            self.lru_key()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_lru_order() {
        let mut c = LruList::new(3);
        c.sim_put(1);
        c.sim_put(2);
        c.sim_put(3);
        assert!(c.sim_get(1)); // order now: 1,3,2 (MRU..LRU)
        c.sim_put(4); // evicts 2
        assert!(!c.sim_get(2));
        assert!(c.sim_get(1));
        assert!(c.sim_get(3));
        assert!(c.sim_get(4));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn repeated_put_does_not_duplicate() {
        let mut c = LruList::new(2);
        c.sim_put(7);
        c.sim_put(7);
        c.sim_put(7);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn peek_matches_actual_eviction() {
        let mut c = LruList::new(3);
        for k in 0..3 {
            c.sim_put(k);
        }
        let victim = c.sim_peek_victim(99).unwrap();
        c.sim_put(99);
        assert!(!c.sim_get(victim), "peeked victim {victim} must be evicted");
    }

    #[test]
    fn capacity_one() {
        let mut c = LruList::new(1);
        c.sim_put(1);
        c.sim_put(2);
        assert!(!c.sim_get(1));
        assert!(c.sim_get(2));
    }

    #[test]
    fn model_equivalence_property() {
        // Compare against a naive O(n) vector model of LRU.
        crate::util::check::check("lru-vs-naive", 20, |rng| {
            let cap = 1 + rng.index(20);
            let mut c = LruList::new(cap);
            let mut model: Vec<u64> = Vec::new(); // front = MRU
            for _ in 0..1000 {
                let key = rng.below(60);
                if rng.chance(0.5) {
                    let hit = c.sim_get(key);
                    let mhit = model.contains(&key);
                    assert_eq!(hit, mhit, "get({key}) mismatch");
                    if mhit {
                        model.retain(|&k| k != key);
                        model.insert(0, key);
                    }
                } else {
                    c.sim_put(key);
                    if model.contains(&key) {
                        model.retain(|&k| k != key);
                    } else if model.len() >= cap {
                        model.pop();
                    }
                    model.insert(0, key);
                }
            }
        });
    }
}
