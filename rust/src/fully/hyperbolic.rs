//! Fully-associative Hyperbolic caching (Blankstein et al., ATC'17), as
//! that paper itself makes it practical: each entry carries
//! `(access count n, insert time t0)` and at eviction the priority
//! `n / (now − t0)` is computed for a uniform *sample* of resident
//! entries; the minimum is evicted. `sample >= capacity` gives the exact
//! (O(n)-scan) variant for small caches.

use super::SimVictimPeek;
use crate::util::rng::Rng;
use crate::SimCache;
use std::collections::HashMap;

#[derive(Clone, Copy)]
struct Meta {
    count: u64,
    t0: u64,
}

/// Hyperbolic cache with sampled eviction (single-threaded baseline).
pub struct HyperbolicFull {
    capacity: usize,
    sample: usize,
    keys: Vec<u64>,
    index: HashMap<u64, usize>,
    metas: Vec<Meta>,
    rng: Rng,
    now: u64,
}

impl HyperbolicFull {
    /// `sample = 64` reproduces the original system's default; pass
    /// `sample >= capacity` for exact hyperbolic caching.
    pub fn new(capacity: usize, sample: usize, seed: u64) -> Self {
        assert!(capacity > 0 && sample > 0);
        Self {
            capacity,
            sample,
            keys: Vec::with_capacity(capacity),
            index: HashMap::with_capacity(capacity),
            metas: Vec::with_capacity(capacity),
            rng: Rng::new(seed),
            now: 0,
        }
    }

    /// Number of resident keys.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Priority comparison without floats: n_a/age_a < n_b/age_b.
    fn lower_priority(&self, a: Meta, b: Meta) -> bool {
        let age_a = self.now.saturating_sub(a.t0).max(1) as u128;
        let age_b = self.now.saturating_sub(b.t0).max(1) as u128;
        (a.count as u128) * age_b < (b.count as u128) * age_a
    }

    fn pick_victim_slot(&mut self) -> usize {
        let n = self.keys.len();
        debug_assert!(n > 0);
        if self.sample >= n {
            // Exact: full scan.
            let mut best = 0;
            for slot in 1..n {
                if self.lower_priority(self.metas[slot], self.metas[best]) {
                    best = slot;
                }
            }
            best
        } else {
            let mut best = self.rng.index(n);
            for _ in 1..self.sample {
                let s = self.rng.index(n);
                if self.lower_priority(self.metas[s], self.metas[best]) {
                    best = s;
                }
            }
            best
        }
    }

    fn remove_at(&mut self, slot: usize) {
        let key = self.keys.swap_remove(slot);
        self.metas.swap_remove(slot);
        self.index.remove(&key);
        if slot < self.keys.len() {
            let moved = self.keys[slot];
            self.index.insert(moved, slot);
        }
    }
}

impl SimCache for HyperbolicFull {
    fn sim_get(&mut self, key: u64) -> bool {
        self.now += 1;
        if let Some(&slot) = self.index.get(&key) {
            self.metas[slot].count += 1;
            true
        } else {
            false
        }
    }

    fn sim_put(&mut self, key: u64) {
        self.now += 1;
        if let Some(&slot) = self.index.get(&key) {
            self.metas[slot].count += 1;
            return;
        }
        if self.keys.len() >= self.capacity {
            let slot = self.pick_victim_slot();
            self.remove_at(slot);
        }
        self.index.insert(key, self.keys.len());
        self.keys.push(key);
        self.metas.push(Meta { count: 1, t0: self.now });
    }

    fn sim_name(&self) -> String {
        if self.sample >= self.capacity {
            "full-Hyperbolic(exact)".into()
        } else {
            format!("full-Hyperbolic(s{})", self.sample)
        }
    }
}

impl SimVictimPeek for HyperbolicFull {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        if self.keys.len() >= self.capacity {
            let slot = self.pick_victim_slot();
            Some(self.keys[slot])
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_mode_evicts_lowest_rate() {
        let mut c = HyperbolicFull::new(3, usize::MAX, 1);
        c.sim_put(1); // t0=1
        c.sim_put(2); // t0=2
        c.sim_put(3); // t0=3
        // Heat up 1 and 3.
        for _ in 0..20 {
            c.sim_get(1);
            c.sim_get(3);
        }
        c.sim_put(4); // victim must be 2 (count 1, oldest rate)
        assert!(!c.sim_get(2));
        assert!(c.sim_get(1) && c.sim_get(3) && c.sim_get(4));
    }

    #[test]
    fn sampled_mode_bounded() {
        let mut c = HyperbolicFull::new(100, 8, 2);
        for k in 0..10_000u64 {
            c.sim_put(k);
        }
        assert_eq!(c.len(), 100);
    }

    #[test]
    fn new_entries_get_grace() {
        // A fresh entry has age ~1 so its priority (count/age = 1) is
        // high; a long-resident single-hit entry should lose to it.
        let mut c = HyperbolicFull::new(2, usize::MAX, 3);
        c.sim_put(1);
        for _ in 0..100 {
            c.sim_get(99); // misses advance the clock
        }
        c.sim_put(2);
        c.sim_put(3); // victim should be 1 (count 1 / age ~102)
        assert!(!c.sim_get(1));
        assert!(c.sim_get(2));
    }
}
