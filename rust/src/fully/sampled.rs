//! The *sampled* competitor (Redis-style sampled LRU / LFU / Hyperbolic):
//! a segment-locked fully-associative store whose eviction draws `sample`
//! uniform resident entries and evicts the policy minimum among them.
//!
//! This reproduces the cost structure the paper measures against
//! (§5.3): every miss pays `sample` PRNG draws plus `sample` *random*
//! memory touches, where the k-way design pays one hash plus one short
//! contiguous scan. Hits only touch the accessed entry's metadata, which
//! is why sampled can win on very hit-heavy traces (the paper's Sprite
//! discussion).

use super::SimVictimPeek;
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::util::hash;
use crate::util::rng::Rng;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::collections::HashMap;
use std::sync::Mutex;

struct Seg {
    keys: Vec<u64>,
    values: Vec<u64>,
    metas: Vec<u64>,
    index: HashMap<u64, usize>,
    rng: Rng,
}

impl Seg {
    fn new(capacity_hint: usize, seed: u64) -> Self {
        Self {
            keys: Vec::with_capacity(capacity_hint),
            values: Vec::with_capacity(capacity_hint),
            metas: Vec::with_capacity(capacity_hint),
            index: HashMap::with_capacity(capacity_hint),
            rng: Rng::new(seed),
        }
    }

    fn remove_at(&mut self, slot: usize) {
        let key = self.keys.swap_remove(slot);
        self.values.swap_remove(slot);
        self.metas.swap_remove(slot);
        self.index.remove(&key);
        if slot < self.keys.len() {
            let moved = self.keys[slot];
            self.index.insert(moved, slot);
        }
    }

    /// Sample `sample` resident slots and return the policy victim's slot.
    fn sample_victim(&mut self, policy: Policy, sample: usize, now: u64) -> usize {
        let n = self.keys.len();
        debug_assert!(n > 0);
        let mut best = self.rng.index(n);
        for _ in 1..sample {
            let s = self.rng.index(n);
            if !policy.victim_le(self.metas[best], self.metas[s], now) {
                best = s;
            }
        }
        best
    }
}

/// Concurrent sampled cache (the paper's "sampled" throughput line).
pub struct Sampled {
    segments: Box<[CachePadded<Mutex<Seg>>]>,
    seg_capacity: usize,
    policy: Policy,
    sample: usize,
    clock: LogicalClock,
    capacity: usize,
}

impl Sampled {
    /// `sample` mirrors the paper's evaluation (sample size 8 in the
    /// throughput study); `segments` is rounded up to a power of two.
    pub fn new(capacity: usize, sample: usize, policy: Policy, segments: usize) -> Self {
        assert!(capacity > 0 && sample > 0 && segments > 0);
        let nsegs = segments.next_power_of_two();
        let seg_capacity = capacity.div_ceil(nsegs).max(1);
        let segments = (0..nsegs)
            .map(|i| CachePadded::new(Mutex::new(Seg::new(seg_capacity.min(1 << 20), i as u64))))
            .collect();
        Self { segments, seg_capacity, policy, sample, clock: LogicalClock::new(), capacity }
    }

    /// Default segment count used by the evaluation harness.
    pub fn with_defaults(capacity: usize, sample: usize, policy: Policy) -> Self {
        Self::new(capacity, sample, policy, 64)
    }

    #[inline]
    fn segment(&self, key: u64) -> &Mutex<Seg> {
        // Different hash seed than the k-way set hash so experiments that
        // compare both do not correlate their placements.
        let idx = (hash::xxh64_u64(key, 0x5E67) as usize) & (self.segments.len() - 1);
        &self.segments[idx]
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn sample_size(&self) -> usize {
        self.sample
    }
}

impl Cache for Sampled {
    fn get(&self, key: u64) -> Option<u64> {
        let now = self.clock.tick();
        let mut seg = self.segment(key).lock().unwrap();
        if let Some(&slot) = seg.index.get(&key) {
            seg.metas[slot] = self.policy.on_hit_meta(seg.metas[slot], now);
            Some(seg.values[slot])
        } else {
            None
        }
    }

    fn put(&self, key: u64, value: u64) {
        let now = self.clock.tick();
        let mut seg = self.segment(key).lock().unwrap();
        if let Some(&slot) = seg.index.get(&key) {
            seg.values[slot] = value;
            seg.metas[slot] = self.policy.on_hit_meta(seg.metas[slot], now);
            return;
        }
        if seg.keys.len() >= self.seg_capacity {
            let slot = seg.sample_victim(self.policy, self.sample, now);
            seg.remove_at(slot);
        }
        let slot = seg.keys.len();
        seg.keys.push(key);
        seg.values.push(value);
        seg.metas.push(self.policy.initial_meta(now));
        seg.index.insert(key, slot);
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.segments.iter().map(|s| s.lock().unwrap().keys.len()).sum()
    }

    fn name(&self) -> &'static str {
        "sampled"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let now = self.clock.now();
        let mut seg = self.segment(key).lock().unwrap();
        if seg.keys.len() >= self.seg_capacity {
            let slot = seg.sample_victim(self.policy, self.sample, now);
            Some(seg.keys[slot])
        } else {
            None
        }
    }
}

// `Sampled` implements `Cache`, so it picks up `SimCache` and
// `SimVictimPeek` via the blanket impls; nothing more needed — this line
// just documents the fact for readers grepping for the baseline set.
#[allow(dead_code)]
fn _assert_traits(s: &mut Sampled) {
    let _: Option<u64> = s.sim_peek_victim(0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = Sampled::new(128, 8, Policy::Lru, 4);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn bounded_per_segment() {
        let c = Sampled::new(256, 8, Policy::Lfu, 4);
        for k in 0..100_000u64 {
            c.put(k, k);
        }
        assert!(c.len() <= c.capacity() + 4, "len {} vs capacity {}", c.len(), c.capacity());
    }

    #[test]
    fn sampled_lru_keeps_hot_keys_mostly() {
        // With sample=capacity of a 1-segment cache, sampling is exact LRU.
        let c = Sampled::new(4, 64, Policy::Lru, 1);
        for k in 0..4u64 {
            c.put(k, k);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None, "exact-sample LRU must evict the oldest");
    }

    #[test]
    fn concurrent_smoke() {
        let c = Arc::new(Sampled::new(1024, 8, Policy::Lru, 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(300 + t);
                for _ in 0..10_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
