//! The *sampled* competitor (Redis-style sampled LRU / LFU / Hyperbolic):
//! a segment-locked fully-associative store whose eviction draws `sample`
//! uniform resident entries and evicts the policy minimum among them.
//!
//! This reproduces the cost structure the paper measures against
//! (§5.3): every miss pays `sample` PRNG draws plus `sample` *random*
//! memory touches, where the k-way design pays one hash plus one short
//! contiguous scan. Hits only touch the accessed entry's metadata, which
//! is why sampled can win on very hit-heavy traces (the paper's Sprite
//! discussion).
//!
//! Lifetime support mirrors the k-way family so expiring/weighted
//! comparisons stay apples-to-apples (DESIGN.md §Expiration, §Weighted
//! capacity): an expired entry probes as a miss (and is reclaimed in
//! place — the segment lock makes that exact, like Redis's
//! expire-on-access), eviction prefers an expired entry found in the
//! sample, and each segment bounds the *sum of entry weights* by its
//! capacity share.

use super::SimVictimPeek;
use crate::lifetime::{self, EntryOpts};
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::util::hash;
use crate::util::rng::Rng;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

struct Seg {
    keys: Vec<u64>,
    values: Vec<u64>,
    metas: Vec<u64>,
    /// Packed (weight, expiry) life words, parallel to `keys`.
    lives: Vec<u64>,
    /// Running total of resident entry weights (exact under the lock).
    weight: u64,
    index: HashMap<u64, usize>,
    rng: Rng,
}

impl Seg {
    fn new(capacity_hint: usize, seed: u64) -> Self {
        Self {
            keys: Vec::with_capacity(capacity_hint),
            values: Vec::with_capacity(capacity_hint),
            metas: Vec::with_capacity(capacity_hint),
            lives: Vec::with_capacity(capacity_hint),
            weight: 0,
            index: HashMap::with_capacity(capacity_hint),
            rng: Rng::new(seed),
        }
    }

    fn remove_at(&mut self, slot: usize) {
        let key = self.keys.swap_remove(slot);
        self.values.swap_remove(slot);
        self.metas.swap_remove(slot);
        self.weight -= lifetime::weight_of(self.lives.swap_remove(slot));
        self.index.remove(&key);
        if slot < self.keys.len() {
            let moved = self.keys[slot];
            self.index.insert(moved, slot);
        }
    }

    /// Sample `sample` resident slots and return the victim's slot: an
    /// expired entry in the sample wins outright (victim of first
    /// resort), the policy minimum otherwise. `exclude` spares a slot
    /// (the entry the current put installed).
    fn sample_victim(
        &mut self,
        policy: Policy,
        sample: usize,
        now: u64,
        now_ms: u64,
        exclude: Option<usize>,
    ) -> Option<usize> {
        let n = self.keys.len();
        debug_assert!(n > 0);
        let mut best: Option<usize> = None;
        for _ in 0..sample.max(1) {
            let s = self.rng.index(n);
            if Some(s) == exclude {
                continue;
            }
            if lifetime::is_expired(self.lives[s], now_ms) {
                return Some(s);
            }
            best = match best {
                None => Some(s),
                Some(b) if !policy.victim_le(self.metas[b], self.metas[s], now) => Some(s),
                keep => keep,
            };
        }
        // All draws hit the excluded slot: fall back to any other slot.
        if best.is_none() {
            best = (0..n).find(|&s| Some(s) != exclude);
        }
        best
    }
}

/// Concurrent sampled cache (the paper's "sampled" throughput line).
pub struct Sampled {
    segments: Box<[CachePadded<Mutex<Seg>>]>,
    /// Per-segment entry/weight budget. Atomic because online resizing
    /// re-derives it ([`Cache::resize`] — segment *re-budgeting*): the
    /// fully-associative segments have no geometry to migrate, so a
    /// resize is just a budget change plus (when shrinking) an evict-down
    /// pass under each segment lock.
    seg_capacity: AtomicUsize,
    policy: Policy,
    sample: usize,
    clock: LogicalClock,
    /// Total capacity; atomic for the same resize reason.
    capacity: AtomicUsize,
    /// Rotating segment cursor for [`Cache::sweep_expired`].
    sweep_cursor: AtomicUsize,
    /// Latched once any put carries a TTL or a non-unit weight; until
    /// then the hot paths skip the wall-clock read entirely, keeping the
    /// paper-comparison baseline's cost profile untouched (same gating
    /// as the k-way engine's activity flags).
    lifetimed: AtomicBool,
}

impl Sampled {
    /// `sample` mirrors the paper's evaluation (sample size 8 in the
    /// throughput study); `segments` is rounded up to a power of two.
    pub fn new(capacity: usize, sample: usize, policy: Policy, segments: usize) -> Self {
        assert!(capacity > 0 && sample > 0 && segments > 0);
        let nsegs = segments.next_power_of_two();
        let seg_capacity = capacity.div_ceil(nsegs).max(1);
        let segments = (0..nsegs)
            .map(|i| CachePadded::new(Mutex::new(Seg::new(seg_capacity.min(1 << 20), i as u64))))
            .collect();
        Self {
            segments,
            seg_capacity: AtomicUsize::new(seg_capacity),
            policy,
            sample,
            clock: LogicalClock::new(),
            capacity: AtomicUsize::new(capacity),
            sweep_cursor: AtomicUsize::new(0),
            lifetimed: AtomicBool::new(false),
        }
    }

    /// The per-segment entry/weight budget currently in force.
    #[inline]
    fn seg_budget(&self) -> usize {
        self.seg_capacity.load(Ordering::Relaxed)
    }

    /// Default segment count used by the evaluation harness.
    pub fn with_defaults(capacity: usize, sample: usize, policy: Policy) -> Self {
        Self::new(capacity, sample, policy, 64)
    }

    #[inline]
    fn segment(&self, key: u64) -> &Mutex<Seg> {
        // Different hash seed than the k-way set hash so experiments that
        // compare both do not correlate their placements.
        let idx = (hash::xxh64_u64(key, 0x5E67) as usize) & (self.segments.len() - 1);
        &self.segments[idx]
    }

    /// The eviction policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Entries drawn per eviction.
    pub fn sample_size(&self) -> usize {
        self.sample
    }

    /// Coarse wall-clock for expiry checks: 0 until any lifetime-carrying
    /// put latched the flag (an unlatched cache holds only immortal
    /// unit-weight entries, against which nothing ever expires).
    #[inline]
    fn lifetime_now(&self) -> u64 {
        if self.lifetimed.load(Ordering::Relaxed) {
            lifetime::now_ms()
        } else {
            0
        }
    }
}

impl Cache for Sampled {
    fn get(&self, key: u64) -> Option<u64> {
        let now = self.clock.tick();
        let now_ms = self.lifetime_now();
        let mut seg = self.segment(key).lock().unwrap();
        if let Some(&slot) = seg.index.get(&key) {
            if lifetime::is_expired(seg.lives[slot], now_ms) {
                // Expire-on-access: the lock makes reclamation exact.
                seg.remove_at(slot);
                return None;
            }
            seg.metas[slot] = self.policy.on_hit_meta(seg.metas[slot], now);
            Some(seg.values[slot])
        } else {
            None
        }
    }

    fn put(&self, key: u64, value: u64) {
        self.put_with(key, value, EntryOpts::default());
    }

    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        let seg_capacity = self.seg_budget();
        let budget = seg_capacity as u64;
        if opts.weight as u64 > budget {
            return; // heavier than a whole segment: can never fit
        }
        if !opts.is_plain() && !self.lifetimed.load(Ordering::Relaxed) {
            self.lifetimed.store(true, Ordering::Relaxed);
        }
        let now = self.clock.tick();
        let now_ms = self.lifetime_now();
        let life = lifetime::life_of(&opts, now_ms);
        let mut seg = self.segment(key).lock().unwrap();
        if let Some(&slot) = seg.index.get(&key) {
            seg.values[slot] = value;
            seg.weight -= lifetime::weight_of(seg.lives[slot]);
            seg.weight += lifetime::weight_of(life);
            seg.lives[slot] = life;
            seg.metas[slot] = self.policy.on_hit_meta(seg.metas[slot], now);
        } else {
            // Evict-then-insert on the count-full path — the pre-lifetime
            // baseline semantics, so plain (no-TTL, unit-weight) workloads
            // draw the exact same victims as before this dimension
            // existed; the repair loop below only handles weight overflow.
            if seg.keys.len() >= seg_capacity {
                let victim = seg.sample_victim(self.policy, self.sample, now, now_ms, None);
                if let Some(slot) = victim {
                    seg.remove_at(slot);
                }
            }
            let slot = seg.keys.len();
            seg.keys.push(key);
            seg.values.push(value);
            seg.metas.push(self.policy.initial_meta(now));
            seg.weight += lifetime::weight_of(life);
            seg.lives.push(life);
            seg.index.insert(key, slot);
        }
        // Weighted capacity: evict (expired lines first) until both the
        // entry count and the weight sum fit the segment's share. The
        // installed entry is spared so a legal insert never bounces
        // itself; its slot can move when remove_at swap-removes, so it
        // is re-resolved through the index every round.
        while seg.keys.len() > seg_capacity || seg.weight > budget {
            let exclude = seg.index.get(&key).copied();
            match seg.sample_victim(self.policy, self.sample, now, now_ms, exclude) {
                Some(slot) => seg.remove_at(slot),
                None => break, // only the new entry remains
            }
        }
    }

    fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed)
    }

    fn supports_resize(&self) -> bool {
        true
    }

    fn resize(&self, new_capacity: usize) -> bool {
        if new_capacity == 0 {
            return false;
        }
        // Segment re-budgeting: publish the new budgets, then (for a
        // shrink) evict each segment down to its new share by the cache's
        // own policy — the fully-associative baseline has no geometry to
        // migrate, so the whole resize completes inside this call and
        // `resize_step` never has pending work.
        let nsegs = self.segments.len();
        let seg_capacity = new_capacity.div_ceil(nsegs).max(1);
        self.capacity.store(new_capacity, Ordering::Relaxed);
        self.seg_capacity.store(seg_capacity, Ordering::Relaxed);
        let budget = seg_capacity as u64;
        let now = self.clock.now();
        let now_ms = self.lifetime_now();
        for segment in self.segments.iter() {
            let mut seg = segment.lock().unwrap();
            while seg.keys.len() > seg_capacity || seg.weight > budget {
                match seg.sample_victim(self.policy, self.sample, now, now_ms, None) {
                    Some(slot) => seg.remove_at(slot),
                    None => break,
                }
            }
        }
        true
    }

    fn len(&self) -> usize {
        self.segments.iter().map(|s| s.lock().unwrap().keys.len()).sum()
    }

    fn weight(&self) -> u64 {
        self.segments.iter().map(|s| s.lock().unwrap().weight).sum()
    }

    fn name(&self) -> &'static str {
        "sampled"
    }

    fn supports_lifetime(&self) -> bool {
        true
    }

    fn sweep_expired(&self, max_sets: usize) -> usize {
        if max_sets == 0 || !self.lifetimed.load(Ordering::Relaxed) {
            return 0;
        }
        let nsegs = self.segments.len();
        let span = max_sets.min(nsegs);
        let start = self.sweep_cursor.fetch_add(span, Ordering::Relaxed) % nsegs;
        let now_ms = lifetime::now_ms();
        let mut reclaimed = 0;
        for j in 0..span {
            let mut seg = self.segments[(start + j) % nsegs].lock().unwrap();
            let mut slot = 0;
            while slot < seg.keys.len() {
                if lifetime::is_expired(seg.lives[slot], now_ms) {
                    seg.remove_at(slot); // swap_remove: re-check this slot
                    reclaimed += 1;
                } else {
                    slot += 1;
                }
            }
        }
        reclaimed
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let now = self.clock.now();
        let now_ms = self.lifetime_now();
        let seg_capacity = self.seg_budget();
        let mut seg = self.segment(key).lock().unwrap();
        if seg.keys.len() >= seg_capacity || seg.weight >= seg_capacity as u64 {
            let slot = seg.sample_victim(self.policy, self.sample, now, now_ms, None)?;
            if lifetime::is_expired(seg.lives[slot], now_ms) {
                return None; // an expired line counts as free room
            }
            Some(seg.keys[slot])
        } else {
            None
        }
    }
}

// `Sampled` implements `Cache`, so it picks up `SimCache` and
// `SimVictimPeek` via the blanket impls; nothing more needed — this line
// just documents the fact for readers grepping for the baseline set.
#[allow(dead_code)]
fn _assert_traits(s: &mut Sampled) {
    let _: Option<u64> = s.sim_peek_victim(0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn put_get_overwrite() {
        let c = Sampled::new(128, 8, Policy::Lru, 4);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn bounded_per_segment() {
        let c = Sampled::new(256, 8, Policy::Lfu, 4);
        for k in 0..100_000u64 {
            c.put(k, k);
        }
        assert!(c.len() <= c.capacity() + 4, "len {} vs capacity {}", c.len(), c.capacity());
    }

    #[test]
    fn sampled_lru_keeps_hot_keys_mostly() {
        // With sample=capacity of a 1-segment cache, sampling is exact LRU.
        let c = Sampled::new(4, 64, Policy::Lru, 1);
        for k in 0..4u64 {
            c.put(k, k);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None, "exact-sample LRU must evict the oldest");
    }

    #[test]
    fn expired_entries_are_misses_and_reclaimed() {
        let c = Sampled::new(128, 8, Policy::Lru, 4);
        c.put_with(1, 10, EntryOpts::ttl(Duration::ZERO));
        c.put_with(2, 20, EntryOpts::ttl(Duration::from_secs(3600)));
        assert_eq!(c.len(), 2, "lazy: the dead entry still occupies a slot");
        assert_eq!(c.get(1), None);
        assert_eq!(c.len(), 1, "expire-on-access reclaims under the lock");
        assert_eq!(c.get(2), Some(20));
    }

    #[test]
    fn sweep_reclaims_expired_entries() {
        let c = Sampled::new(128, 8, Policy::Lru, 4);
        for k in 0..10u64 {
            c.put_with(k, k, EntryOpts::ttl(Duration::ZERO));
        }
        for k in 10..20u64 {
            c.put(k, k);
        }
        assert_eq!(c.sweep_expired(usize::MAX), 10);
        assert_eq!(c.len(), 10);
        assert_eq!(c.weight(), 10);
    }

    #[test]
    fn weight_budget_is_exact_per_segment() {
        // One segment, capacity 8 = weight budget 8.
        let c = Sampled::new(8, 8, Policy::Lru, 1);
        c.put_with(0, 0, EntryOpts::weight(5));
        c.put_with(1, 1, EntryOpts::weight(3));
        assert_eq!(c.weight(), 8);
        c.put_with(2, 2, EntryOpts::weight(4)); // 12 > 8: must evict
        assert!(c.weight() <= 8, "weight {} exceeds the budget", c.weight());
        assert_eq!(c.get(2), Some(2), "the inserting key is spared");
        c.put_with(9, 9, EntryOpts::weight(9));
        assert_eq!(c.get(9), None, "oversized entries are dropped");
    }

    #[test]
    fn resize_rebudgets_segments() {
        let c = Sampled::new(64, 8, Policy::Lru, 4);
        for k in 0..64u64 {
            c.put(k, k);
        }
        assert!(c.supports_resize());
        assert!(c.resize(128));
        assert_eq!(c.capacity(), 128);
        assert_eq!(c.requested_capacity(), 128);
        assert!(!c.resize_pending(), "re-budgeting completes synchronously");
        assert_eq!(c.resize_step(usize::MAX), 0);
        for k in 64..128u64 {
            c.put(k, k);
        }
        assert!(c.len() > 64, "grown budgets must admit more entries: {}", c.len());
        // Shrink evicts down to the new per-segment share immediately.
        assert!(c.resize(32));
        assert!(c.len() <= 32, "len {} exceeds the shrunk capacity", c.len());
        assert!(!c.resize(0), "a zero capacity is refused, not applied");
    }

    #[test]
    fn concurrent_smoke() {
        let c = Arc::new(Sampled::new(1024, 8, Policy::Lru, 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(300 + t);
                for _ in 0..10_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
