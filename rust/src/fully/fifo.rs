//! Exact fully-associative FIFO: a ring of keys plus a residency set.
//! Hits do not reorder anything — the defining property of FIFO.

use super::SimVictimPeek;
use crate::SimCache;
use std::collections::{HashSet, VecDeque};

/// Exact FIFO cache (single-threaded; simulator baseline).
pub struct FifoQueue {
    capacity: usize,
    queue: VecDeque<u64>,
    resident: HashSet<u64>,
}

impl FifoQueue {
    /// A FIFO cache holding at most `capacity` keys.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            queue: VecDeque::with_capacity(capacity),
            resident: HashSet::with_capacity(capacity),
        }
    }

    /// Number of resident keys.
    pub fn len(&self) -> usize {
        self.resident.len()
    }

    /// Maximum number of resident keys.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

impl SimCache for FifoQueue {
    fn sim_get(&mut self, key: u64) -> bool {
        self.resident.contains(&key)
    }

    fn sim_put(&mut self, key: u64) {
        if self.resident.contains(&key) {
            return; // FIFO position unchanged on re-put
        }
        if self.resident.len() >= self.capacity {
            if let Some(victim) = self.queue.pop_front() {
                self.resident.remove(&victim);
            }
        }
        self.queue.push_back(key);
        self.resident.insert(key);
    }

    fn sim_name(&self) -> String {
        "full-FIFO".into()
    }
}

impl SimVictimPeek for FifoQueue {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        if self.resident.len() >= self.capacity {
            self.queue.front().copied()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_in_insertion_order() {
        let mut c = FifoQueue::new(3);
        c.sim_put(1);
        c.sim_put(2);
        c.sim_put(3);
        // Hits must not save key 1.
        for _ in 0..10 {
            assert!(c.sim_get(1));
        }
        c.sim_put(4);
        assert!(!c.sim_get(1));
        assert!(c.sim_get(2));
    }

    #[test]
    fn re_put_keeps_position() {
        let mut c = FifoQueue::new(2);
        c.sim_put(1);
        c.sim_put(2);
        c.sim_put(1); // no-op
        c.sim_put(3); // evicts 1 (still oldest)
        assert!(!c.sim_get(1));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn peek_is_front() {
        let mut c = FifoQueue::new(2);
        c.sim_put(10);
        assert_eq!(c.sim_peek_victim(0), None);
        c.sim_put(20);
        assert_eq!(c.sim_peek_victim(0), Some(10));
    }
}
