//! Fully-associative and sampled baselines.
//!
//! These are the comparison lines of the paper's hit-ratio study
//! (Figures 4–13) and the "sampled" competitor of the throughput study:
//!
//! * [`LruList`] — the classic linked-list LRU ("the `fully associative`
//!   line stands for a linked-list based fully associative
//!   implementation", §5.1). Exact.
//! * [`LfuOrdered`] — exact LFU with LRU tie-breaking (ordered-set based;
//!   O(log n) per op, which only matters for the simulator, not the hot
//!   path).
//! * [`FifoQueue`], [`RandomFull`] — exact FIFO / uniform-random eviction.
//! * [`HyperbolicFull`] — Hyperbolic caching as the Hyperbolic paper
//!   itself implements it: priorities are evaluated on a uniform sample at
//!   eviction time (`sample = 64` by default; exact mode available for
//!   small caches by setting `sample >= capacity`).
//! * [`Sampled`] — the Redis-style *concurrent* sampled cache used in the
//!   throughput figures: segment-locked storage, eviction by sampling
//!   `sample` random resident entries and evicting the policy minimum.
//!   This reproduces the cost the paper highlights: one PRNG draw plus one
//!   random memory access per sampled entry on every miss.
//!
//! For expiring/weighted scenarios, [`Sampled`] carries full lifetime
//! support (TTL + weighted capacity, like the k-way family) and
//! [`LruList`] expires lazily through a side deadline map, so the
//! headline baselines stay apples-to-apples with the k-way designs
//! (DESIGN.md §Expiration). The remaining sequential baselines treat
//! every entry as immortal (the [`crate::SimCache`] default).

mod fifo;
mod hyperbolic;
mod lfu;
mod lru;
mod random;
mod sampled;

pub use fifo::FifoQueue;
pub use hyperbolic::HyperbolicFull;
pub use lfu::LfuOrdered;
pub use lru::LruList;
pub use random::RandomFull;
pub use sampled::Sampled;

/// Victim preview for admission policies (TinyLFU): which key would be
/// evicted if `key` were inserted now and the cache were full? `None`
/// means "no eviction needed" (free room) — the caller should admit.
pub trait SimVictimPeek {
    /// The key that would be evicted if `key` were inserted now, or
    /// `None` when no eviction would be needed.
    fn sim_peek_victim(&mut self, key: u64) -> Option<u64>;
}

/// Every concurrent [`crate::Cache`] supplies a victim preview through its
/// `peek_victim` method, so it composes with the TinyLFU admission wrapper
/// the same way the sequential baselines do.
impl<C: crate::Cache> SimVictimPeek for C {
    fn sim_peek_victim(&mut self, key: u64) -> Option<u64> {
        self.peek_victim(key)
    }
}
