//! Exact fully-associative Random eviction: resident keys in a vector for
//! O(1) uniform victim selection, plus a key→slot index for O(1) lookup.

use super::SimVictimPeek;
use crate::util::rng::Rng;
use crate::SimCache;
use std::collections::HashMap;

/// Uniform-random eviction cache (single-threaded; simulator baseline).
pub struct RandomFull {
    capacity: usize,
    keys: Vec<u64>,
    index: HashMap<u64, usize>,
    rng: Rng,
}

impl RandomFull {
    /// A random-eviction cache holding at most `capacity` keys.
    pub fn new(capacity: usize, seed: u64) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            keys: Vec::with_capacity(capacity),
            index: HashMap::with_capacity(capacity),
            rng: Rng::new(seed),
        }
    }

    /// Number of resident keys.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    fn remove_at(&mut self, slot: usize) {
        let key = self.keys.swap_remove(slot);
        self.index.remove(&key);
        if slot < self.keys.len() {
            let moved = self.keys[slot];
            self.index.insert(moved, slot);
        }
    }
}

impl SimCache for RandomFull {
    fn sim_get(&mut self, key: u64) -> bool {
        self.index.contains_key(&key)
    }

    fn sim_put(&mut self, key: u64) {
        if self.index.contains_key(&key) {
            return;
        }
        if self.keys.len() >= self.capacity {
            let slot = self.rng.index(self.keys.len());
            self.remove_at(slot);
        }
        self.index.insert(key, self.keys.len());
        self.keys.push(key);
    }

    fn sim_name(&self) -> String {
        "full-Random".into()
    }
}

impl SimVictimPeek for RandomFull {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        // Random eviction has no stable preview; report the key that WOULD
        // be evicted by pre-drawing is not reproducible, so preview the
        // first resident key when full (admission treats all equally).
        if self.keys.len() >= self.capacity {
            self.keys.first().copied()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_and_consistent() {
        let mut c = RandomFull::new(50, 7);
        for k in 0..10_000u64 {
            c.sim_put(k);
            assert!(c.sim_get(k), "just-inserted key must be resident");
        }
        assert_eq!(c.len(), 50);
        // Index must agree with the vector.
        for (slot, &k) in c.keys.iter().enumerate() {
            assert_eq!(c.index[&k], slot);
        }
    }

    #[test]
    fn eviction_is_spread_out() {
        // Insert 0..100 into a cache of 50, then check survivors are not
        // simply the last 50 (that would be FIFO, not random).
        let mut c = RandomFull::new(50, 42);
        for k in 0..100u64 {
            c.sim_put(k);
        }
        let early_survivors = (0..50u64).filter(|&k| c.sim_get(k)).count();
        assert!(early_survivors > 0, "random eviction should spare some early keys");
        assert!(early_survivors < 50);
    }
}
