//! Exact fully-associative LFU ("Perfect LFU" in the paper's terminology)
//! with LRU tie-breaking, built on an ordered set of
//! `(frequency, last-touch, key)` triples. O(log n) per operation — only
//! the simulator pays this, never the serving hot path.

use super::SimVictimPeek;
use crate::SimCache;
use std::collections::{BTreeSet, HashMap};

/// Exact LFU cache (single-threaded; simulator baseline).
pub struct LfuOrdered {
    capacity: usize,
    /// key -> (freq, seq) so the ordered entry can be located for removal.
    map: HashMap<u64, (u64, u64)>,
    /// (freq, seq, key), ordered; the minimum is the eviction victim.
    order: BTreeSet<(u64, u64, u64)>,
    seq: u64,
}

impl LfuOrdered {
    /// An exact LFU cache holding at most `capacity` keys.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            map: HashMap::with_capacity(capacity),
            order: BTreeSet::new(),
            seq: 0,
        }
    }

    /// Number of resident keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Maximum number of resident keys.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn bump(&mut self, key: u64) {
        let &(freq, seq) = self.map.get(&key).unwrap();
        self.order.remove(&(freq, seq, key));
        self.seq += 1;
        self.map.insert(key, (freq + 1, self.seq));
        self.order.insert((freq + 1, self.seq, key));
    }

    fn insert_new(&mut self, key: u64) {
        if self.map.len() >= self.capacity {
            let &(freq, seq, victim) = self.order.iter().next().unwrap();
            self.order.remove(&(freq, seq, victim));
            self.map.remove(&victim);
        }
        self.seq += 1;
        self.map.insert(key, (1, self.seq));
        self.order.insert((1, self.seq, key));
    }
}

impl SimCache for LfuOrdered {
    fn sim_get(&mut self, key: u64) -> bool {
        if self.map.contains_key(&key) {
            self.bump(key);
            true
        } else {
            false
        }
    }

    fn sim_put(&mut self, key: u64) {
        if self.map.contains_key(&key) {
            self.bump(key);
        } else {
            self.insert_new(key);
        }
    }

    fn sim_name(&self) -> String {
        "full-LFU".into()
    }
}

impl SimVictimPeek for LfuOrdered {
    fn sim_peek_victim(&mut self, _key: u64) -> Option<u64> {
        if self.map.len() >= self.capacity {
            self.order.iter().next().map(|&(_, _, k)| k)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut c = LfuOrdered::new(3);
        c.sim_put(1);
        c.sim_put(2);
        c.sim_put(3);
        c.sim_get(1);
        c.sim_get(1);
        c.sim_get(2);
        c.sim_put(4); // victim: 3 (freq 1)
        assert!(!c.sim_get(3));
        assert!(c.sim_get(1) && c.sim_get(2) && c.sim_get(4));
    }

    #[test]
    fn tie_breaks_towards_older() {
        let mut c = LfuOrdered::new(2);
        c.sim_put(1);
        c.sim_put(2); // both freq 1; 1 is older
        c.sim_put(3); // evicts 1
        assert!(!c.sim_get(1));
        assert!(c.sim_get(2));
    }

    #[test]
    fn peek_matches_eviction() {
        let mut c = LfuOrdered::new(3);
        for k in 0..3 {
            c.sim_put(k);
        }
        c.sim_get(0);
        c.sim_get(2);
        let victim = c.sim_peek_victim(99).unwrap();
        assert_eq!(victim, 1);
        c.sim_put(99);
        assert!(!c.sim_get(1));
    }

    #[test]
    fn len_bounded() {
        let mut c = LfuOrdered::new(10);
        for k in 0..1000u64 {
            c.sim_put(k);
        }
        assert_eq!(c.len(), 10);
    }
}
