//! Multi-threaded throughput harness, reproducing the paper's §5.1.2
//! methodology:
//!
//! 1. warm-up — the main thread inserts `capacity` elements that are not
//!    in the trace, then each worker inserts `capacity / threads` more;
//! 2. all workers start simultaneously on a barrier;
//! 3. each worker performs *read; on miss, write* over its own offset of
//!    the (cyclic) trace for a fixed wall-clock duration;
//! 4. the result is total Mops/s, averaged over repeated runs
//!    (the paper uses 11 runs; the repeat count is configurable because
//!    the full figure set on one core would otherwise take hours).
//!
//! Synthetic workloads (Figures 27–30) are expressed as [`Workload`]
//! variants: 100% miss (unique keys), 100% hit (resident working set), and
//! fixed hit-ratio mixes (1 put per N gets). The batching extension adds
//! [`Workload::Batched`]: resident-set gets issued through
//! [`Cache::get_batch`] in fixed-size batches, the workload the `batch`
//! sweep and `benches/batched.rs` measure.
//!
//! Besides Mops/s, every run samples operation latency (one op in
//! `SAMPLE_EVERY` per worker, so sampling does not perturb what it
//! measures) into a [`LatencyHistogram`]; [`RunResult`] reports the p50
//! and p99 next to the throughput summary. For batched workloads the
//! sample is the latency of one whole batch — the latency a batched
//! caller actually observes.

use crate::lifetime::{EntryOpts, WeightDist};
use crate::metrics::LatencyHistogram;
use crate::tinylfu::AdmissionMode;
use crate::trace::Trace;
use crate::util::stats::Summary;
use crate::Cache;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// How every fill (the put on a miss, and the resident-set install) is
/// performed: which TTL the entry carries and which per-key weight
/// distribution sizes it. The default (`ttl: None`, unit weights) routes
/// through the plain [`Cache::put`] path, so TTL-free measurements are
/// bit-identical to the pre-lifetime harness. Built from the CLI's
/// `--ttl` / `--weight-dist` options.
#[derive(Debug, Clone, Default)]
pub struct FillSpec {
    /// TTL stamped on every filled entry; `None` = immortal.
    pub ttl: Option<Duration>,
    /// Deterministic per-key weight distribution.
    pub weight_dist: WeightDist,
}

impl FillSpec {
    /// True when fills are indistinguishable from plain puts.
    pub fn is_plain(&self) -> bool {
        self.ttl.is_none() && self.weight_dist == WeightDist::Unit
    }

    /// The [`EntryOpts`] a fill of `key` carries.
    pub fn opts_for(&self, key: u64) -> EntryOpts {
        EntryOpts { ttl: self.ttl, weight: self.weight_dist.weight_of(key) }
    }

    /// Perform one fill through the cheapest matching path.
    #[inline]
    pub fn fill(&self, cache: &dyn Cache, key: u64, value: u64) {
        if self.is_plain() {
            cache.put(key, value);
        } else {
            cache.put_with(key, value, self.opts_for(key));
        }
    }

    /// Human-readable summary for table headers.
    pub fn label(&self) -> String {
        match self.ttl {
            None if self.weight_dist == WeightDist::Unit => "immortal".into(),
            None => format!("immortal/{}", self.weight_dist.name()),
            Some(ttl) => format!("ttl={ttl:?}/{}", self.weight_dist.name()),
        }
    }
}

/// What the workers execute.
#[derive(Clone)]
pub enum Workload {
    /// Replay a trace cyclically: get; on miss, put (Figures 14–26).
    TraceReplay(Arc<Trace>),
    /// Every access is a unique key: get (miss) then put (Figure 27).
    AllMiss,
    /// Only gets over a resident working set (Figure 28).
    AllHit {
        /// Resident keys drawn uniformly.
        working_set: u64,
    },
    /// `gets_per_put` gets over a resident set, then one put of a fresh
    /// key (Figures 29–30: 19:1 ≈ 95%, 9:1 ≈ 90%).
    HitRatio {
        /// Resident keys drawn uniformly.
        working_set: u64,
        /// Gets issued between consecutive fresh-key puts.
        gets_per_put: u32,
    },
    /// Gets over a resident set issued through the batched path,
    /// `batch` keys per `get_batch` call (the batching extension; same
    /// key distribution as [`Workload::AllHit`] so the two are directly
    /// comparable).
    Batched {
        /// Resident keys drawn uniformly.
        working_set: u64,
        /// Keys per `get_batch` call.
        batch: usize,
    },
    /// Get-or-fill over a uniform working set where every fill carries
    /// the run's [`FillSpec`] (the expiration/weighted-capacity
    /// extension): with a TTL the resident set continuously decays and
    /// is refilled, so the steady-state hit ratio measures how cheaply
    /// an implementation reclaims dead lines; with a weight distribution
    /// the set budget admits fewer-but-heavier entries
    /// (`benches/expiry.rs`, `kway synthetic --workload expiring`).
    Expiring {
        /// Keys drawn uniformly; misses are refilled with the run's
        /// fill options.
        working_set: u64,
    },
}

impl Workload {
    /// Short label used in tables and bench output.
    pub fn label(&self) -> String {
        match self {
            Workload::TraceReplay(t) => format!("trace:{}", t.name),
            Workload::AllMiss => "100%-miss".into(),
            Workload::AllHit { .. } => "100%-hit".into(),
            Workload::HitRatio { gets_per_put, .. } => {
                format!("{}%-hit", 100 * *gets_per_put / (*gets_per_put + 1))
            }
            Workload::Batched { batch, .. } => format!("batched-x{batch}"),
            Workload::Expiring { .. } => "expiring".into(),
        }
    }
}

/// Harness configuration.
#[derive(Clone)]
pub struct RunConfig {
    /// Worker thread count.
    pub threads: usize,
    /// Wall-clock measurement window per repeat.
    pub duration: Duration,
    /// Independent repeats (fresh cache each).
    pub repeats: usize,
    /// Base RNG seed (perturbed per repeat and per thread).
    pub seed: u64,
    /// TTL/weight options applied to every fill (see [`FillSpec`]).
    pub fill: FillSpec,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            threads: 4,
            duration: Duration::from_millis(500),
            repeats: 5,
            seed: 1,
            fill: FillSpec::default(),
        }
    }
}

/// Result of one measurement: throughput summary in Mops/s, the hit ratio
/// aggregated over *all* repeats (total hits / total gets, so every repeat
/// counts — not just the last one), and latency percentiles from the
/// sampled per-op histogram (nanoseconds; per *batch* for
/// [`Workload::Batched`]).
pub struct RunResult {
    /// Throughput summary (Mops/s over the repeats).
    pub mops: Summary,
    /// Total hits / total gets across all repeats.
    pub hit_ratio: f64,
    /// Sampled per-op latency: 50th percentile, nanoseconds.
    pub lat_p50_ns: u64,
    /// Sampled per-op latency: 99th percentile, nanoseconds.
    pub lat_p99_ns: u64,
    /// Sampled per-op latency: mean, nanoseconds.
    pub lat_mean_ns: f64,
}

/// Keys guaranteed not to collide with trace keys or resident sets
/// (high bit space).
const WARM_BASE: u64 = 1 << 48;
/// Fresh-miss key space for the synthetic workloads.
const FRESH_BASE: u64 = 1 << 49;

/// One op in this many is individually timed into the latency histogram.
const SAMPLE_EVERY: u32 = 64;

/// Measure a cache implementation under a workload. `factory` builds a
/// fresh cache per repeat (so runs are independent, like the paper's).
pub fn measure(
    factory: &dyn Fn() -> Arc<dyn Cache>,
    workload: &Workload,
    cfg: &RunConfig,
) -> RunResult {
    let mut mops = Summary::new();
    let latency = Arc::new(LatencyHistogram::new());
    let mut total_hits = 0u64;
    let mut total_gets = 0u64;
    for rep in 0..cfg.repeats {
        let cache = factory();
        // A TTL/weight fill against a cache without lifetime support is
        // a silent no-op (entries stay immortal) — say so once, or the
        // cross-impl comparison rows would look valid when they are not.
        if rep == 0 && !cfg.fill.is_plain() && !cache.supports_lifetime() {
            eprintln!(
                "warning: {} has no lifetime support; --ttl/--weight-dist fills are immortal",
                cache.name()
            );
        }
        let (ops, hits, gets, secs) = one_run(cache, workload, cfg, rep as u64, &latency);
        mops.add(ops as f64 / secs / 1e6);
        total_hits += hits;
        total_gets += gets;
    }
    RunResult {
        mops,
        hit_ratio: if total_gets > 0 { total_hits as f64 / total_gets as f64 } else { 0.0 },
        lat_p50_ns: latency.percentile(50.0),
        lat_p99_ns: latency.percentile(99.0),
        lat_mean_ns: latency.mean(),
    }
}

fn one_run(
    cache: Arc<dyn Cache>,
    workload: &Workload,
    cfg: &RunConfig,
    rep: u64,
    latency: &Arc<LatencyHistogram>,
) -> (u64, u64, u64, f64) {
    let capacity = cache.capacity();
    // Warm-up phase 1: main thread fills with non-trace keys.
    for i in 0..capacity as u64 {
        cache.put(WARM_BASE + i, i);
    }

    let stop = Arc::new(AtomicBool::new(false));
    // Two rendezvous: after per-thread warm-up (so the main thread can
    // install the resident working set *last*, un-evicted), and at the
    // simultaneous start (§5.1.2).
    let warm_done = Arc::new(Barrier::new(cfg.threads + 1));
    let barrier = Arc::new(Barrier::new(cfg.threads + 1));
    let total_ops = Arc::new(AtomicU64::new(0));
    let total_hits = Arc::new(AtomicU64::new(0));
    let total_gets = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::new();
    for t in 0..cfg.threads {
        let cache = cache.clone();
        let stop = stop.clone();
        let warm_done = warm_done.clone();
        let barrier = barrier.clone();
        let total_ops = total_ops.clone();
        let total_hits = total_hits.clone();
        let total_gets = total_gets.clone();
        let latency = latency.clone();
        let workload = workload.clone();
        let threads = cfg.threads;
        let seed = cfg.seed ^ (rep << 32) ^ t as u64;
        let fill = cfg.fill.clone();
        handles.push(std::thread::spawn(move || {
            // Warm-up phase 2: per-thread non-trace inserts.
            let per = (cache.capacity() / threads).max(1) as u64;
            for i in 0..per {
                cache.put(WARM_BASE + (1 + t as u64) * (1 << 32) + i, i);
            }
            warm_done.wait();
            barrier.wait();
            let (ops, hits, gets) =
                worker(&*cache, &workload, &fill, &stop, t, threads, seed, &latency);
            total_ops.fetch_add(ops, Ordering::Relaxed);
            total_hits.fetch_add(hits, Ordering::Relaxed);
            total_gets.fetch_add(gets, Ordering::Relaxed);
        }));
    }

    warm_done.wait();
    // For hit-mode workloads the resident set must be installed after all
    // warm-up traffic so it is actually resident when the clock starts.
    // Installed with the same get-then-fill pattern the workers measure:
    // for plain caches this is identical to a bare put, and for
    // admission-filtered caches it seeds the frequency a bare put of a
    // never-seen key would lack (exactly what TinyLFU is built to reject).
    match workload {
        Workload::AllHit { working_set }
        | Workload::HitRatio { working_set, .. }
        | Workload::Batched { working_set, .. }
        | Workload::Expiring { working_set } => {
            for k in 0..*working_set {
                if cache.get(k).is_none() {
                    cfg.fill.fill(&*cache, k, k);
                }
            }
        }
        _ => {}
    }

    barrier.wait();
    let start = std::time::Instant::now();
    std::thread::sleep(cfg.duration);
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    (
        total_ops.load(Ordering::Relaxed),
        total_hits.load(Ordering::Relaxed),
        total_gets.load(Ordering::Relaxed),
        secs,
    )
}

/// Times one op in [`SAMPLE_EVERY`] into the shared histogram; the other
/// ops run untimed so the measurement does not perturb the hot loop.
struct Sampler<'a> {
    hist: &'a LatencyHistogram,
    countdown: u32,
}

impl<'a> Sampler<'a> {
    fn new(hist: &'a LatencyHistogram) -> Self {
        Self { hist, countdown: 1 } // sample the first op, then 1-in-N
    }

    #[inline]
    fn run<T>(&mut self, op: impl FnOnce() -> T) -> T {
        self.countdown -= 1;
        if self.countdown == 0 {
            self.countdown = SAMPLE_EVERY;
            let start = Instant::now();
            let out = op();
            self.hist.record(start.elapsed().as_nanos() as u64);
            out
        } else {
            op()
        }
    }
}

/// The worker loop; returns (ops, hits, gets). An "op" is a get or a put,
/// matching the paper's Get/Put operations-per-second metric (every key of
/// a batched get counts as one op). Every fill goes through `fill`, which
/// routes to the plain put path unless the run carries TTLs or weights.
#[allow(clippy::too_many_arguments)]
fn worker(
    cache: &dyn Cache,
    workload: &Workload,
    fill: &FillSpec,
    stop: &AtomicBool,
    thread_id: usize,
    threads: usize,
    seed: u64,
    latency: &LatencyHistogram,
) -> (u64, u64, u64) {
    const CHECK_EVERY: u64 = 256;
    let mut ops = 0u64;
    let mut hits = 0u64;
    let mut gets = 0u64;
    let mut sampler = Sampler::new(latency);
    match workload {
        Workload::TraceReplay(trace) => {
            let n = trace.len();
            let mut pos = (n / threads) * thread_id;
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = trace.keys[pos];
                    pos += 1;
                    if pos == n {
                        pos = 0;
                    }
                    gets += 1;
                    // One access = get, plus the fill on a miss.
                    let hit = sampler.run(|| {
                        if cache.get(key).is_some() {
                            true
                        } else {
                            fill.fill(cache, key, key);
                            false
                        }
                    });
                    if hit {
                        hits += 1;
                        ops += 1;
                    } else {
                        ops += 2;
                    }
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::AllMiss => {
            // Disjoint fresh keys per thread: every get misses.
            let mut next = FRESH_BASE + (thread_id as u64) * (1 << 40);
            loop {
                for _ in 0..CHECK_EVERY {
                    gets += 1;
                    let key = next;
                    let hit = sampler.run(|| {
                        let hit = cache.get(key).is_some();
                        fill.fill(cache, key, key);
                        hit
                    });
                    if hit {
                        hits += 1;
                    }
                    ops += 2;
                    next += 1;
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::AllHit { working_set } => {
            let mut rng = crate::util::rng::Rng::new(seed);
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = rng.below(*working_set);
                    gets += 1;
                    if sampler.run(|| cache.get(key)).is_some() {
                        hits += 1;
                    }
                    ops += 1;
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::HitRatio { working_set, gets_per_put } => {
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut next = FRESH_BASE + (thread_id as u64) * (1 << 40);
            let mut since_put = 0u32;
            loop {
                for _ in 0..CHECK_EVERY {
                    if since_put >= *gets_per_put {
                        since_put = 0;
                        let key = next;
                        sampler.run(|| fill.fill(cache, key, key));
                        next += 1;
                        ops += 1;
                    } else {
                        since_put += 1;
                        let key = rng.below(*working_set);
                        gets += 1;
                        if sampler.run(|| cache.get(key)).is_some() {
                            hits += 1;
                        }
                        ops += 1;
                    }
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::Batched { working_set, batch } => {
            let batch = (*batch).max(1);
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut keys = vec![0u64; batch];
            let mut out: Vec<Option<u64>> = Vec::with_capacity(batch);
            // Keep the stop-poll cadence comparable to the scalar arms.
            let batches_per_check = (CHECK_EVERY / batch as u64).max(1);
            loop {
                for _ in 0..batches_per_check {
                    for slot in keys.iter_mut() {
                        *slot = rng.below(*working_set);
                    }
                    out.clear();
                    // The latency sample is one whole batch: what a
                    // batched caller observes per call.
                    sampler.run(|| cache.get_batch(&keys, &mut out));
                    gets += batch as u64;
                    ops += batch as u64;
                    hits += out.iter().filter(|v| v.is_some()).count() as u64;
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::Expiring { working_set } => {
            // Get-or-fill over a uniform working set: with a TTL in the
            // fill spec the resident set decays continuously, so the
            // steady-state hit ratio is governed by TTL vs. re-reference
            // interval; with weights the sets hold fewer, heavier
            // entries. Same op accounting as trace replay.
            let mut rng = crate::util::rng::Rng::new(seed);
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = rng.below(*working_set);
                    gets += 1;
                    let hit = sampler.run(|| {
                        if cache.get(key).is_some() {
                            true
                        } else {
                            fill.fill(cache, key, key);
                            false
                        }
                    });
                    if hit {
                        hits += 1;
                        ops += 1;
                    } else {
                        ops += 2;
                    }
                }
                if stop.load(Ordering::Acquire) {
                    return (ops, hits, gets);
                }
            }
        }
    }
}

/// The implementation lineup of the throughput figures (Figures 14–30):
/// the three K-Way variants (k = 8), sampled (sample = 8), Guava,
/// Caffeine, and segmented Caffeine. `threads` sizes the per-thread
/// segmentation where the paper does (segmented Caffeine, Guava's
/// concurrency level).
pub const IMPLS: [&str; 7] =
    ["KW-WFA", "KW-WFSC", "KW-LS", "sampled", "Guava", "Caffeine", "seg-Caffeine"];

/// A cache constructor handed to [`measure`]: one fresh cache per repeat.
pub type CacheFactory = Box<dyn Fn() -> Arc<dyn Cache> + Sync>;

/// Build a cache factory by implementation name, optionally layered
/// behind an admission filter ([`AdmissionMode::TinyLfu`] wraps every
/// built cache in a [`crate::tinylfu::TlfuCache`]).
pub fn impl_factory(
    name: &str,
    capacity: usize,
    threads: usize,
    policy: crate::policy::Policy,
    admission: AdmissionMode,
) -> Option<CacheFactory> {
    use crate::fully::Sampled;
    use crate::kway::{KwLs, KwWfa, KwWfsc};
    use crate::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
    let ways = 8;
    let sample = 8;
    let f: CacheFactory = match name {
        "KW-WFA" | "wfa" => Box::new(move || Arc::new(KwWfa::new(capacity, ways, policy))),
        "KW-WFSC" | "wfsc" => Box::new(move || Arc::new(KwWfsc::new(capacity, ways, policy))),
        "KW-LS" | "ls" => Box::new(move || Arc::new(KwLs::new(capacity, ways, policy))),
        "sampled" => {
            Box::new(move || Arc::new(Sampled::with_defaults(capacity, sample, policy)))
        }
        "Guava" | "guava" => Box::new(move || Arc::new(GuavaLike::new(capacity, 4))),
        "Caffeine" | "caffeine" => Box::new(move || Arc::new(CaffeineLike::new(capacity))),
        "seg-Caffeine" | "segcaffeine" => {
            let segs = threads.max(2);
            Box::new(move || Arc::new(SegmentedCaffeine::new(capacity, segs)))
        }
        _ => return None,
    };
    Some(match admission {
        AdmissionMode::None => f,
        AdmissionMode::TinyLfu => Box::new(move || AdmissionMode::TinyLfu.wrap(f())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kway::{KwWfsc, Variant};
    use crate::policy::Policy;

    fn quick_cfg(threads: usize) -> RunConfig {
        RunConfig {
            threads,
            duration: Duration::from_millis(50),
            repeats: 2,
            seed: 9,
            ..Default::default()
        }
    }

    fn kw_factory(capacity: usize) -> impl Fn() -> Arc<dyn Cache> {
        move || Arc::new(KwWfsc::new(capacity, 8, Policy::Lru)) as Arc<dyn Cache>
    }

    #[test]
    fn all_miss_yields_zero_hits() {
        let r = measure(&kw_factory(1024), &Workload::AllMiss, &quick_cfg(2));
        assert_eq!(r.hit_ratio, 0.0);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn all_hit_yields_high_hits() {
        // Working set of 256 inside a 4096-entry cache: every set has
        // room, so after the pre-fill everything hits.
        let r = measure(
            &kw_factory(4096),
            &Workload::AllHit { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
    }

    #[test]
    fn trace_replay_runs() {
        let trace = Arc::new(crate::trace::paper::build("sprite", 50_000, 2).unwrap());
        let r = measure(&kw_factory(2048), &Workload::TraceReplay(trace), &quick_cfg(2));
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.0, "sprite should have hits");
        assert_eq!(r.mops.count(), 2);
    }

    #[test]
    fn hit_ratio_mix_is_close_to_target() {
        let r = measure(
            &kw_factory(4096),
            &Workload::HitRatio { working_set: 256, gets_per_put: 19 },
            &quick_cfg(2),
        );
        // Gets hit nearly always; the put fraction lowers overall ratio.
        assert!(r.hit_ratio > 0.9, "hit ratio {}", r.hit_ratio);
        assert_eq!(Workload::HitRatio { working_set: 1, gets_per_put: 19 }.label(), "95%-hit");
        assert_eq!(Workload::HitRatio { working_set: 1, gets_per_put: 9 }.label(), "90%-hit");
    }

    #[test]
    fn batched_workload_hits_resident_set() {
        let r = measure(
            &kw_factory(4096),
            &Workload::Batched { working_set: 256, batch: 32 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
        assert_eq!(Workload::Batched { working_set: 1, batch: 32 }.label(), "batched-x32");
    }

    #[test]
    fn latency_percentiles_are_populated_and_ordered() {
        let r = measure(
            &kw_factory(4096),
            &Workload::AllHit { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.lat_p50_ns > 0, "p50 {}", r.lat_p50_ns);
        assert!(r.lat_p99_ns >= r.lat_p50_ns, "p99 {} < p50 {}", r.lat_p99_ns, r.lat_p50_ns);
        assert!(r.lat_mean_ns > 0.0);
    }

    #[test]
    fn hit_ratio_is_aggregated_over_repeats_not_last() {
        use std::sync::atomic::AtomicUsize;
        // A stateful factory gives repeat 0 a cache that holds ~25% of the
        // working set (ratio ≈ 0.25) and repeat 1 one that holds all of it
        // (ratio ≈ 1.0). Only an aggregate over both repeats lands in the
        // middle; the old bug — reporting the last repeat only — would be
        // ≈ 1.0, and "first repeat only" would be ≈ 0.25.
        let calls = AtomicUsize::new(0);
        let factory = move || -> Arc<dyn Cache> {
            let capacity =
                if calls.fetch_add(1, Ordering::Relaxed) == 0 { 1024 } else { 16_384 };
            Arc::new(KwWfsc::new(capacity, 8, Policy::Lru))
        };
        let cfg = RunConfig {
            threads: 2,
            duration: Duration::from_millis(40),
            repeats: 2,
            seed: 5,
            ..Default::default()
        };
        let r = measure(&factory, &Workload::AllHit { working_set: 4096 }, &cfg);
        assert!(
            r.hit_ratio > 0.30 && r.hit_ratio < 0.95,
            "aggregate ratio {} should mix both repeats, not report the last",
            r.hit_ratio
        );
    }

    #[test]
    fn tlfu_factory_wraps_and_measures() {
        let factory =
            impl_factory("KW-WFSC", 4096, 2, Policy::Lru, AdmissionMode::TinyLfu).unwrap();
        assert_eq!(factory().name(), "KW-WFSC+TLFU");
        // The resident working set must survive the warm-up through
        // admission (the install loop seeds frequency via get-then-fill).
        let r = measure(&*factory, &Workload::AllHit { working_set: 256 }, &quick_cfg(2));
        assert!(r.hit_ratio > 0.9, "hit ratio through admission {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn every_impl_builds_with_both_admission_modes() {
        for name in IMPLS {
            for admission in AdmissionMode::ALL {
                let factory = impl_factory(name, 1024, 2, Policy::Lru, admission)
                    .unwrap_or_else(|| panic!("no factory for {name}"));
                let cache = factory();
                cache.put(3, 33);
                assert_eq!(cache.get(3), Some(33), "{name}{}", admission.label());
            }
        }
    }

    #[test]
    fn workload_labels() {
        assert_eq!(Workload::AllMiss.label(), "100%-miss");
        assert_eq!(Workload::AllHit { working_set: 1 }.label(), "100%-hit");
        assert_eq!(Workload::Expiring { working_set: 1 }.label(), "expiring");
    }

    #[test]
    fn fill_spec_labels_and_plain_detection() {
        use crate::lifetime::WeightDist;
        let plain = FillSpec::default();
        assert!(plain.is_plain());
        assert_eq!(plain.label(), "immortal");
        assert_eq!(plain.opts_for(7), crate::lifetime::EntryOpts::default());
        let ttl = FillSpec { ttl: Some(Duration::from_millis(100)), ..Default::default() };
        assert!(!ttl.is_plain());
        let weighted = FillSpec { weight_dist: WeightDist::Zipf { max: 8 }, ..Default::default() };
        assert!(!weighted.is_plain());
        assert_eq!(weighted.label(), "immortal/zipf:8");
        assert!(weighted.opts_for(7).weight >= 1);
    }

    #[test]
    fn expiring_workload_without_ttl_behaves_like_all_hit() {
        // No TTL in the fill spec: the pre-installed working set never
        // decays, so the expiring loop is a pure hit loop.
        let r = measure(
            &kw_factory(4096),
            &Workload::Expiring { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn expiring_workload_with_short_ttl_misses_and_refills() {
        // A 1 ms TTL over a 50 ms window: entries die between touches,
        // so a healthy fraction of gets miss and refill. The run must
        // stay well-formed (ops flowing, ratio strictly between 0 and 1).
        let cfg = RunConfig {
            fill: FillSpec { ttl: Some(Duration::from_millis(1)), ..Default::default() },
            ..quick_cfg(2)
        };
        let r = measure(&kw_factory(4096), &Workload::Expiring { working_set: 4096 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio < 1.0, "a 1ms TTL must produce some expiries");
    }

    #[test]
    fn weighted_fills_run_end_to_end() {
        use crate::lifetime::WeightDist;
        let cfg = RunConfig {
            fill: FillSpec { weight_dist: WeightDist::Zipf { max: 8 }, ..Default::default() },
            ..quick_cfg(2)
        };
        let r = measure(&kw_factory(4096), &Workload::Expiring { working_set: 512 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.0, "weighted resident set should still hit");
    }

    #[test]
    fn variant_name_unused_guard() {
        // Keep Variant imported for the bench code that shares this module.
        let _ = Variant::ALL;
    }
}
