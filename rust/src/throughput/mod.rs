//! Multi-threaded throughput harness, reproducing the paper's §5.1.2
//! methodology:
//!
//! 1. warm-up — the main thread inserts `capacity` elements that are not
//!    in the trace, then each worker inserts `capacity / threads` more;
//! 2. all workers start simultaneously on a barrier;
//! 3. each worker performs *read; on miss, write* over its own offset of
//!    the (cyclic) trace for a fixed wall-clock duration;
//! 4. the result is total Mops/s, averaged over repeated runs
//!    (the paper uses 11 runs; the repeat count is configurable because
//!    the full figure set on one core would otherwise take hours).
//!
//! Synthetic workloads (Figures 27–30) are expressed as [`Workload`]
//! variants: 100% miss (unique keys), 100% hit (resident working set), and
//! fixed hit-ratio mixes (1 put per N gets). The batching extension adds
//! [`Workload::Batched`]: resident-set gets issued through
//! [`Cache::get_batch`] in fixed-size batches, the workload the `batch`
//! sweep and `benches/batched.rs` measure.
//!
//! Besides Mops/s, every run samples operation latency into a
//! per-thread [`Reservoir`] (~10K samples each): individual ops are
//! timed at randomized intervals (mean one in `SAMPLE_MEAN_GAP`, so the
//! cadence cannot alias against periodic contention and sampling does
//! not perturb what it measures), and the reservoir keeps a uniform
//! subset no matter how long the run is. [`RunResult`] reports
//! nearest-rank p50/p99 over the merged samples next to the throughput
//! summary. For batched workloads the sample is the latency of one whole
//! batch — the latency a batched caller actually observes.

use crate::lifetime::{EntryOpts, ValueDist, WeightDist};
use crate::tinylfu::AdmissionMode;
use crate::trace::Trace;
use crate::util::rng::Rng;
use crate::util::stats::{percentile_u64, Reservoir, Summary};
use crate::Cache;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

/// How every fill (the put on a miss, and the resident-set install) is
/// performed: which TTL the entry carries, which per-key weight
/// distribution sizes it, and whether the value is a word or a slab
/// byte blob. The default (`ttl: None`, unit weights, word values)
/// routes through the plain [`Cache::put`] path, so TTL-free
/// measurements are bit-identical to the pre-lifetime harness. Built
/// from the CLI's `--ttl` / `--weight-dist` / `--value-dist` options.
#[derive(Debug, Clone, Default)]
pub struct FillSpec {
    /// TTL stamped on every filled entry; `None` = immortal.
    pub ttl: Option<Duration>,
    /// Deterministic per-key weight distribution.
    pub weight_dist: WeightDist,
    /// Deterministic per-key value payloads: [`ValueDist::Word`] keeps
    /// the classic u64 fills; byte distributions route every fill
    /// through [`Cache::put_bytes_with`] (entry weight then becomes the
    /// slab bytes actually held, overriding `weight_dist`).
    pub value_dist: ValueDist,
}

impl FillSpec {
    /// True when fills are indistinguishable from plain puts.
    pub fn is_plain(&self) -> bool {
        self.ttl.is_none()
            && self.weight_dist == WeightDist::Unit
            && self.value_dist == ValueDist::Word
    }

    /// The [`EntryOpts`] a fill of `key` carries.
    pub fn opts_for(&self, key: u64) -> EntryOpts {
        EntryOpts { ttl: self.ttl, weight: self.weight_dist.weight_of(key) }
    }

    /// Perform one fill through the cheapest matching path. Byte
    /// distributions reuse a thread-local scratch buffer, so the hot
    /// loop allocates only when a key's payload outgrows it.
    #[inline]
    pub fn fill(&self, cache: &dyn Cache, key: u64, value: u64) {
        if self.value_dist.is_bytes() {
            BYTE_SCRATCH.with(|scratch| {
                let buf = &mut *scratch.borrow_mut();
                self.value_dist.fill(key, buf);
                cache.put_bytes_with(key, buf, self.opts_for(key));
            });
        } else if self.is_plain() {
            cache.put(key, value);
        } else {
            cache.put_with(key, value, self.opts_for(key));
        }
    }

    /// Human-readable summary for table headers.
    pub fn label(&self) -> String {
        let base = match self.ttl {
            None if self.weight_dist == WeightDist::Unit => "immortal".to_string(),
            None => format!("immortal/{}", self.weight_dist.name()),
            Some(ttl) => format!("ttl={ttl:?}/{}", self.weight_dist.name()),
        };
        if self.value_dist.is_bytes() {
            format!("{base}/values={}", self.value_dist.name())
        } else {
            base
        }
    }
}

std::thread_local! {
    /// Per-thread payload scratch for byte-distribution fills.
    static BYTE_SCRATCH: std::cell::RefCell<Vec<u8>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// What the workers execute.
#[derive(Clone)]
pub enum Workload {
    /// Replay a trace cyclically: get; on miss, put (Figures 14–26).
    TraceReplay(Arc<Trace>),
    /// Every access is a unique key: get (miss) then put (Figure 27).
    AllMiss,
    /// Only gets over a resident working set (Figure 28).
    AllHit {
        /// Resident keys drawn uniformly.
        working_set: u64,
    },
    /// `gets_per_put` gets over a resident set, then one put of a fresh
    /// key (Figures 29–30: 19:1 ≈ 95%, 9:1 ≈ 90%).
    HitRatio {
        /// Resident keys drawn uniformly.
        working_set: u64,
        /// Gets issued between consecutive fresh-key puts.
        gets_per_put: u32,
    },
    /// Gets over a resident set issued through the batched path,
    /// `batch` keys per `get_batch` call (the batching extension; same
    /// key distribution as [`Workload::AllHit`] so the two are directly
    /// comparable).
    Batched {
        /// Resident keys drawn uniformly.
        working_set: u64,
        /// Keys per `get_batch` call.
        batch: usize,
    },
    /// Get-or-fill over a uniform working set where every fill carries
    /// the run's [`FillSpec`] (the expiration/weighted-capacity
    /// extension): with a TTL the resident set continuously decays and
    /// is refilled, so the steady-state hit ratio measures how cheaply
    /// an implementation reclaims dead lines; with a weight distribution
    /// the set budget admits fewer-but-heavier entries
    /// (`benches/expiry.rs`, `kway synthetic --workload expiring`).
    Expiring {
        /// Keys drawn uniformly; misses are refilled with the run's
        /// fill options.
        working_set: u64,
    },
}

impl Workload {
    /// Short label used in tables and bench output.
    pub fn label(&self) -> String {
        match self {
            Workload::TraceReplay(t) => format!("trace:{}", t.name),
            Workload::AllMiss => "100%-miss".into(),
            Workload::AllHit { .. } => "100%-hit".into(),
            Workload::HitRatio { gets_per_put, .. } => {
                format!("{}%-hit", 100 * *gets_per_put / (*gets_per_put + 1))
            }
            Workload::Batched { batch, .. } => format!("batched-x{batch}"),
            Workload::Expiring { .. } => "expiring".into(),
        }
    }
}

/// A mid-run online resize trigger (the CLI's `--resize-at N
/// --resize-to C`): once the workers have issued `at_ops` operations,
/// the harness calls [`Cache::resize`]`(to_capacity)` and then acts as
/// the background migration driver (pumping [`Cache::resize_step`])
/// until the split watermark covers every source set — all while the
/// workers keep hammering the cache. Caches without resize support get
/// one warning and run unresized.
#[derive(Debug, Clone, Copy)]
pub struct ResizeSpec {
    /// Total worker operations after which the resize fires.
    pub at_ops: u64,
    /// Capacity the resize targets.
    pub to_capacity: usize,
}

/// Harness configuration.
#[derive(Clone)]
pub struct RunConfig {
    /// Worker thread count.
    pub threads: usize,
    /// Wall-clock measurement window per repeat.
    pub duration: Duration,
    /// Independent repeats (fresh cache each).
    pub repeats: usize,
    /// Base RNG seed (perturbed per repeat and per thread).
    pub seed: u64,
    /// TTL/weight options applied to every fill (see [`FillSpec`]).
    pub fill: FillSpec,
    /// Optional mid-run online resize (see [`ResizeSpec`]).
    pub resize: Option<ResizeSpec>,
    /// Pin worker `t` to core `t mod num_cores` before the warm-up, so
    /// scheduler migrations never land inside the measured window (the
    /// CLI's `--pin`; see [`crate::util::affinity`]).
    pub pin: bool,
    /// Install `MPOL_INTERLEAVE` before building each repeat's cache so
    /// its table pages spread round-robin across NUMA nodes (the CLI's
    /// `--numa-interleave`). Harmless on single-node machines.
    pub numa_interleave: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            threads: 4,
            duration: Duration::from_millis(500),
            repeats: 5,
            seed: 1,
            fill: FillSpec::default(),
            resize: None,
            pin: false,
            numa_interleave: false,
        }
    }
}

/// Result of one measurement: throughput summary in Mops/s, the hit ratio
/// aggregated over *all* repeats (total hits / total gets, so every repeat
/// counts — not just the last one), and nearest-rank latency percentiles
/// over the merged per-thread reservoirs (nanoseconds; per *batch* for
/// [`Workload::Batched`]).
pub struct RunResult {
    /// Throughput summary (Mops/s over the repeats).
    pub mops: Summary,
    /// Total hits / total gets across all repeats.
    pub hit_ratio: f64,
    /// Sampled per-op latency: 50th percentile, nanoseconds.
    pub lat_p50_ns: u64,
    /// Sampled per-op latency: 99th percentile, nanoseconds.
    pub lat_p99_ns: u64,
    /// Sampled per-op latency: mean, nanoseconds.
    pub lat_mean_ns: f64,
    /// CPU cycles per operation: the sum of each worker's TSC delta over
    /// its measured loop (warm-up excluded) divided by total ops, across
    /// all repeats. 0 where [`crate::util::clock::cycles_supported`] is
    /// false. Unlike ns/op this is invariant under frequency scaling of
    /// the measurement clock, so it isolates the probe path's work.
    pub cycles_per_op: f64,
}

/// Keys guaranteed not to collide with trace keys or resident sets
/// (high bit space).
const WARM_BASE: u64 = 1 << 48;
/// Fresh-miss key space for the synthetic workloads.
const FRESH_BASE: u64 = 1 << 49;

/// Mean gap between individually timed ops per worker. Actual gaps are
/// drawn uniformly from `[1, 2*mean - 1]`, so the sampling cadence has
/// no fixed period to alias against; one timed op in ~64 keeps the
/// `Instant::now` overhead invisible next to the accesses themselves.
const SAMPLE_MEAN_GAP: u64 = 64;

/// Per-thread latency reservoir capacity: ~10K samples per worker keep
/// p50/p99 stable while bounding memory regardless of run length.
const RESERVOIR_CAP: usize = 10_000;

/// Measure a cache implementation under a workload. `factory` builds a
/// fresh cache per repeat (so runs are independent, like the paper's).
pub fn measure(
    factory: &dyn Fn() -> Arc<dyn Cache>,
    workload: &Workload,
    cfg: &RunConfig,
) -> RunResult {
    let mut mops = Summary::new();
    let latency: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut total_hits = 0u64;
    let mut total_gets = 0u64;
    let mut total_ops_all = 0u64;
    let mut total_cycles = 0u64;
    if cfg.numa_interleave {
        // Install the interleave policy before the factory allocates the
        // tables, so their pages spread as they are first touched.
        crate::util::affinity::interleave_allocations();
    }
    for rep in 0..cfg.repeats {
        let cache = factory();
        // A TTL/weight fill against a cache without lifetime support is
        // a silent no-op (entries stay immortal) — say so once, or the
        // cross-impl comparison rows would look valid when they are not.
        if rep == 0 && !cfg.fill.is_plain() && !cache.supports_lifetime() {
            eprintln!(
                "warning: {} has no lifetime support; --ttl/--weight-dist fills are immortal",
                cache.name()
            );
        }
        // Byte-distribution fills against a word-only cache are rejected
        // puts (`put_bytes_with` returns false): every access would miss.
        if rep == 0 && cfg.fill.value_dist.is_bytes() && !cache.supports_values() {
            eprintln!(
                "warning: {} has no byte-value store; --value-dist fills are dropped \
                 (build the cache with a value budget)",
                cache.name()
            );
        }
        if rep == 0 && cfg.resize.is_some() && !cache.supports_resize() {
            eprintln!(
                "warning: {} has no resize support; --resize-at/--resize-to are ignored",
                cache.name()
            );
        }
        let (ops, hits, gets, cycles, secs) = one_run(cache, workload, cfg, rep as u64, &latency);
        mops.add(ops as f64 / secs / 1e6);
        total_hits += hits;
        total_gets += gets;
        total_ops_all += ops;
        total_cycles += cycles;
    }
    let mut samples = std::mem::take(&mut *latency.lock().unwrap());
    let lat_mean_ns = if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<u64>() as f64 / samples.len() as f64
    };
    RunResult {
        mops,
        hit_ratio: if total_gets > 0 { total_hits as f64 / total_gets as f64 } else { 0.0 },
        lat_p50_ns: percentile_u64(&mut samples, 50.0),
        lat_p99_ns: percentile_u64(&mut samples, 99.0),
        lat_mean_ns,
        cycles_per_op: if total_ops_all > 0 {
            total_cycles as f64 / total_ops_all as f64
        } else {
            0.0
        },
    }
}

/// Throughput and hit ratio over one wall-clock phase of the resize
/// measurement ([`measure_resize`]).
#[derive(Debug, Clone, Copy)]
pub struct PhaseStats {
    /// Mops/s over the phase.
    pub mops: f64,
    /// hits / gets over the phase.
    pub hit_ratio: f64,
}

/// Result of a [`measure_resize`] run: the same workload measured before
/// the resize fires, *during* the migration, and after it completes,
/// plus the steady-state hit ratio of a *twin* cache built directly at
/// the target capacity — the yardstick the after-phase must recover to
/// (the figR acceptance criterion).
#[derive(Debug, Clone)]
pub struct ResizeRunResult {
    /// Steady state at the initial capacity.
    pub before: PhaseStats,
    /// While the migration driver is pumping `resize_step`.
    pub during: PhaseStats,
    /// Steady state after the migration completed.
    pub after: PhaseStats,
    /// Wall-clock milliseconds from `resize()` to watermark completion.
    pub migrate_ms: f64,
    /// Steady-state hit ratio of the twin built at the target capacity.
    pub twin_hit: f64,
}

/// Drive `threads` get-or-fill workers (uniform keys below
/// `working_set`) against `cache` for `duration`; returns the phase's
/// throughput and hit ratio. The fill value of key `k` is always
/// `k.wrapping_mul(31)`, so phases compose (an entry installed in one
/// phase hits in the next).
pub fn drive_phase(
    cache: &Arc<dyn Cache>,
    working_set: u64,
    threads: usize,
    duration: Duration,
    seed: u64,
) -> PhaseStats {
    let stop = AtomicBool::new(false);
    let ops = AtomicU64::new(0);
    let hits = AtomicU64::new(0);
    let gets = AtomicU64::new(0);
    let start = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads {
            let cache = cache.clone();
            let stop = &stop;
            let ops = &ops;
            let hits = &hits;
            let gets = &gets;
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(seed ^ (0xA11CE << 8) ^ t as u64);
                let mut local = (0u64, 0u64, 0u64);
                loop {
                    for _ in 0..256 {
                        let key = rng.below(working_set);
                        local.2 += 1;
                        if cache.get(key).is_some() {
                            local.1 += 1;
                            local.0 += 1;
                        } else {
                            cache.put(key, key.wrapping_mul(31));
                            local.0 += 2;
                        }
                    }
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                }
                ops.fetch_add(local.0, Ordering::Relaxed);
                hits.fetch_add(local.1, Ordering::Relaxed);
                gets.fetch_add(local.2, Ordering::Relaxed);
            });
        }
        std::thread::sleep(duration);
        stop.store(true, Ordering::Release);
    });
    let secs = start.elapsed().as_secs_f64();
    let g = gets.load(Ordering::Relaxed);
    PhaseStats {
        mops: ops.load(Ordering::Relaxed) as f64 / secs / 1e6,
        hit_ratio: if g > 0 { hits.load(Ordering::Relaxed) as f64 / g as f64 } else { 0.0 },
    }
}

/// Measure an online resize end to end (the `kway resize` sweep and
/// `benches/resize.rs`): warm a cache built by `factory` to steady state
/// on a uniform get-or-fill working set, measure the **before** phase,
/// fire `resize(to_capacity)` with a concurrent background driver while
/// measuring the **during** phase, let the working set re-reach steady
/// state, measure the **after** phase, and finally measure a *twin*
/// cache built by `twin_factory` directly at the target capacity. A grow
/// recovers when `after.hit_ratio` reaches the twin's; the during-phase
/// Mops/s dip quantifies what the migration costs the serving path.
pub fn measure_resize(
    factory: &dyn Fn() -> Arc<dyn Cache>,
    twin_factory: &dyn Fn() -> Arc<dyn Cache>,
    to_capacity: usize,
    working_set: u64,
    threads: usize,
    phase_duration: Duration,
    seed: u64,
) -> ResizeRunResult {
    let warm = |cache: &Arc<dyn Cache>| {
        for k in 0..working_set {
            if cache.get(k).is_none() {
                cache.put(k, k.wrapping_mul(31));
            }
        }
        drive_phase(cache, working_set, threads, phase_duration, seed ^ 0x77);
    };

    let cache = factory();
    warm(&cache);
    let before = drive_phase(&cache, working_set, threads, phase_duration, seed);

    let t0 = Instant::now();
    let accepted = cache.resize(to_capacity);
    let driver = {
        let cache = cache.clone();
        std::thread::spawn(move || {
            while cache.resize_pending() {
                if cache.resize_step(64) == 0 {
                    std::thread::yield_now();
                }
            }
            t0.elapsed().as_secs_f64() * 1e3
        })
    };
    let during = drive_phase(&cache, working_set, threads, phase_duration, seed ^ 1);
    let migrate_ms = driver.join().expect("resize driver panicked");
    if !accepted {
        eprintln!("warning: {} refused the resize; phases ran unresized", cache.name());
    }

    // Let the (possibly grown) cache refill to steady state, then
    // measure the recovery phase.
    warm(&cache);
    let after = drive_phase(&cache, working_set, threads, phase_duration, seed ^ 2);

    let twin = twin_factory();
    warm(&twin);
    let twin_hit = drive_phase(&twin, working_set, threads, phase_duration, seed ^ 3).hit_ratio;

    ResizeRunResult { before, during, after, migrate_ms, twin_hit }
}

fn one_run(
    cache: Arc<dyn Cache>,
    workload: &Workload,
    cfg: &RunConfig,
    rep: u64,
    latency: &Arc<Mutex<Vec<u64>>>,
) -> (u64, u64, u64, u64, f64) {
    let capacity = cache.capacity();
    // Warm-up phase 1: main thread fills with non-trace keys.
    for i in 0..capacity as u64 {
        cache.put(WARM_BASE + i, i);
    }

    let stop = Arc::new(AtomicBool::new(false));
    // Two rendezvous: after per-thread warm-up (so the main thread can
    // install the resident working set *last*, un-evicted), and at the
    // simultaneous start (§5.1.2).
    let warm_done = Arc::new(Barrier::new(cfg.threads + 1));
    let barrier = Arc::new(Barrier::new(cfg.threads + 1));
    let total_ops = Arc::new(AtomicU64::new(0));
    let total_hits = Arc::new(AtomicU64::new(0));
    let total_gets = Arc::new(AtomicU64::new(0));
    let total_cycles = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::new();
    for t in 0..cfg.threads {
        let cache = cache.clone();
        let stop = stop.clone();
        let warm_done = warm_done.clone();
        let barrier = barrier.clone();
        let total_ops = total_ops.clone();
        let total_hits = total_hits.clone();
        let total_gets = total_gets.clone();
        let total_cycles = total_cycles.clone();
        let latency = latency.clone();
        let workload = workload.clone();
        let threads = cfg.threads;
        let seed = cfg.seed ^ (rep << 32) ^ t as u64;
        let fill = cfg.fill.clone();
        let pin = cfg.pin;
        handles.push(std::thread::spawn(move || {
            // Pin before the warm-up so even the warm traffic runs where
            // the measurement will (first-touch page placement included).
            if pin {
                crate::util::affinity::pin_to_core(t);
            }
            // Warm-up phase 2: per-thread non-trace inserts.
            let per = (cache.capacity() / threads).max(1) as u64;
            for i in 0..per {
                cache.put(WARM_BASE + (1 + t as u64) * (1 << 32) + i, i);
            }
            warm_done.wait();
            barrier.wait();
            // The TSC window brackets exactly the measured loop — after
            // the start barrier, before the counter publication — so
            // warm-up cycles never pollute cycles-per-op. Per-thread
            // deltas are summed, never differenced across threads.
            let tsc0 = crate::util::clock::cycles_now();
            // `worker` publishes its op count progressively through the
            // pacer (into `total_ops`), so only hits/gets remain to add.
            let (_ops, hits, gets) =
                worker(&*cache, &workload, &fill, &stop, &total_ops, t, threads, seed, &latency);
            let tsc1 = crate::util::clock::cycles_now();
            total_cycles.fetch_add(tsc1.wrapping_sub(tsc0), Ordering::Relaxed);
            total_hits.fetch_add(hits, Ordering::Relaxed);
            total_gets.fetch_add(gets, Ordering::Relaxed);
        }));
    }

    warm_done.wait();
    // For hit-mode workloads the resident set must be installed after all
    // warm-up traffic so it is actually resident when the clock starts.
    // Installed with the same get-then-fill pattern the workers measure:
    // for plain caches this is identical to a bare put, and for
    // admission-filtered caches it seeds the frequency a bare put of a
    // never-seen key would lack (exactly what TinyLFU is built to reject).
    match workload {
        Workload::AllHit { working_set }
        | Workload::HitRatio { working_set, .. }
        | Workload::Batched { working_set, .. }
        | Workload::Expiring { working_set } => {
            for k in 0..*working_set {
                if cache.get(k).is_none() {
                    cfg.fill.fill(&*cache, k, k);
                }
            }
        }
        _ => {}
    }

    barrier.wait();
    let start = std::time::Instant::now();
    match cfg.resize {
        Some(spec) if cache.supports_resize() => {
            // Poll cheaply until the op-count trigger (or the window
            // ends), fire the resize, then serve as the background
            // migration driver while the workers keep running.
            let deadline = start + cfg.duration;
            while Instant::now() < deadline && total_ops.load(Ordering::Relaxed) < spec.at_ops {
                std::thread::sleep(Duration::from_micros(200));
            }
            if Instant::now() < deadline {
                cache.resize(spec.to_capacity);
                while cache.resize_pending() && Instant::now() < deadline {
                    if cache.resize_step(64) == 0 {
                        std::thread::yield_now();
                    }
                }
            }
            let now = Instant::now();
            if now < deadline {
                std::thread::sleep(deadline - now);
            }
        }
        _ => std::thread::sleep(cfg.duration),
    }
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    (
        total_ops.load(Ordering::Relaxed),
        total_hits.load(Ordering::Relaxed),
        total_gets.load(Ordering::Relaxed),
        total_cycles.load(Ordering::Relaxed),
        secs,
    )
}

/// Paces a worker's outer loop: at every stop-flag poll (once per
/// `CHECK_EVERY` accesses) it also publishes the ops performed since the
/// last poll into the shared progress counter, so the main thread can
/// watch the run advance — the `--resize-at N` trigger fires off exactly
/// this counter. One relaxed `fetch_add` per 256 accesses per thread:
/// noise next to the accesses themselves, and identical across
/// implementations.
struct Pacer<'a> {
    stop: &'a AtomicBool,
    progress: &'a AtomicU64,
    published: u64,
}

impl Pacer<'_> {
    #[inline]
    fn should_stop(&mut self, ops: u64) -> bool {
        self.progress.fetch_add(ops - self.published, Ordering::Relaxed);
        self.published = ops;
        self.stop.load(Ordering::Acquire)
    }
}

/// Times the occasional op into a per-thread [`Reservoir`]; the other
/// ops run untimed so the measurement does not perturb the hot loop.
/// The gap to the next timed op is drawn uniformly from
/// `[1, 2*SAMPLE_MEAN_GAP - 1]` — same mean rate as the old fixed
/// stride, but with no period for the workload to alias against — and
/// the reservoir keeps a uniform subset of the timed ops, so the
/// retained sample is unbiased however long the run lasts.
struct Sampler<'a> {
    res: &'a mut Reservoir,
    gap_rng: Rng,
    countdown: u64,
}

impl<'a> Sampler<'a> {
    fn new(res: &'a mut Reservoir, gap_seed: u64) -> Self {
        // Sample the first op, then at randomized gaps.
        Self { res, gap_rng: Rng::new(gap_seed), countdown: 1 }
    }

    #[inline]
    fn run<T>(&mut self, op: impl FnOnce() -> T) -> T {
        self.countdown -= 1;
        if self.countdown == 0 {
            self.countdown = self.gap_rng.range_u64(1, 2 * SAMPLE_MEAN_GAP - 1);
            let start = Instant::now();
            let out = op();
            self.res.record(start.elapsed().as_nanos() as u64);
            out
        } else {
            op()
        }
    }
}

/// The worker loop; returns (ops, hits, gets). An "op" is a get or a put,
/// matching the paper's Get/Put operations-per-second metric (every key of
/// a batched get counts as one op). Every fill goes through `fill`, which
/// routes to the plain put path unless the run carries TTLs or weights;
/// `progress` receives the running op count once per check interval (the
/// final figure is exact — the last poll before returning publishes the
/// remainder).
#[allow(clippy::too_many_arguments)]
fn worker(
    cache: &dyn Cache,
    workload: &Workload,
    fill: &FillSpec,
    stop: &AtomicBool,
    progress: &AtomicU64,
    thread_id: usize,
    threads: usize,
    seed: u64,
    latency: &Mutex<Vec<u64>>,
) -> (u64, u64, u64) {
    // Per-thread reservoir + sampler, merged into the shared sink once at
    // the end — zero cross-thread traffic on the measured path.
    let mut reservoir = Reservoir::new(RESERVOIR_CAP, seed ^ 0x5EED_0F_5A3B);
    let mut sampler = Sampler::new(&mut reservoir, seed ^ 0x6A9);
    let result =
        worker_loop(cache, workload, fill, stop, progress, thread_id, threads, seed, &mut sampler);
    latency.lock().unwrap().extend_from_slice(reservoir.samples());
    result
}

/// The measured loop proper; split from [`worker`] so every workload
/// arm's early return still funnels through the one reservoir merge.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cache: &dyn Cache,
    workload: &Workload,
    fill: &FillSpec,
    stop: &AtomicBool,
    progress: &AtomicU64,
    thread_id: usize,
    threads: usize,
    seed: u64,
    sampler: &mut Sampler<'_>,
) -> (u64, u64, u64) {
    const CHECK_EVERY: u64 = 256;
    let mut ops = 0u64;
    let mut hits = 0u64;
    let mut gets = 0u64;
    let mut pacer = Pacer { stop, progress, published: 0 };
    match workload {
        Workload::TraceReplay(trace) => {
            let n = trace.len();
            let mut pos = (n / threads) * thread_id;
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = trace.keys[pos];
                    pos += 1;
                    if pos == n {
                        pos = 0;
                    }
                    gets += 1;
                    // One access = get, plus the fill on a miss.
                    let hit = sampler.run(|| {
                        if cache.get(key).is_some() {
                            true
                        } else {
                            fill.fill(cache, key, key);
                            false
                        }
                    });
                    if hit {
                        hits += 1;
                        ops += 1;
                    } else {
                        ops += 2;
                    }
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::AllMiss => {
            // Disjoint fresh keys per thread: every get misses.
            let mut next = FRESH_BASE + (thread_id as u64) * (1 << 40);
            loop {
                for _ in 0..CHECK_EVERY {
                    gets += 1;
                    let key = next;
                    let hit = sampler.run(|| {
                        let hit = cache.get(key).is_some();
                        fill.fill(cache, key, key);
                        hit
                    });
                    if hit {
                        hits += 1;
                    }
                    ops += 2;
                    next += 1;
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::AllHit { working_set } => {
            let mut rng = crate::util::rng::Rng::new(seed);
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = rng.below(*working_set);
                    gets += 1;
                    if sampler.run(|| cache.get(key)).is_some() {
                        hits += 1;
                    }
                    ops += 1;
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::HitRatio { working_set, gets_per_put } => {
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut next = FRESH_BASE + (thread_id as u64) * (1 << 40);
            let mut since_put = 0u32;
            loop {
                for _ in 0..CHECK_EVERY {
                    if since_put >= *gets_per_put {
                        since_put = 0;
                        let key = next;
                        sampler.run(|| fill.fill(cache, key, key));
                        next += 1;
                        ops += 1;
                    } else {
                        since_put += 1;
                        let key = rng.below(*working_set);
                        gets += 1;
                        if sampler.run(|| cache.get(key)).is_some() {
                            hits += 1;
                        }
                        ops += 1;
                    }
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::Batched { working_set, batch } => {
            let batch = (*batch).max(1);
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut keys = vec![0u64; batch];
            let mut out: Vec<Option<u64>> = Vec::with_capacity(batch);
            // Keep the stop-poll cadence comparable to the scalar arms.
            let batches_per_check = (CHECK_EVERY / batch as u64).max(1);
            loop {
                for _ in 0..batches_per_check {
                    for slot in keys.iter_mut() {
                        *slot = rng.below(*working_set);
                    }
                    out.clear();
                    // The latency sample is one whole batch: what a
                    // batched caller observes per call.
                    sampler.run(|| cache.get_batch(&keys, &mut out));
                    gets += batch as u64;
                    ops += batch as u64;
                    hits += out.iter().filter(|v| v.is_some()).count() as u64;
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
        Workload::Expiring { working_set } => {
            // Get-or-fill over a uniform working set: with a TTL in the
            // fill spec the resident set decays continuously, so the
            // steady-state hit ratio is governed by TTL vs. re-reference
            // interval; with weights the sets hold fewer, heavier
            // entries. Same op accounting as trace replay.
            let mut rng = crate::util::rng::Rng::new(seed);
            loop {
                for _ in 0..CHECK_EVERY {
                    let key = rng.below(*working_set);
                    gets += 1;
                    let hit = sampler.run(|| {
                        if cache.get(key).is_some() {
                            true
                        } else {
                            fill.fill(cache, key, key);
                            false
                        }
                    });
                    if hit {
                        hits += 1;
                        ops += 1;
                    } else {
                        ops += 2;
                    }
                }
                if pacer.should_stop(ops) {
                    return (ops, hits, gets);
                }
            }
        }
    }
}

/// The implementation lineup of the throughput figures (Figures 14–30):
/// the three K-Way variants (k = 8), sampled (sample = 8), Guava,
/// Caffeine, and segmented Caffeine. `threads` sizes the per-thread
/// segmentation where the paper does (segmented Caffeine, Guava's
/// concurrency level).
pub const IMPLS: [&str; 7] =
    ["KW-WFA", "KW-WFSC", "KW-LS", "sampled", "Guava", "Caffeine", "seg-Caffeine"];

/// A cache constructor handed to [`measure`]: one fresh cache per repeat.
pub type CacheFactory = Box<dyn Fn() -> Arc<dyn Cache> + Sync>;

/// Build a cache factory by implementation name, optionally layered
/// behind an admission filter ([`AdmissionMode::TinyLfu`] wraps every
/// built cache in a [`crate::tinylfu::TlfuCache`]).
pub fn impl_factory(
    name: &str,
    capacity: usize,
    threads: usize,
    policy: crate::policy::Policy,
    admission: AdmissionMode,
) -> Option<CacheFactory> {
    use crate::fully::Sampled;
    use crate::kway::{KwLs, KwWfa, KwWfsc};
    use crate::products::{CaffeineLike, GuavaLike, SegmentedCaffeine};
    let ways = 8;
    let sample = 8;
    let f: CacheFactory = match name {
        "KW-WFA" | "wfa" => Box::new(move || Arc::new(KwWfa::new(capacity, ways, policy))),
        "KW-WFSC" | "wfsc" => Box::new(move || Arc::new(KwWfsc::new(capacity, ways, policy))),
        "KW-LS" | "ls" => Box::new(move || Arc::new(KwLs::new(capacity, ways, policy))),
        "sampled" => {
            Box::new(move || Arc::new(Sampled::with_defaults(capacity, sample, policy)))
        }
        "Guava" | "guava" => Box::new(move || Arc::new(GuavaLike::new(capacity, 4))),
        "Caffeine" | "caffeine" => Box::new(move || Arc::new(CaffeineLike::new(capacity))),
        "seg-Caffeine" | "segcaffeine" => {
            let segs = threads.max(2);
            Box::new(move || Arc::new(SegmentedCaffeine::new(capacity, segs)))
        }
        _ => return None,
    };
    Some(match admission {
        AdmissionMode::None => f,
        AdmissionMode::TinyLfu => Box::new(move || AdmissionMode::TinyLfu.wrap(f())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kway::{KwWfsc, Variant};
    use crate::policy::Policy;

    fn quick_cfg(threads: usize) -> RunConfig {
        RunConfig {
            threads,
            duration: Duration::from_millis(50),
            repeats: 2,
            seed: 9,
            ..Default::default()
        }
    }

    fn kw_factory(capacity: usize) -> impl Fn() -> Arc<dyn Cache> {
        move || Arc::new(KwWfsc::new(capacity, 8, Policy::Lru)) as Arc<dyn Cache>
    }

    #[test]
    fn all_miss_yields_zero_hits() {
        let r = measure(&kw_factory(1024), &Workload::AllMiss, &quick_cfg(2));
        assert_eq!(r.hit_ratio, 0.0);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn all_hit_yields_high_hits() {
        // Working set of 256 inside a 4096-entry cache: every set has
        // room, so after the pre-fill everything hits.
        let r = measure(
            &kw_factory(4096),
            &Workload::AllHit { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
    }

    #[test]
    fn trace_replay_runs() {
        let trace = Arc::new(crate::trace::paper::build("sprite", 50_000, 2).unwrap());
        let r = measure(&kw_factory(2048), &Workload::TraceReplay(trace), &quick_cfg(2));
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.0, "sprite should have hits");
        assert_eq!(r.mops.count(), 2);
    }

    #[test]
    fn hit_ratio_mix_is_close_to_target() {
        let r = measure(
            &kw_factory(4096),
            &Workload::HitRatio { working_set: 256, gets_per_put: 19 },
            &quick_cfg(2),
        );
        // Gets hit nearly always; the put fraction lowers overall ratio.
        assert!(r.hit_ratio > 0.9, "hit ratio {}", r.hit_ratio);
        assert_eq!(Workload::HitRatio { working_set: 1, gets_per_put: 19 }.label(), "95%-hit");
        assert_eq!(Workload::HitRatio { working_set: 1, gets_per_put: 9 }.label(), "90%-hit");
    }

    #[test]
    fn batched_workload_hits_resident_set() {
        let r = measure(
            &kw_factory(4096),
            &Workload::Batched { working_set: 256, batch: 32 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
        assert_eq!(Workload::Batched { working_set: 1, batch: 32 }.label(), "batched-x32");
    }

    #[test]
    fn latency_percentiles_are_populated_and_ordered() {
        let r = measure(
            &kw_factory(4096),
            &Workload::AllHit { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.lat_p50_ns > 0, "p50 {}", r.lat_p50_ns);
        assert!(r.lat_p99_ns >= r.lat_p50_ns, "p99 {} < p50 {}", r.lat_p99_ns, r.lat_p50_ns);
        assert!(r.lat_mean_ns > 0.0);
    }

    #[test]
    fn hit_ratio_is_aggregated_over_repeats_not_last() {
        use std::sync::atomic::AtomicUsize;
        // A stateful factory gives repeat 0 a cache that holds ~25% of the
        // working set (ratio ≈ 0.25) and repeat 1 one that holds all of it
        // (ratio ≈ 1.0). Only an aggregate over both repeats lands in the
        // middle; the old bug — reporting the last repeat only — would be
        // ≈ 1.0, and "first repeat only" would be ≈ 0.25.
        let calls = AtomicUsize::new(0);
        let factory = move || -> Arc<dyn Cache> {
            let capacity =
                if calls.fetch_add(1, Ordering::Relaxed) == 0 { 1024 } else { 16_384 };
            Arc::new(KwWfsc::new(capacity, 8, Policy::Lru))
        };
        let cfg = RunConfig {
            threads: 2,
            duration: Duration::from_millis(40),
            repeats: 2,
            seed: 5,
            ..Default::default()
        };
        let r = measure(&factory, &Workload::AllHit { working_set: 4096 }, &cfg);
        assert!(
            r.hit_ratio > 0.30 && r.hit_ratio < 0.95,
            "aggregate ratio {} should mix both repeats, not report the last",
            r.hit_ratio
        );
    }

    #[test]
    fn tlfu_factory_wraps_and_measures() {
        let factory =
            impl_factory("KW-WFSC", 4096, 2, Policy::Lru, AdmissionMode::TinyLfu).unwrap();
        assert_eq!(factory().name(), "KW-WFSC+TLFU");
        // The resident working set must survive the warm-up through
        // admission (the install loop seeds frequency via get-then-fill).
        let r = measure(&*factory, &Workload::AllHit { working_set: 256 }, &quick_cfg(2));
        assert!(r.hit_ratio > 0.9, "hit ratio through admission {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn every_impl_builds_with_both_admission_modes() {
        for name in IMPLS {
            for admission in AdmissionMode::ALL {
                let factory = impl_factory(name, 1024, 2, Policy::Lru, admission)
                    .unwrap_or_else(|| panic!("no factory for {name}"));
                let cache = factory();
                cache.put(3, 33);
                assert_eq!(cache.get(3), Some(33), "{name}{}", admission.label());
            }
        }
    }

    #[test]
    fn workload_labels() {
        assert_eq!(Workload::AllMiss.label(), "100%-miss");
        assert_eq!(Workload::AllHit { working_set: 1 }.label(), "100%-hit");
        assert_eq!(Workload::Expiring { working_set: 1 }.label(), "expiring");
    }

    #[test]
    fn fill_spec_labels_and_plain_detection() {
        use crate::lifetime::WeightDist;
        let plain = FillSpec::default();
        assert!(plain.is_plain());
        assert_eq!(plain.label(), "immortal");
        assert_eq!(plain.opts_for(7), crate::lifetime::EntryOpts::default());
        let ttl = FillSpec { ttl: Some(Duration::from_millis(100)), ..Default::default() };
        assert!(!ttl.is_plain());
        let weighted = FillSpec { weight_dist: WeightDist::Zipf { max: 8 }, ..Default::default() };
        assert!(!weighted.is_plain());
        assert_eq!(weighted.label(), "immortal/zipf:8");
        assert!(weighted.opts_for(7).weight >= 1);
    }

    #[test]
    fn expiring_workload_without_ttl_behaves_like_all_hit() {
        // No TTL in the fill spec: the pre-installed working set never
        // decays, so the expiring loop is a pure hit loop.
        let r = measure(
            &kw_factory(4096),
            &Workload::Expiring { working_set: 256 },
            &quick_cfg(2),
        );
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
        assert!(r.mops.mean() > 0.0);
    }

    #[test]
    fn expiring_workload_with_short_ttl_misses_and_refills() {
        // A 1 ms TTL over a 50 ms window: entries die between touches,
        // so a healthy fraction of gets miss and refill. The run must
        // stay well-formed (ops flowing, ratio strictly between 0 and 1).
        let cfg = RunConfig {
            fill: FillSpec { ttl: Some(Duration::from_millis(1)), ..Default::default() },
            ..quick_cfg(2)
        };
        let r = measure(&kw_factory(4096), &Workload::Expiring { working_set: 4096 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio < 1.0, "a 1ms TTL must produce some expiries");
    }

    #[test]
    fn weighted_fills_run_end_to_end() {
        use crate::lifetime::WeightDist;
        let cfg = RunConfig {
            fill: FillSpec { weight_dist: WeightDist::Zipf { max: 8 }, ..Default::default() },
            ..quick_cfg(2)
        };
        let r = measure(&kw_factory(4096), &Workload::Expiring { working_set: 512 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.0, "weighted resident set should still hit");
    }

    #[test]
    fn byte_value_fills_run_end_to_end() {
        use crate::lifetime::ValueDist;
        // A byte-dist fill against a value-store cache: the resident set
        // is installed as slab blobs, the word-path `get` probe still
        // sees the published handles, so the hit loop behaves normally.
        let factory = || -> Arc<dyn Cache> {
            Arc::from(crate::kway::build_with_values(Variant::Wfsc, 4096, 8, Policy::Lru, 1 << 22))
        };
        let cfg = RunConfig {
            fill: FillSpec { value_dist: ValueDist::Zipf { max: 512 }, ..Default::default() },
            ..quick_cfg(2)
        };
        assert_eq!(cfg.fill.label(), "immortal/values=zipf:512");
        assert!(!cfg.fill.is_plain());
        let r = measure(&factory, &Workload::Expiring { working_set: 512 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.5, "byte resident set should hit: {}", r.hit_ratio);
    }

    #[test]
    fn mid_run_resize_spec_grows_the_cache() {
        use std::sync::Mutex;
        let last: Arc<Mutex<Option<Arc<dyn Cache>>>> = Arc::new(Mutex::new(None));
        let last2 = last.clone();
        let factory = move || -> Arc<dyn Cache> {
            let c: Arc<dyn Cache> = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
            *last2.lock().unwrap() = Some(c.clone());
            c
        };
        let cfg = RunConfig {
            threads: 2,
            duration: Duration::from_millis(150),
            repeats: 1,
            seed: 7,
            resize: Some(ResizeSpec { at_ops: 1, to_capacity: 4096 }),
            ..Default::default()
        };
        let r = measure(&factory, &Workload::AllHit { working_set: 256 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        let cache = last.lock().unwrap().clone().unwrap();
        assert!(!cache.resize_pending(), "the harness drives the migration to completion");
        assert_eq!(cache.capacity(), 4096, "the mid-run resize must have landed");
    }

    #[test]
    fn measure_resize_recovers_the_twin_hit_ratio() {
        // Working set 3× the initial capacity: capped hit ratio before,
        // near-twin hit ratio after the grow refills. This is the
        // acceptance criterion of the figR figures in miniature.
        let factory = || -> Arc<dyn Cache> { Arc::new(KwWfsc::new(1024, 8, Policy::Lru)) };
        let twin = || -> Arc<dyn Cache> { Arc::new(KwWfsc::new(4096, 8, Policy::Lru)) };
        let r = measure_resize(&factory, &twin, 4096, 3072, 2, Duration::from_millis(80), 3);
        assert!(r.before.hit_ratio < 0.8, "3× working set must overflow: {}", r.before.hit_ratio);
        assert!(r.twin_hit > 0.85, "twin at target capacity should mostly hit: {}", r.twin_hit);
        assert!(
            r.after.hit_ratio > r.twin_hit - 0.05,
            "grow must recover the twin's steady state: {} vs twin {}",
            r.after.hit_ratio,
            r.twin_hit
        );
        assert!(r.before.mops > 0.0 && r.during.mops > 0.0 && r.after.mops > 0.0);
        assert!(r.migrate_ms >= 0.0);
    }

    #[test]
    fn pinned_run_measures_and_reports_cycles() {
        // `pin` + `numa_interleave` must not disturb the measurement
        // (both are best-effort), and on x86_64 the summed TSC deltas
        // must produce a positive cycles-per-op figure.
        let cfg = RunConfig { pin: true, numa_interleave: true, ..quick_cfg(2) };
        let r = measure(&kw_factory(4096), &Workload::AllHit { working_set: 256 }, &cfg);
        assert!(r.mops.mean() > 0.0);
        assert!(r.hit_ratio > 0.95, "hit ratio {}", r.hit_ratio);
        if crate::util::clock::cycles_supported() {
            assert!(r.cycles_per_op > 0.0, "cycles/op {}", r.cycles_per_op);
        } else {
            assert_eq!(r.cycles_per_op, 0.0);
        }
    }

    #[test]
    fn unpinned_run_still_reports_cycles() {
        // cycles-per-op is sampled whether or not the run pins: the TSC
        // bracket lives in the worker path, not behind the flag.
        let r = measure(&kw_factory(1024), &Workload::AllMiss, &quick_cfg(1));
        if crate::util::clock::cycles_supported() {
            assert!(r.cycles_per_op > 0.0, "cycles/op {}", r.cycles_per_op);
        }
    }

    #[test]
    fn variant_name_unused_guard() {
        // Keep Variant imported for the bench code that shares this module.
        let _ = Variant::ALL;
    }
}
