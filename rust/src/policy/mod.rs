//! Eviction policies as *metadata semantics over one counter word per way*.
//!
//! The paper's key implementation observation (Section 3) is that with
//! limited associativity, LRU / LFU / FIFO / Random / Hyperbolic all reduce
//! to (a) how a per-entry counter is initialized, (b) how it is updated on
//! a hit, and (c) a scan over at most K counters to pick the victim — no
//! linked lists, heaps or ghost entries. This module encodes exactly that
//! contract so every cache implementation (`kway::*`, the sampled
//! baselines, the XLA-side simulator) shares one definition.
//!
//! Metadata packing:
//! * LRU — the logical timestamp of the last access; victim = min.
//! * LFU — the access count; victim = min.
//! * FIFO — the insertion timestamp, never updated on hit; victim = min.
//! * Random — metadata unused; victim = uniform way.
//! * Hyperbolic — packs `(count: 24 bits | t0: 40 bits)`; the priority is
//!   `count / (now - t0)` and the victim is the minimum. Comparison is done
//!   with u128 cross-multiplication so the hot path stays float-free:
//!   `n_a/(age_a) < n_b/(age_b)  ⟺  n_a·age_b < n_b·age_a`.

use crate::util::rng::Rng;

/// Bits reserved for the hyperbolic access count (saturating).
const HYP_COUNT_BITS: u32 = 24;
const HYP_T0_MASK: u64 = (1 << (64 - HYP_COUNT_BITS)) - 1;
const HYP_COUNT_MAX: u64 = (1 << HYP_COUNT_BITS) - 1;

/// The five eviction policies of the paper's K-Way implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Least-recently-used: metadata = last-access timestamp.
    Lru,
    /// Least-frequently-used: metadata = access count.
    Lfu,
    /// First-in-first-out: metadata = insertion timestamp, hits ignored.
    Fifo,
    /// Uniform-random victim; metadata unused.
    Random,
    /// Hyperbolic caching: victim minimizes `count / age`.
    Hyperbolic,
}

impl Policy {
    /// All policies, for sweeps.
    pub const ALL: [Policy; 5] =
        [Policy::Lru, Policy::Lfu, Policy::Fifo, Policy::Random, Policy::Hyperbolic];

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(Policy::Lru),
            "lfu" => Some(Policy::Lfu),
            "fifo" => Some(Policy::Fifo),
            "random" | "rand" => Some(Policy::Random),
            "hyperbolic" | "hyp" => Some(Policy::Hyperbolic),
            _ => None,
        }
    }

    /// Canonical CLI spelling (inverse of [`Policy::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Lru => "lru",
            Policy::Lfu => "lfu",
            Policy::Fifo => "fifo",
            Policy::Random => "random",
            Policy::Hyperbolic => "hyperbolic",
        }
    }

    /// Metadata for a freshly inserted entry at logical time `now`.
    #[inline]
    pub fn initial_meta(&self, now: u64) -> u64 {
        match self {
            Policy::Lru | Policy::Fifo => now,
            Policy::Lfu => 1,
            Policy::Random => 0,
            Policy::Hyperbolic => pack_hyperbolic(1, now),
        }
    }

    /// Metadata after a hit at logical time `now`.
    #[inline]
    pub fn on_hit_meta(&self, old: u64, now: u64) -> u64 {
        match self {
            Policy::Lru => now,
            Policy::Lfu => old.saturating_add(1),
            Policy::Fifo | Policy::Random => old,
            Policy::Hyperbolic => {
                let (count, t0) = unpack_hyperbolic(old);
                pack_hyperbolic((count + 1).min(HYP_COUNT_MAX), t0)
            }
        }
    }

    /// Does a hit need to write metadata back at all?
    #[inline]
    pub fn updates_on_hit(&self) -> bool {
        !matches!(self, Policy::Fifo | Policy::Random)
    }

    /// True when entry `a` is a better (or equal) eviction victim than `b`.
    #[inline]
    pub fn victim_le(&self, a: u64, b: u64, now: u64) -> bool {
        match self {
            Policy::Lru | Policy::Lfu | Policy::Fifo => a <= b,
            Policy::Random => true, // selection is done by the caller's RNG
            Policy::Hyperbolic => {
                let (na, t0a) = unpack_hyperbolic(a);
                let (nb, t0b) = unpack_hyperbolic(b);
                let age_a = now.saturating_sub(t0a).max(1) as u128;
                let age_b = now.saturating_sub(t0b).max(1) as u128;
                // priority_a <= priority_b  ⟺  na/age_a <= nb/age_b
                (na as u128) * age_b <= (nb as u128) * age_a
            }
        }
    }

    /// Index of the victim among `metas` (all ways occupied). For `Random`
    /// the choice is uniform via `rng`; for the rest it is the policy
    /// minimum with ties broken towards the lowest index.
    #[inline]
    pub fn select_victim(&self, metas: &[u64], now: u64, rng: &mut Rng) -> usize {
        debug_assert!(!metas.is_empty());
        if matches!(self, Policy::Random) {
            return rng.index(metas.len());
        }
        let mut best = 0usize;
        for (i, &m) in metas.iter().enumerate().skip(1) {
            if !self.victim_le(metas[best], m, now) {
                best = i;
            }
        }
        best
    }

    /// A frequency estimate used by TinyLFU admission when comparing a
    /// candidate against the victim this policy picked.
    #[inline]
    pub fn victim_freq_hint(&self, meta: u64) -> u64 {
        match self {
            Policy::Lfu => meta,
            Policy::Hyperbolic => unpack_hyperbolic(meta).0,
            _ => 0,
        }
    }
}

/// Pack (count, t0) into one hyperbolic metadata word.
#[inline]
pub fn pack_hyperbolic(count: u64, t0: u64) -> u64 {
    (count.min(HYP_COUNT_MAX) << (64 - HYP_COUNT_BITS)) | (t0 & HYP_T0_MASK)
}

/// Unpack a hyperbolic metadata word into (count, t0).
#[inline]
pub fn unpack_hyperbolic(meta: u64) -> (u64, u64) {
    (meta >> (64 - HYP_COUNT_BITS), meta & HYP_T0_MASK)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names_round_trip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
    }

    #[test]
    fn lru_victim_is_oldest() {
        let mut rng = Rng::new(1);
        let metas = [50, 10, 90, 30];
        assert_eq!(Policy::Lru.select_victim(&metas, 100, &mut rng), 1);
    }

    #[test]
    fn lfu_victim_is_least_frequent() {
        let mut rng = Rng::new(1);
        let metas = [5, 3, 3, 9];
        // Ties break to the lowest index.
        assert_eq!(Policy::Lfu.select_victim(&metas, 100, &mut rng), 1);
    }

    #[test]
    fn fifo_ignores_hits() {
        let p = Policy::Fifo;
        let m = p.initial_meta(7);
        assert_eq!(p.on_hit_meta(m, 99), m);
        assert!(!p.updates_on_hit());
    }

    #[test]
    fn lru_hit_refreshes() {
        let p = Policy::Lru;
        assert_eq!(p.on_hit_meta(3, 42), 42);
        assert!(p.updates_on_hit());
    }

    #[test]
    fn lfu_hit_increments_and_saturates() {
        let p = Policy::Lfu;
        assert_eq!(p.on_hit_meta(3, 0), 4);
        assert_eq!(p.on_hit_meta(u64::MAX, 0), u64::MAX);
    }

    #[test]
    fn random_uses_rng_uniformly() {
        let mut rng = Rng::new(3);
        let metas = [0u64; 8];
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[Policy::Random.select_victim(&metas, 0, &mut rng)] += 1;
        }
        for &c in &counts {
            assert!(c > 700 && c < 1300, "non-uniform random victim: {counts:?}");
        }
    }

    #[test]
    fn hyperbolic_pack_unpack() {
        let m = pack_hyperbolic(123, 456_789);
        assert_eq!(unpack_hyperbolic(m), (123, 456_789));
        // Saturation at the 24-bit counter limit.
        let m = pack_hyperbolic(u64::MAX, 1);
        assert_eq!(unpack_hyperbolic(m).0, (1 << 24) - 1);
    }

    #[test]
    fn hyperbolic_prefers_low_rate() {
        let mut rng = Rng::new(4);
        let now = 1000;
        // Entry 0: 10 accesses over age 100 (rate 0.1)
        // Entry 1: 2 accesses over age 500  (rate 0.004)  <- victim
        // Entry 2: 50 accesses over age 100 (rate 0.5)
        let metas = [
            pack_hyperbolic(10, 900),
            pack_hyperbolic(2, 500),
            pack_hyperbolic(50, 900),
        ];
        assert_eq!(Policy::Hyperbolic.select_victim(&metas, now, &mut rng), 1);
    }

    #[test]
    fn hyperbolic_hit_bumps_count_not_t0() {
        let p = Policy::Hyperbolic;
        let m0 = p.initial_meta(10);
        let m1 = p.on_hit_meta(m0, 500);
        let (n, t0) = unpack_hyperbolic(m1);
        assert_eq!(n, 2);
        assert_eq!(t0, 10);
    }

    #[test]
    fn victim_freq_hint() {
        assert_eq!(Policy::Lfu.victim_freq_hint(7), 7);
        assert_eq!(Policy::Hyperbolic.victim_freq_hint(pack_hyperbolic(9, 100)), 9);
        assert_eq!(Policy::Lru.victim_freq_hint(1234), 0);
    }
}
