//! Slab-class byte-value store: the variable-size value memory behind
//! `Cache::put_bytes` / `Cache::get_bytes`.
//!
//! The k-way set lines store fixed 64-bit words; real caches store byte
//! blobs of wildly varying size. This module adds the missing half the
//! memcached way (SNIPPETS.md Snippet 1): **slab classes** — a geometric
//! ladder of fixed item sizes (64 B base × 1.25 growth by default, every
//! size rounded up to the 64-byte [`GRANULE`]) — each class carving its
//! items out of large slab allocations and recycling them through a
//! lock-free Treiber free list. A stored value occupies exactly one item
//! of the smallest class that fits it, so internal fragmentation is
//! bounded by the growth factor and — crucially for the weight-accounting
//! honesty this PR pins — *known*: the entry's weight is the item size in
//! granules, not the requested length, so the per-set weight budget
//! meters bytes the slab actually holds.
//!
//! ## Handles
//!
//! The cache's existing u64 value word carries a packed **handle**
//! instead of a payload:
//!
//! ```text
//!   63      58 57                    32 31                     0
//!  +----------+------------------------+-----------------------+
//!  | class+1  |   generation (26 bit)  |     slot index        |
//!  +----------+------------------------+-----------------------+
//! ```
//!
//! `class+1` keeps every handle non-zero (so 0 stays the "no bytes"
//! word), and the generation makes recycling detectable: every `free`
//! bumps the slot's generation, so a reader holding a stale handle can
//! never mistake a recycled slot's new bytes for its own value. All
//! three k-way claim protocols publish the handle word exactly as they
//! publish word values today — the set-line protocol is untouched.
//!
//! ## Why a torn or recycled read is impossible
//!
//! Each slot leads with a header word `(generation:32 | len:32)` and the
//! read side is a seqlock over it:
//!
//! * **alloc** (exclusive owner via free-list pop): write payload words
//!   (Relaxed), then `header.store(gen|len, Release)`. The handle only
//!   reaches readers through a cache value word published *after* that
//!   store (Release→Acquire through the set line), so a reader that
//!   obtained the handle sees the full payload.
//! * **read**: `h1 = header.load(Acquire)`; bail unless `h1`'s
//!   generation matches the handle; copy payload words (Relaxed);
//!   `fence(Acquire)`; `h2 = header.load(Relaxed)`; accept iff
//!   `h2 == h1`.
//! * **free** (exclusive owner via the cache's claim protocol):
//!   `header.store(gen+1 << 32, Relaxed)`; `fence(Release)`; only *then*
//!   link the slot into the free list (scribbling the payload) — and any
//!   later alloc's scribbles are ordered after the pop that saw the push.
//!
//! The fences give store→store order on the writer side and load→load
//! order on the reader side, so if any copy observed a post-free
//! scribble, the re-load observes the generation bump and the read is
//! discarded — the classic seqlock argument, in the same
//! fence-to-fence style as the wfsc publish audit (DESIGN.md §Hot
//! path). A reader that validates against the *old* generation returns
//! the *old intact* bytes, which linearizes the read before the
//! eviction — exactly what the differential test demands. The 26-bit
//! generation would need 2^26 recycles of one slot *during a single
//! read* to ABA, which no real schedule approaches.
//!
//! Slab memory is grow-only while the store lives (slabs are published
//! to a lock-free pointer table and never unmapped, mirroring the
//! engine's retired-never-freed epochs); a shrink reduces the *budget*
//! so evictions drain items back onto free lists as reuse capacity.

use super::alloc::AlignedSlice;
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, Ordering};
use std::sync::Mutex;

/// Accounting granule: weights meter value memory in units of 64 bytes,
/// so the 16-bit weight field of the life word spans 64 B … 4 MiB and a
/// 1 MiB item is 16384 granules. Every class size is a multiple of this,
/// which is what makes `weight × GRANULE == bytes held` exact.
pub const GRANULE: usize = 64;

/// Target bytes per slab allocation (the memcached page size). Classes
/// whose item outgrows this get one item per slab.
const SLAB_BYTES: usize = 1 << 20;

/// Handle field widths.
const SLOT_BITS: u32 = 32;
const GEN_BITS: u32 = 26;
const GEN_MASK: u64 = (1 << GEN_BITS) - 1;

/// Geometry of the class ladder and the store's hard memory cap.
#[derive(Debug, Clone, Copy)]
pub struct SlabConfig {
    /// Smallest item size in bytes (rounded up to [`GRANULE`]).
    pub base: usize,
    /// Growth factor numerator (item sizes grow by `num/den` per class,
    /// rounded up to [`GRANULE`]).
    pub growth_num: usize,
    /// Growth factor denominator.
    pub growth_den: usize,
    /// Largest value length the store accepts; the ladder's last class
    /// is the first size ≥ this.
    pub max_item: usize,
    /// Hard cap on total carved slab bytes; allocation fails rather than
    /// carve past it (the cache's weight budget governs steady state,
    /// this bounds worst-case footprint).
    pub max_bytes: usize,
}

impl Default for SlabConfig {
    fn default() -> Self {
        Self {
            base: GRANULE,
            growth_num: 5,
            growth_den: 4,
            max_item: 1 << 20,
            max_bytes: 1 << 30,
        }
    }
}

/// One size class: its fixed item size, the slabs carved for it, and the
/// Treiber free list of recycled items.
struct SlabClass {
    /// Payload capacity of one item, bytes (multiple of [`GRANULE`]).
    item_bytes: usize,
    /// Words per slot: 1 header + item_bytes / 8 payload words.
    slot_words: usize,
    /// Slots carved per slab allocation (fixed per class).
    slots_per_slab: usize,
    /// Free-list head: `(aba_tag:32) << 32 | (slot_index + 1):32`;
    /// low half 0 ⇔ empty.
    free_head: AtomicU64,
    /// Free-list length (meters the carved = live + free balance the
    /// torture test asserts).
    free_len: AtomicU64,
    /// Successful allocations / frees, ever.
    allocs: AtomicU64,
    frees: AtomicU64,
    /// Slots carved out of slabs, ever.
    carved: AtomicU64,
    /// Lock-free slab pointer table for readers: `published[i]` is the
    /// first word of slab `i`, null until that slab exists. Pointees are
    /// owned by `slabs` and live until the store drops.
    published: Vec<AtomicPtr<AtomicU64>>,
    /// Owns every slab allocation; also serializes carving.
    slabs: Mutex<Vec<AlignedSlice<AtomicU64>>>,
}

impl SlabClass {
    /// Word `w` of slot `idx`, or `None` for an index beyond the
    /// published slabs (a stale or forged handle).
    #[inline]
    fn word(&self, idx: usize, w: usize) -> Option<&AtomicU64> {
        let slab = idx / self.slots_per_slab;
        let ptr = self.published.get(slab)?.load(Ordering::Acquire);
        if ptr.is_null() {
            return None;
        }
        let off = (idx % self.slots_per_slab) * self.slot_words + w;
        // SAFETY: `ptr` was published from an AlignedSlice of
        // `slots_per_slab * slot_words` words that `slabs` keeps alive
        // for the store's lifetime, and `off` is in range by the modulo.
        Some(unsafe { &*ptr.add(off) })
    }

    /// Pop a recycled slot off the free list (lock-free).
    fn pop_free(&self) -> Option<usize> {
        loop {
            let head = self.free_head.load(Ordering::Acquire);
            let enc = head & 0xFFFF_FFFF;
            if enc == 0 {
                return None;
            }
            let idx = (enc - 1) as usize;
            // The next link lives in payload word 1 of the free slot;
            // visible via the Release CAS that pushed it.
            let next = self.word(idx, 1)?.load(Ordering::Acquire) & 0xFFFF_FFFF;
            let tag = head >> 32;
            let new = ((tag + 1) & 0xFFFF_FFFF) << 32 | next;
            if self
                .free_head
                .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.free_len.fetch_sub(1, Ordering::Relaxed);
                return Some(idx);
            }
        }
    }

    /// Push a slot onto the free list. Caller owns the slot exclusively
    /// and has already bumped its generation behind a Release fence.
    fn push_free(&self, idx: usize) {
        let link = self.word(idx, 1).expect("pushing a slot that was never carved");
        loop {
            let head = self.free_head.load(Ordering::Acquire);
            link.store(head & 0xFFFF_FFFF, Ordering::Relaxed);
            let tag = head >> 32;
            let new = ((tag + 1) & 0xFFFF_FFFF) << 32 | (idx as u64 + 1);
            if self
                .free_head
                .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.free_len.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
}

/// Per-class snapshot for tests and the slab bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassStats {
    /// Fixed item size of the class, bytes.
    pub item_bytes: usize,
    /// Slots ever carved out of slabs.
    pub carved: u64,
    /// Live items (allocs − frees).
    pub live: u64,
    /// Items sitting on the free list.
    pub free: u64,
}

/// Whole-store snapshot: per-class stats plus the byte ledgers.
#[derive(Debug, Clone)]
pub struct SlabStats {
    /// One row per class, smallest first.
    pub classes: Vec<ClassStats>,
    /// Item bytes held by live allocations (Σ live × item_bytes).
    pub used_bytes: u64,
    /// Total bytes carved into slabs (grow-only).
    pub carved_bytes: u64,
    /// The hard cap carving respects.
    pub max_bytes: u64,
}

/// The concurrent byte-value store. See the module docs for the handle
/// layout and the seqlock protocol; the public surface is
/// `alloc` / `read` / `free` plus accounting.
pub struct SlabStore {
    classes: Vec<SlabClass>,
    /// Item bytes held by live allocations.
    used_bytes: AtomicU64,
    /// Bytes carved into slabs, ever.
    carved_bytes: AtomicU64,
    max_bytes: usize,
    max_item: usize,
}

impl SlabStore {
    /// A store with the default ladder (64 B × 1.25 up to 1 MiB items)
    /// capped at `max_bytes` of carved slab memory.
    pub fn new(max_bytes: usize) -> Self {
        Self::with_config(SlabConfig { max_bytes, ..SlabConfig::default() })
    }

    /// A store sized for a cache whose total value-weight budget is
    /// `value_bytes`: the carve cap is twice the budget (headroom for
    /// transient overshoot and free-list retention — free items are
    /// reuse capacity, not returned memory), floored so at least a few
    /// largest-class items always fit.
    pub fn for_budget(value_bytes: usize) -> Self {
        Self::new(value_bytes.saturating_mul(2).max(4 * SLAB_BYTES))
    }

    /// The per-way granule budget for a cache of `capacity` entry slots
    /// sharing `value_bytes` of value memory (at least 1 granule).
    pub fn budget_per_way(value_bytes: usize, capacity: usize) -> u64 {
        ((value_bytes / capacity.max(1)) / GRANULE).max(1) as u64
    }

    /// A store with an explicit class ladder.
    pub fn with_config(cfg: SlabConfig) -> Self {
        assert!(cfg.growth_num > cfg.growth_den && cfg.growth_den > 0, "growth must be > 1");
        assert!(cfg.max_item >= 1, "max_item must be positive");
        let mut sizes = Vec::new();
        let mut cur = cfg.base.max(1).div_ceil(GRANULE) * GRANULE;
        loop {
            sizes.push(cur);
            if cur >= cfg.max_item {
                break;
            }
            let grown = (cur * cfg.growth_num).div_ceil(cfg.growth_den);
            cur = (grown.div_ceil(GRANULE) * GRANULE).max(cur + GRANULE);
        }
        // 6 handle bits hold class+1, so at most 62 classes (1..=63
        // leaves the all-ones pattern unused as a guard).
        assert!(sizes.len() <= 62, "class ladder too deep: {}", sizes.len());
        let classes = sizes
            .iter()
            .map(|&item_bytes| {
                let slot_words = 1 + item_bytes / 8;
                let slots_per_slab = (SLAB_BYTES / (slot_words * 8)).max(1);
                let slab_bytes = slots_per_slab * slot_words * 8;
                // Enough pointer table for this class to consume the
                // whole byte cap on its own, plus one for rounding.
                let max_slabs = cfg.max_bytes.div_ceil(slab_bytes) + 1;
                SlabClass {
                    item_bytes,
                    slot_words,
                    slots_per_slab,
                    free_head: AtomicU64::new(0),
                    free_len: AtomicU64::new(0),
                    allocs: AtomicU64::new(0),
                    frees: AtomicU64::new(0),
                    carved: AtomicU64::new(0),
                    published: (0..max_slabs)
                        .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                        .collect(),
                    slabs: Mutex::new(Vec::new()),
                }
            })
            .collect();
        Self {
            classes,
            used_bytes: AtomicU64::new(0),
            carved_bytes: AtomicU64::new(0),
            max_bytes: cfg.max_bytes,
            max_item: cfg.max_item,
        }
    }

    /// Number of size classes in the ladder.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// The ladder's item sizes, smallest first (tests sweep these).
    pub fn class_sizes(&self) -> Vec<usize> {
        self.classes.iter().map(|c| c.item_bytes).collect()
    }

    /// Largest value length [`SlabStore::alloc`] accepts.
    pub fn max_item_bytes(&self) -> usize {
        self.max_item
    }

    /// Index of the smallest class fitting `len` bytes.
    fn class_of(&self, len: usize) -> Option<usize> {
        if len > self.max_item {
            return None;
        }
        self.classes.iter().position(|c| c.item_bytes >= len)
    }

    /// The item size a value of `len` bytes would occupy — the *honest*
    /// footprint, internal fragmentation included.
    pub fn item_bytes_for(&self, len: usize) -> Option<usize> {
        self.class_of(len).map(|c| self.classes[c].item_bytes)
    }

    /// The weight (in [`GRANULE`]s) a value of `len` bytes costs.
    pub fn granules_for(&self, len: usize) -> Option<u64> {
        self.item_bytes_for(len).map(|b| (b / GRANULE) as u64)
    }

    /// Item bytes currently held by live allocations.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes.load(Ordering::Relaxed)
    }

    /// Total bytes ever carved into slabs (grow-only).
    pub fn carved_bytes(&self) -> u64 {
        self.carved_bytes.load(Ordering::Relaxed)
    }

    /// Snapshot the per-class ledgers. Only *quiescent* snapshots are
    /// exactly consistent (concurrent alloc/free can be mid-count).
    pub fn stats(&self) -> SlabStats {
        SlabStats {
            classes: self
                .classes
                .iter()
                .map(|c| ClassStats {
                    item_bytes: c.item_bytes,
                    carved: c.carved.load(Ordering::Relaxed),
                    live: c.allocs.load(Ordering::Relaxed) - c.frees.load(Ordering::Relaxed),
                    free: c.free_len.load(Ordering::Relaxed),
                })
                .collect(),
            used_bytes: self.used_bytes(),
            carved_bytes: self.carved_bytes(),
            max_bytes: self.max_bytes as u64,
        }
    }

    /// Store `value` into a fresh item and return its packed handle, or
    /// `None` when the value exceeds the largest class or carving another
    /// slab would break the byte cap and no recycled item is free.
    pub fn alloc(&self, value: &[u8]) -> Option<u64> {
        let ci = self.class_of(value.len())?;
        let class = &self.classes[ci];
        let idx = match class.pop_free() {
            Some(idx) => idx,
            None => self.carve(ci)?,
        };
        // Exclusive owner of slot `idx` from here to the header publish.
        let header = class.word(idx, 0).expect("carved slot must resolve");
        let gen = header.load(Ordering::Relaxed) >> 32;
        // Payload: whole little-endian words, the last one zero-padded.
        let mut w = 1usize;
        let mut chunks = value.chunks_exact(8);
        for chunk in &mut chunks {
            let word = u64::from_le_bytes(chunk.try_into().unwrap());
            class.word(idx, w).expect("payload word in range").store(word, Ordering::Relaxed);
            w += 1;
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            class
                .word(idx, w)
                .expect("payload word in range")
                .store(u64::from_le_bytes(buf), Ordering::Relaxed);
        }
        // Publish length under the slot's current generation. Release
        // orders the payload stores before it; the cache's own value-word
        // publish (also Release) then carries the whole chain to readers.
        header.store(gen << 32 | value.len() as u64, Ordering::Release);
        class.allocs.fetch_add(1, Ordering::Relaxed);
        self.used_bytes.fetch_add(class.item_bytes as u64, Ordering::Relaxed);
        Some(pack_handle(ci, gen, idx))
    }

    /// Carve a fresh slot for class `ci`, allocating a new slab when
    /// needed; surplus slots of the new slab go straight onto the free
    /// list. Returns `None` when the byte cap is exhausted.
    fn carve(&self, ci: usize) -> Option<usize> {
        let class = &self.classes[ci];
        let mut slabs = class.slabs.lock().unwrap();
        // Someone may have freed or carved while we waited for the lock.
        if let Some(idx) = class.pop_free() {
            return Some(idx);
        }
        let slab_i = slabs.len();
        let slab_words = class.slots_per_slab * class.slot_words;
        let slab_bytes = slab_words * 8;
        if slab_i >= class.published.len()
            || self.carved_bytes.load(Ordering::Relaxed) + slab_bytes as u64
                > self.max_bytes as u64
        {
            return None;
        }
        // SAFETY: AtomicU64's all-zero pattern is valid and Drop-free;
        // zeroed headers mean generation 0, length 0.
        let slab: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(slab_words) };
        class.published[slab_i].store(slab.as_ptr() as *mut AtomicU64, Ordering::Release);
        slabs.push(slab);
        self.carved_bytes.fetch_add(slab_bytes as u64, Ordering::Relaxed);
        class.carved.fetch_add(class.slots_per_slab as u64, Ordering::Relaxed);
        let base = slab_i * class.slots_per_slab;
        for idx in base + 1..base + class.slots_per_slab {
            class.push_free(idx);
        }
        Some(base)
    }

    /// Read the value `handle` refers to, or `None` when the slot was
    /// recycled (the entry was evicted between the set-line probe and
    /// this read — a correct miss) or the handle is malformed.
    pub fn read(&self, handle: u64) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        self.read_into(handle, &mut out).then_some(out)
    }

    /// [`SlabStore::read`] into a caller-supplied buffer (cleared first);
    /// `false` ⇔ miss. This is the seqlock read described in the module
    /// docs.
    pub fn read_into(&self, handle: u64, out: &mut Vec<u8>) -> bool {
        out.clear();
        let Some((ci, gen, idx)) = self.unpack(handle) else { return false };
        let class = &self.classes[ci];
        let Some(header) = class.word(idx, 0) else { return false };
        let h1 = header.load(Ordering::Acquire);
        if (h1 >> 32) & GEN_MASK != gen {
            return false;
        }
        let len = (h1 & 0xFFFF_FFFF) as usize;
        if len > class.item_bytes {
            return false; // malformed header: never trust it
        }
        out.reserve(len);
        let words = len.div_ceil(8);
        for w in 0..words {
            let Some(word) = class.word(idx, 1 + w) else { return false };
            let bytes = word.load(Ordering::Relaxed).to_le_bytes();
            let take = (len - w * 8).min(8);
            out.extend_from_slice(&bytes[..take]);
        }
        // Load→load order against the re-check; pairs with the freer's
        // Release fence (module docs: the seqlock argument).
        fence(Ordering::Acquire);
        if header.load(Ordering::Relaxed) != h1 {
            out.clear();
            return false;
        }
        true
    }

    /// Recycle the item behind `handle`. The caller must own the handle
    /// exclusively (it was swapped or claimed out of a set line), and
    /// must not free the same handle twice — the cache variants guarantee
    /// both by only freeing words obtained via `swap` or under a claimed
    /// (RESERVED / locked) line.
    pub fn free(&self, handle: u64) {
        let Some((ci, gen, idx)) = self.unpack(handle) else { return };
        let class = &self.classes[ci];
        let Some(header) = class.word(idx, 0) else { return };
        let cur = header.load(Ordering::Relaxed);
        debug_assert_eq!(
            (cur >> 32) & GEN_MASK,
            gen,
            "freeing a stale handle (double free?)"
        );
        // Invalidate first — generation bump, length 0 — then the
        // Release fence orders the bump before the free-list scribbles.
        header.store((cur >> 32).wrapping_add(1) << 32, Ordering::Relaxed);
        fence(Ordering::Release);
        class.push_free(idx);
        class.frees.fetch_add(1, Ordering::Relaxed);
        self.used_bytes.fetch_sub(class.item_bytes as u64, Ordering::Relaxed);
    }

    /// Decode a handle; `None` for words that are not live-looking
    /// handles (class bits out of range).
    fn unpack(&self, handle: u64) -> Option<(usize, u64, usize)> {
        let class_plus1 = (handle >> (SLOT_BITS + GEN_BITS)) as usize;
        if class_plus1 == 0 || class_plus1 > self.classes.len() {
            return None;
        }
        let gen = (handle >> SLOT_BITS) & GEN_MASK;
        let idx = (handle & 0xFFFF_FFFF) as usize;
        Some((class_plus1 - 1, gen, idx))
    }
}

/// Pack (class, generation, slot) into the non-zero handle word.
fn pack_handle(class: usize, gen: u64, idx: usize) -> u64 {
    ((class as u64 + 1) << (SLOT_BITS + GEN_BITS)) | ((gen & GEN_MASK) << SLOT_BITS) | idx as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_granular_monotone_and_covers_max_item() {
        let s = SlabStore::new(1 << 26);
        let sizes = s.class_sizes();
        assert_eq!(sizes[0], GRANULE);
        for w in sizes.windows(2) {
            assert!(w[1] > w[0]);
            assert_eq!(w[1] % GRANULE, 0, "class sizes must be granule multiples");
            // Growth stays within [+1 granule, ~1.34x]: the configured
            // 1.25 plus granule rounding.
            assert!(w[1] <= w[0] * 4 / 3 + GRANULE as usize, "{} -> {}", w[0], w[1]);
        }
        assert!(*sizes.last().unwrap() >= s.max_item_bytes());
        assert!(sizes.len() <= 62);
    }

    #[test]
    fn roundtrip_every_class_boundary_and_edges() {
        let s = SlabStore::new(1 << 26);
        let mut lens: Vec<usize> = vec![0, 1, 7, 8, 9];
        for &size in &s.class_sizes() {
            if size > 4096 {
                break; // keep the unit test fast; big blobs run in tests/slab.rs
            }
            lens.extend([size - 1, size, size + 1]);
        }
        for len in lens {
            let value: Vec<u8> = (0..len).map(|i| (i * 31 + len) as u8).collect();
            let h = s.alloc(&value).unwrap();
            assert_ne!(h, 0, "handles are never the no-bytes word");
            assert_eq!(s.read(h).as_deref(), Some(&value[..]), "len {len}");
            s.free(h);
            assert_eq!(s.read(h), None, "freed handle must read as a miss");
        }
        assert_eq!(s.used_bytes(), 0, "alloc/free must balance the ledger");
    }

    #[test]
    fn oversized_values_are_refused() {
        let s = SlabStore::new(1 << 26);
        assert!(s.alloc(&vec![0u8; s.max_item_bytes() + 1]).is_none());
        assert_eq!(s.granules_for(s.max_item_bytes() + 1), None);
    }

    #[test]
    fn weight_is_item_size_not_requested_size() {
        let s = SlabStore::new(1 << 26);
        // A 65-byte value lands in the 128-byte class: 2 granules held.
        assert_eq!(s.item_bytes_for(65), Some(128));
        assert_eq!(s.granules_for(65), Some(2));
        assert_eq!(s.granules_for(0), Some(1), "zero-length still holds one item");
        let h = s.alloc(&[7u8; 65]).unwrap();
        assert_eq!(s.used_bytes(), 128, "ledger meters the item, not the request");
        s.free(h);
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn recycling_reuses_slots_and_generations_differ() {
        let s = SlabStore::new(1 << 26);
        let h1 = s.alloc(b"first").unwrap();
        s.free(h1);
        let h2 = s.alloc(b"second").unwrap();
        assert_ne!(h1, h2, "recycled slot must carry a new generation");
        assert_eq!(h1 & 0xFFFF_FFFF, h2 & 0xFFFF_FFFF, "same slot is reused");
        assert_eq!(s.read(h1), None, "stale handle misses");
        assert_eq!(s.read(h2).as_deref(), Some(&b"second"[..]));
        s.free(h2);
    }

    #[test]
    fn byte_cap_refuses_carving_but_recycles() {
        // Cap small enough for exactly one smallest-class slab.
        let one_slab = (SLAB_BYTES / ((1 + GRANULE / 8) * 8)) * (1 + GRANULE / 8) * 8;
        let s = SlabStore::with_config(SlabConfig { max_bytes: one_slab, ..Default::default() });
        let mut handles = Vec::new();
        while let Some(h) = s.alloc(b"x") {
            handles.push(h);
        }
        assert!(!handles.is_empty());
        assert!(s.carved_bytes() <= one_slab as u64);
        // Can't grow, but freeing one item makes one alloc succeed.
        assert!(s.alloc(b"y").is_none());
        s.free(handles.pop().unwrap());
        assert!(s.alloc(b"y").is_some());
    }

    #[test]
    fn stats_balance_at_quiesce() {
        let s = SlabStore::new(1 << 26);
        let mut handles = Vec::new();
        for len in [0usize, 63, 64, 65, 500, 4000] {
            handles.push(s.alloc(&vec![1u8; len]).unwrap());
        }
        for h in handles.drain(..3) {
            s.free(h);
        }
        let stats = s.stats();
        let mut live_bytes = 0u64;
        for c in &stats.classes {
            assert_eq!(c.carved, c.live + c.free, "carved = live + free per class");
            live_bytes += c.live * c.item_bytes as u64;
        }
        assert_eq!(live_bytes, stats.used_bytes);
        for h in handles {
            s.free(h);
        }
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn forged_words_never_read() {
        let s = SlabStore::new(1 << 26);
        for word in [0u64, 1, 42, u64::MAX, 1 << 58, 63 << 58] {
            assert_eq!(s.read(word), None, "word {word:#x}");
            s.free(word); // must be a harmless no-op, not a panic
        }
    }

    #[test]
    fn concurrent_churn_holds_the_ledger() {
        use std::sync::Arc;
        let s = Arc::new(SlabStore::new(1 << 26));
        std::thread::scope(|scope| {
            for t in 0..4 {
                let s = Arc::clone(&s);
                scope.spawn(move || {
                    let mut held: Vec<(u64, Vec<u8>)> = Vec::new();
                    for i in 0..2000usize {
                        let len = (i * 37 + t * 101) % 700;
                        let value: Vec<u8> = (0..len).map(|j| (j ^ i ^ t) as u8).collect();
                        if let Some(h) = s.alloc(&value) {
                            held.push((h, value));
                        }
                        if held.len() > 32 {
                            let (h, v) = held.swap_remove(i % held.len());
                            assert_eq!(s.read(h).as_deref(), Some(&v[..]), "torn read");
                            s.free(h);
                        }
                    }
                    for (h, v) in held {
                        assert_eq!(s.read(h).as_deref(), Some(&v[..]));
                        s.free(h);
                    }
                });
            }
        });
        assert_eq!(s.used_bytes(), 0);
        let stats = s.stats();
        for c in &stats.classes {
            assert_eq!(c.carved, c.free, "everything freed: carved slots all on free lists");
            assert_eq!(c.live, 0);
        }
    }
}
