//! The shared set-engine: everything the three k-way variants have in
//! common, in one place.
//!
//! The paper's observation is that limited associativity reduces every
//! cache operation to (a) hash the key to a set, (b) scan at most K ways,
//! (c) update one metadata word — and that only the *synchronization
//! protocol* around those steps differs between designs. This module owns
//! steps (a)–(c):
//!
//! * key preparation — one hash pass yields the set hash, the encoded
//!   key word and the fingerprint ([`SetEngine::prepare`]);
//! * the probe/re-validate read loop ([`SetEngine::probe_get`]);
//! * policy *touch* semantics on hits, in an atomic flavour for the
//!   wait-free variants and a plain flavour for the locked one;
//! * the victim scan over a set snapshot ([`SetEngine::choose_victim`],
//!   [`SetEngine::peek_victim_with`]);
//! * the batched access driver ([`SetEngine::for_batch`]) that pre-hashes
//!   a chunk of keys and software-prefetches their set lines before the
//!   first probe, amortizing hashing and overlapping memory latency —
//!   the same trick data-plane limited-associativity caches use;
//! * the **elastic-resize machinery** ([`Elastic`] / [`Epoch`]): the
//!   epoch-stamped geometry pair (old/new set counts plus an atomic
//!   split watermark) and the claim/finish protocol of the incremental
//!   linear-hash migration, plus the policy-uniform placement rule for
//!   migrated entries ([`SetEngine::place_migrated`]). The per-variant
//!   `migrate_set` bodies live with their storage, but the lifecycle —
//!   who claims which source sets, when the old table retires — is
//!   decided once, here (DESIGN.md §Elastic resizing).
//!
//! [`KwWfa`](super::KwWfa), [`KwWfsc`](super::KwWfsc) and
//! [`KwLs`](super::KwLs) are thin storage adapters over this engine: each
//! contributes its memory layout and its claim/publish protocol, nothing
//! else. See DESIGN.md §Set engine.

use super::geometry::{Geometry, EMPTY, RESERVED};
use super::slab::SlabStore;
use super::with_thread_rng;
use crate::lifetime::{self, EntryOpts};
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::util::hash;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Upper bound on ways so victim scans can use stack buffers.
pub(crate) const MAX_WAYS: usize = 128;

/// How many keys a batched operation prepares (hashes + prefetches) ahead
/// of probing. Deep enough to cover DRAM latency with independent set
/// lines in flight, small enough not to wash the prefetched lines out of
/// L1 before they are probed.
pub(crate) const BATCH_CHUNK: usize = 32;

/// A key prepared for probing: hashing is done exactly once here, so the
/// batched paths can amortize it across a whole chunk before touching any
/// set memory, and the resize path can derive the key's set under both
/// the old and the new geometry from the same hash.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct PreparedKey {
    /// The user key.
    pub key: u64,
    /// Encoded key word (sentinel-free; see [`Geometry::encode_key`]).
    pub ik: u64,
    /// Non-zero fingerprint (only WFSC stores it, but it is one `mix64`
    /// to derive, so preparing it unconditionally keeps one code path).
    pub fp: u64,
    /// Full set hash; any epoch's set index is `hash & (num_sets - 1)`.
    pub hash: u64,
    /// Set index under the geometry passed to [`SetEngine::prepare`]
    /// (used for prefetching; operations re-derive the index from
    /// `hash` against their own epoch snapshot).
    pub set: usize,
}

/// The victim a [`SetEngine::choose_victim`] scan picked.
#[derive(Debug, Clone, Copy)]
pub(crate) struct VictimChoice {
    /// Way index within the set.
    pub way: usize,
    /// Snapshot of that way's claim-guard word (whatever word the
    /// variant's claim CAS races on: WFA the key word, WFSC the
    /// fingerprint, KW-LS the plain key).
    pub guard: u64,
}

/// Policy + logical clock + the lifetime activity flags — the
/// geometry-independent state every variant shares — plus the probe /
/// touch / victim logic over it. The *geometry* itself lives in the
/// variant's [`Elastic`] holder since the resize refactor: it is
/// epoch-stamped, not frozen.
pub(crate) struct SetEngine {
    ways: usize,
    policy: Policy,
    clock: LogicalClock,
    /// Any put so far carried a TTL.
    ttl_active: AtomicBool,
    /// Any put so far carried a weight != 1.
    weight_active: AtomicBool,
    /// Rotating start position for the incremental expiry sweep.
    sweep_cursor: AtomicUsize,
    /// The byte-value slab store, when this cache stores byte blobs
    /// instead of bare words (fixed at construction — plain field, no
    /// hot-path atomic). `None` keeps the word path bit-identical.
    values: Option<Arc<SlabStore>>,
    /// Per-way weight budget in slab granules. 1 for word caches (so
    /// `set_budget` degenerates to the pre-slab "ways" bound); byte
    /// caches set it to `value_bytes / capacity / GRANULE` so the total
    /// budget meters real memory and scales with the set count across
    /// resizes (shrink ⇒ smaller budget ⇒ evict-then-reclaim).
    budget_per_way: AtomicU64,
}

impl SetEngine {
    /// An engine for sets of `ways` entries evicting under `policy`.
    pub fn new(ways: usize, policy: Policy) -> Self {
        assert!(ways <= MAX_WAYS, "ways must be <= {MAX_WAYS}");
        Self {
            ways,
            policy,
            clock: LogicalClock::new(),
            ttl_active: AtomicBool::new(false),
            weight_active: AtomicBool::new(false),
            sweep_cursor: AtomicUsize::new(0),
            values: None,
            budget_per_way: AtomicU64::new(1),
        }
    }

    /// Attach a byte-value store at construction time (before the engine
    /// is shared). `budget_per_way` is the per-way granule budget; byte
    /// puts latch `weight_active`, so the weight-repair machinery runs
    /// whenever byte values exist.
    pub fn attach_values(&mut self, store: Arc<SlabStore>, budget_per_way: u64) {
        self.values = Some(store);
        self.budget_per_way = AtomicU64::new(budget_per_way.max(1));
    }

    /// The attached byte-value store, if any.
    #[inline]
    pub fn values(&self) -> Option<&Arc<SlabStore>> {
        self.values.as_ref()
    }

    /// Does this cache store byte values? One branch on a plain field;
    /// `false` keeps every word path exactly as before.
    #[inline]
    pub fn values_active(&self) -> bool {
        self.values.is_some()
    }

    /// Recycle the slab item behind a displaced value word. No-op for
    /// word caches, for the zero "no bytes" word and for words that do
    /// not decode to a live handle. Callers must own `word` exclusively —
    /// obtained via `swap` or read under a claimed (RESERVED / locked)
    /// line — so no handle is ever freed twice.
    #[inline]
    pub fn release_value(&self, word: u64) {
        if let Some(store) = &self.values {
            store.free(word);
        }
    }

    /// Retune the per-way granule budget (used when the caller changes
    /// the byte capacity of an attached value store).
    pub fn set_budget_per_way(&self, granules: u64) {
        self.budget_per_way.store(granules.max(1), Ordering::Relaxed);
    }

    /// Store `value` into the slab store and derive the entry options a
    /// byte put publishes with: the caller's TTL, but the weight forced
    /// to the item's granule count — the bytes the slab *actually*
    /// holds, which is what makes `weight()` honest accounting. `None`
    /// when no store is attached, the value exceeds the largest class,
    /// or the store is out of memory. On `Some`, the caller owns the
    /// returned handle and must [`SetEngine::release_value`] it if the
    /// publish fails.
    pub fn alloc_value(&self, value: &[u8], opts: EntryOpts) -> Option<(u64, EntryOpts)> {
        let store = self.values.as_ref()?;
        let granules = store.granules_for(value.len())?;
        if granules > lifetime::MAX_WEIGHT as u64 {
            return None;
        }
        let handle = store.alloc(value)?;
        Some((handle, EntryOpts { ttl: opts.ttl, weight: granules as u32 }))
    }

    /// Record which lifetime dimensions `opts` activates (latching —
    /// once a cache has seen a TTL or a weight it keeps checking them).
    #[inline]
    pub fn note_opts(&self, opts: &EntryOpts) {
        if opts.ttl.is_some() && !self.ttl_active.load(Ordering::Relaxed) {
            self.ttl_active.store(true, Ordering::Relaxed);
        }
        if opts.weight != 1 && !self.weight_active.load(Ordering::Relaxed) {
            self.weight_active.store(true, Ordering::Relaxed);
        }
    }

    /// Has any put carried a TTL? Gates every expiry check.
    #[inline]
    pub fn ttl_active(&self) -> bool {
        self.ttl_active.load(Ordering::Relaxed)
    }

    /// Has any put carried a non-unit weight? Gates the weight repair.
    #[inline]
    pub fn weight_active(&self) -> bool {
        self.weight_active.load(Ordering::Relaxed)
    }

    /// Per-set weight budget. Capacity is interpreted as the total
    /// *weight* budget, so each set's share is its way count times the
    /// per-way granule budget — with unit weights (word caches) the
    /// multiplier is 1 and the bound degenerates to "at most k entries",
    /// exactly the pre-lifetime semantics (DESIGN.md §Weighted
    /// capacity). With a byte-value store the multiplier meters slab
    /// granules, so the budget is real memory. Resizes scale the set
    /// *count*, never the ways, so the per-set budget is a constant of
    /// the cache and the *total* budget tracks the set count.
    #[inline]
    pub fn set_budget(&self) -> u64 {
        self.ways as u64 * self.budget_per_way.load(Ordering::Relaxed)
    }

    /// Ways per set (fixed across resizes).
    #[inline]
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Coarse wall-clock for expiry checks: the shared millisecond clock
    /// when TTLs are active, 0 (against which nothing is expired, since
    /// every check is also gated on [`SetEngine::ttl_active`]) otherwise.
    #[inline]
    pub fn expiry_now(&self) -> u64 {
        if self.ttl_active() {
            lifetime::now_ms()
        } else {
            0
        }
    }

    /// Hand out the rotating start set for an incremental sweep of
    /// `max_sets` of the current `num_sets` sets; consecutive calls cover
    /// the whole cache.
    #[inline]
    pub fn sweep_start(&self, max_sets: usize, num_sets: usize) -> usize {
        self.sweep_cursor.fetch_add(max_sets, Ordering::Relaxed) % num_sets
    }

    /// The eviction policy.
    #[inline]
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Advance the logical clock (one tick per cache operation).
    #[inline]
    pub fn tick(&self) -> u64 {
        self.clock.tick()
    }

    /// Read the logical clock without advancing it.
    #[inline]
    pub fn now(&self) -> u64 {
        self.clock.now()
    }

    /// Hash a key once into everything a probe needs. `geo` supplies the
    /// set mask for the prefetch-facing `set` field; operations re-mask
    /// `hash` against their own epoch snapshot.
    #[inline]
    pub fn prepare(&self, key: u64, geo: Geometry) -> PreparedKey {
        let hash = Geometry::hash_of(key);
        PreparedKey {
            key,
            ik: Geometry::encode_key(key),
            fp: hash::fingerprint(key),
            hash,
            set: geo.set_of_hash(hash),
        }
    }

    /// The probe loop shared by every variant's `get`: scan the k ways and
    /// on a candidate match read the value, then *re-validate* the match so
    /// a mid-replace (torn) read is detected and skipped. For KW-LS the
    /// re-validation is trivially true (the read lock excludes writers) and
    /// folds away after inlining.
    ///
    /// `expired` is the lazy-expiration filter: a way that matches but has
    /// outlived its TTL is treated as a miss, so an expired key is never
    /// returned. Variants gate the life-word load behind
    /// [`SetEngine::ttl_active`] and pass `|_| false` until a TTL exists,
    /// keeping the TTL-free probe identical to the pre-lifetime one.
    #[inline]
    pub fn probe_get(
        &self,
        k: usize,
        matches: impl Fn(usize) -> bool,
        expired: impl Fn(usize) -> bool,
        read_value: impl Fn(usize) -> u64,
    ) -> Option<(usize, u64)> {
        for i in 0..k {
            if matches(i) {
                if expired(i) {
                    continue;
                }
                let value = read_value(i);
                if matches(i) {
                    return Some((i, value));
                }
            }
        }
        None
    }

    /// [`SetEngine::probe_get`] over a precomputed candidate bitmask (bit
    /// `i` ⇔ way `i` may match, from `simd::match_mask` over the set's
    /// fingerprint words). The mask is a *prefilter*: each candidate is
    /// still verified through `matches` (the full atomic key comparison)
    /// and re-validated after the value read, so a stale mask bit is
    /// harmless — exactly the same protocol as the scalar loop, minus the
    /// per-way fingerprint loads for non-candidates.
    #[inline]
    pub fn probe_get_masked(
        &self,
        mut mask: u128,
        matches: impl Fn(usize) -> bool,
        expired: impl Fn(usize) -> bool,
        read_value: impl Fn(usize) -> u64,
    ) -> Option<(usize, u64)> {
        while mask != 0 {
            let i = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            if matches(i) {
                if expired(i) {
                    continue;
                }
                let value = read_value(i);
                if matches(i) {
                    return Some((i, value));
                }
            }
        }
        None
    }

    /// Pass-1 scan of a put: the way already holding this key, if any.
    #[inline]
    pub fn find_match(&self, k: usize, matches: impl Fn(usize) -> bool) -> Option<usize> {
        (0..k).find(|&i| matches(i))
    }

    /// [`SetEngine::find_match`] over a candidate bitmask; same prefilter
    /// contract as [`SetEngine::probe_get_masked`].
    #[inline]
    pub fn find_match_masked(
        &self,
        mut mask: u128,
        matches: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        while mask != 0 {
            let i = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            if matches(i) {
                return Some(i);
            }
        }
        None
    }

    /// Apply the policy's on-hit metadata update with the cheapest atomic
    /// op that implements it. A lost race here only blurs the recency /
    /// frequency signal by one access — the same semantics as the paper's
    /// non-synchronized Java counter updates.
    #[inline]
    pub fn touch_atomic(&self, meta: &AtomicU64, now: u64) {
        match self.policy {
            Policy::Lru => meta.store(now, Ordering::Relaxed),
            Policy::Lfu => {
                meta.fetch_add(1, Ordering::Relaxed);
            }
            Policy::Hyperbolic => {
                let old = meta.load(Ordering::Relaxed);
                let new = self.policy.on_hit_meta(old, now);
                // Single *strong* CAS attempt; on contention we drop the
                // update. Strong so the uncontended (and single-threaded)
                // path never fails spuriously on LL/SC targets — the
                // atomic/plain touch-flavour parity depends on it.
                let _ = meta.compare_exchange(old, new, Ordering::Relaxed, Ordering::Relaxed);
            }
            Policy::Fifo | Policy::Random => {}
        }
    }

    /// On-hit metadata update for plain (lock-protected) storage.
    #[inline]
    pub fn touch_plain(&self, meta: &mut u64, now: u64) {
        *meta = self.policy.on_hit_meta(*meta, now);
    }

    /// Metadata word for a fresh insert.
    #[inline]
    pub fn initial_meta(&self, now: u64) -> u64 {
        self.policy.initial_meta(now)
    }

    /// Does a hit need a metadata write at all?
    #[inline]
    pub fn updates_on_hit(&self) -> bool {
        self.policy.updates_on_hit()
    }

    /// Victim selection over an explicit metadata snapshot.
    #[inline]
    pub fn select_victim(&self, metas: &[u64], now: u64) -> usize {
        with_thread_rng(|rng| self.policy.select_victim(metas, now, rng))
    }

    /// Snapshot a full set through `snap` — per way, the claim-guard word,
    /// the metadata and whether the way holds an *expired* entry — and
    /// pick the victim. An expired line is the victim of first resort
    /// (reclaiming it costs the hit ratio nothing — lazy expiration,
    /// DESIGN.md §Expiration); otherwise the policy chooses. Variants
    /// report a way that must not be chosen (mid-publish) by returning
    /// `u64::MAX` metadata, which only loses to other `u64::MAX` ways and
    /// disables the expired shortcut for that way.
    #[inline]
    pub fn choose_victim(
        &self,
        k: usize,
        now: u64,
        snap: impl Fn(usize) -> (u64, u64, bool),
    ) -> VictimChoice {
        let mut guards = [0u64; MAX_WAYS];
        let mut metas = [u64::MAX; MAX_WAYS];
        for i in 0..k {
            let (guard, meta, expired) = snap(i);
            if expired && meta != u64::MAX {
                return VictimChoice { way: i, guard };
            }
            guards[i] = guard;
            metas[i] = meta;
        }
        let way = self.select_victim(&metas[..k], now);
        VictimChoice { way, guard: guards[way] }
    }

    /// Placement rule for a migrated entry arriving in a *full* target
    /// set (the shrink-merge case, or a grown set refilled by concurrent
    /// churn): the migrated entry competes with the residents under the
    /// cache's own policy, carrying the metadata it earned in the old
    /// table. Returns `Some(way)` when a resident loses (replace it) and
    /// `None` when the migrated entry itself is the policy victim (drop
    /// it — exactly what the policy would have evicted had the sets
    /// always been merged). Mid-publish residents (`u64::MAX` metadata)
    /// are never displaced. For a total-order policy like LRU this greedy
    /// merge keeps exactly the top-k entries of the merged sets — the
    /// "shrink evicts by policy order" contract `rust/tests/resize.rs`
    /// pins.
    pub fn place_migrated(
        &self,
        k: usize,
        now: u64,
        metas: &[u64],
        migrated_meta: u64,
    ) -> Option<usize> {
        debug_assert!(k <= MAX_WAYS);
        // One slot wider than the victim-scan buffers: the migrated
        // entry competes as a (k+1)-th candidate even at ways == MAX_WAYS.
        let mut all = [u64::MAX; MAX_WAYS + 1];
        all[..k].copy_from_slice(&metas[..k]);
        all[k] = migrated_meta;
        let pick = self.select_victim(&all[..k + 1], now);
        (pick != k && metas[pick] != u64::MAX).then_some(pick)
    }

    /// Drive a batched pass: prepare (hash) a chunk of items up front,
    /// issue a software prefetch for each item's set line, then run `op`
    /// per item in input order. Preparing a whole chunk before the first
    /// probe amortizes hashing and overlaps the set lines' memory latency
    /// with useful work instead of stalling on each miss in turn. `geo`
    /// is the batch-entry geometry snapshot; it only steers prefetches,
    /// so a resize landing mid-batch costs at worst a useless prefetch.
    #[inline]
    pub fn for_batch<I>(
        &self,
        geo: Geometry,
        items: &[I],
        key_of: impl Fn(&I) -> u64,
        prefetch_set: impl Fn(usize),
        mut op: impl FnMut(PreparedKey, &I),
    ) {
        let mut prepared = [PreparedKey::default(); BATCH_CHUNK];
        for chunk in items.chunks(BATCH_CHUNK) {
            for (i, item) in chunk.iter().enumerate() {
                let pk = self.prepare(key_of(item), geo);
                prefetch_set(pk.set);
                prepared[i] = pk;
            }
            for (i, item) in chunk.iter().enumerate() {
                op(prepared[i], item);
            }
        }
    }

    /// Shared `peek_victim` (the advisory preview used by TinyLFU
    /// admission). `load_key` must yield the *effective* key word of a
    /// way: [`EMPTY`] when the way is free, [`RESERVED`] when it is
    /// mid-publish, the encoded key otherwise; `load_life` the way's life
    /// word (only consulted while TTLs are active). Returns `None` when
    /// the set still has room (no eviction needed) or the victim is
    /// mid-publish.
    ///
    /// The victim-preview **contract** every variant upholds (pinned by
    /// `rust/tests/peek_victim.rs` and relied on by
    /// [`crate::tinylfu::TlfuCache`]):
    ///
    /// * a returned key was resident in the probed key's set at snapshot
    ///   time — never a sentinel, never a made-up key;
    /// * `None` ⇒ the insert needs no eviction *or* the set is mid-churn
    ///   (callers must treat `None` as "admit") — an *expired* resident
    ///   line counts as free room, since displacing it costs nothing;
    /// * under concurrency the preview is *advisory*: the put that follows
    ///   may evict a different way. Admission is a probabilistic filter,
    ///   so acting on a stale preview mis-scores at most one insert —
    ///   safety is untouched (DESIGN.md §Admission).
    pub fn peek_victim_with(
        &self,
        k: usize,
        load_key: impl Fn(usize) -> u64,
        load_meta: impl Fn(usize) -> u64,
        load_life: impl Fn(usize) -> u64,
    ) -> Option<u64> {
        let now = self.now();
        let ttl_active = self.ttl_active();
        let now_ms = self.expiry_now();
        let mut keys = [0u64; MAX_WAYS];
        let mut metas = [0u64; MAX_WAYS];
        for i in 0..k {
            keys[i] = load_key(i);
            if keys[i] == EMPTY {
                return None; // room available, no eviction needed
            }
            if keys[i] != RESERVED && ttl_active && lifetime::is_expired(load_life(i), now_ms) {
                return None; // expired line: the insert evicts a dead entry
            }
            metas[i] = if keys[i] == RESERVED { u64::MAX } else { load_meta(i) };
        }
        let vi = self.select_victim(&metas[..k], now);
        (keys[vi] != RESERVED).then(|| Geometry::decode_key(keys[vi]))
    }
}

/// One geometry epoch of an elastic cache: the target geometry, its
/// storage, and — while a resize is migrating — a pointer back to the
/// *source* epoch plus the linear-hash split watermark over its sets.
///
/// `prev == null` means "not resizing": the epoch is self-contained and
/// every operation touches only `table`. While `prev` is set, readers
/// that miss in `table` fall through to the source epoch's table, and
/// writers drain their key's source set into `table` before inserting
/// (help-on-write), so no admitted entry is ever lost to the move.
pub(crate) struct Epoch<T> {
    /// Target geometry of this epoch.
    pub geo: Geometry,
    /// Storage for `geo`. Shared (`Arc`) so the completion epoch can
    /// reuse the migrated-into table without copying it.
    pub table: Arc<T>,
    /// The epoch being migrated *from*; null once migration completed.
    prev: *const Epoch<T>,
    /// Next source set a background `resize_step` claims (monotone;
    /// claims beyond the source set count are harmless no-ops).
    watermark: AtomicUsize,
    /// Source sets whose claimed migration step has completed. When this
    /// reaches the source set count the resize is finished and the old
    /// table retires from the read path.
    drained: AtomicUsize,
}

// SAFETY: `prev` points at an epoch owned by the same `Elastic`'s
// retired-epoch list, which outlives every reader (epochs are never freed
// before the Elastic itself drops); all mutable state is atomic.
unsafe impl<T: Send + Sync> Send for Epoch<T> {}
unsafe impl<T: Send + Sync> Sync for Epoch<T> {}

impl<T> Epoch<T> {
    /// The epoch being migrated from, while a resize is in flight.
    #[inline]
    pub fn prev(&self) -> Option<&Epoch<T>> {
        // SAFETY: see the Send/Sync justification above.
        unsafe { self.prev.as_ref() }
    }
}

/// Holder of an elastic cache's epoch chain: one atomic pointer to the
/// current epoch, plus ownership of every epoch ever installed.
///
/// Epochs are *retired, never freed* while the cache lives: a reader that
/// snapshotted an epoch just before a transition can keep using it (its
/// table is still valid memory; at worst it performs a benign stale probe
/// or an insert that the in-flight migration immediately republishes).
/// This is the rust answer to the paper's reliance on Java's GC for
/// node reclamation, applied at table granularity: resizes are rare, so
/// holding a retired table until drop costs one allocation per resize,
/// not a hot-path reclamation protocol.
pub(crate) struct Elastic<T> {
    current: AtomicPtr<Epoch<T>>,
    /// Owns every epoch ever installed (including the current one), in
    /// installation order. Also serializes begin/finish transitions.
    epochs: Mutex<Vec<Box<Epoch<T>>>>,
}

impl<T> Elastic<T> {
    /// A fresh holder whose first epoch is (`geo`, `table`).
    pub fn new(geo: Geometry, table: T) -> Self {
        let epoch = Box::new(Epoch {
            geo,
            table: Arc::new(table),
            prev: std::ptr::null(),
            watermark: AtomicUsize::new(0),
            drained: AtomicUsize::new(0),
        });
        let ptr = &*epoch as *const Epoch<T> as *mut Epoch<T>;
        Self { current: AtomicPtr::new(ptr), epochs: Mutex::new(vec![epoch]) }
    }

    /// The current epoch. One atomic load; the reference stays valid for
    /// the borrow of `self` (epochs are never freed before drop).
    #[inline]
    pub fn snapshot(&self) -> &Epoch<T> {
        // SAFETY: `current` always points into `epochs`, whose boxes are
        // never dropped while `self` is alive.
        unsafe { &*self.current.load(Ordering::Acquire) }
    }

    /// Is a resize migration currently in flight?
    #[inline]
    pub fn resizing(&self) -> bool {
        self.snapshot().prev().is_some()
    }

    /// Begin a resize toward `new_geo`, building fresh storage through
    /// `make_table`. Returns `false` when another resize is still
    /// migrating (finish it first — [`Elastic::step`]); returns `true`
    /// without starting a migration when the set count is unchanged (the
    /// geometry is swapped in place: same table, new requested-capacity
    /// bookkeeping).
    pub fn begin(&self, new_geo: Geometry, make_table: impl FnOnce(Geometry) -> T) -> bool {
        let mut epochs = self.epochs.lock().unwrap();
        let cur_ptr = self.current.load(Ordering::Acquire);
        // SAFETY: same invariant as `snapshot`.
        let cur = unsafe { &*cur_ptr };
        if cur.prev().is_some() {
            return false;
        }
        if new_geo == cur.geo {
            return true;
        }
        let (table, prev) = if new_geo.num_sets() == cur.geo.num_sets() {
            (cur.table.clone(), std::ptr::null()) // same shape: no migration
        } else {
            (Arc::new(make_table(new_geo)), cur_ptr as *const Epoch<T>)
        };
        let epoch = Box::new(Epoch {
            geo: new_geo,
            table,
            prev,
            watermark: AtomicUsize::new(0),
            drained: AtomicUsize::new(0),
        });
        let ptr = &*epoch as *const Epoch<T> as *mut Epoch<T>;
        epochs.push(epoch);
        self.current.store(ptr, Ordering::Release);
        true
    }

    /// One increment of background migration: claim up to `max_sets`
    /// source sets off the split watermark, drain each through the
    /// variant's `drain(target, source, set)` and, when the final claimed
    /// set completes, retire the source epoch. Returns the number of sets
    /// this call drained (0 when no resize is pending or every set is
    /// already claimed by other threads).
    pub fn step(
        &self,
        max_sets: usize,
        mut drain: impl FnMut(&Epoch<T>, &Epoch<T>, usize),
    ) -> usize {
        if max_sets == 0 {
            return 0;
        }
        let ep = self.snapshot();
        let Some(prev) = ep.prev() else { return 0 };
        let old_n = prev.geo.num_sets();
        // Clamp before claiming: callers pass usize::MAX as the
        // "drain everything" idiom, and an unclamped fetch_add would
        // overflow both the watermark and the `start + max_sets` sum.
        let max_sets = max_sets.min(old_n);
        if ep.watermark.load(Ordering::Relaxed) >= old_n {
            // Everything is claimed; if the claimants are also done, make
            // sure the epoch closes (the completing thread may have raced
            // a concurrent step when it checked).
            if ep.drained.load(Ordering::Acquire) >= old_n {
                self.finish(ep);
            }
            return 0;
        }
        let start = ep.watermark.fetch_add(max_sets, Ordering::Relaxed);
        if start >= old_n {
            if ep.drained.load(Ordering::Acquire) >= old_n {
                self.finish(ep);
            }
            return 0;
        }
        let end = (start + max_sets).min(old_n);
        for set in start..end {
            drain(ep, prev, set);
        }
        if ep.drained.fetch_add(end - start, Ordering::AcqRel) + (end - start) >= old_n {
            self.finish(ep);
        }
        end - start
    }

    /// Retire the source epoch of `ep`: install a completion epoch with
    /// the same geometry and the *same* table, prev = null. Serialized
    /// with `begin` through the epochs lock; a stale call (the epoch was
    /// already superseded) is a no-op.
    fn finish(&self, ep: &Epoch<T>) {
        let mut epochs = self.epochs.lock().unwrap();
        if self.current.load(Ordering::Acquire) != ep as *const Epoch<T> as *mut Epoch<T> {
            return;
        }
        let epoch = Box::new(Epoch {
            geo: ep.geo,
            table: ep.table.clone(),
            prev: std::ptr::null(),
            watermark: AtomicUsize::new(0),
            drained: AtomicUsize::new(0),
        });
        let ptr = &*epoch as *const Epoch<T> as *mut Epoch<T>;
        epochs.push(epoch);
        self.current.store(ptr, Ordering::Release);
    }
}

/// Best-effort software prefetch of the cache line holding `ptr` into all
/// cache levels. A no-op on targets without a stable prefetch intrinsic —
/// the batched path still wins there from amortized hashing and fewer
/// virtual calls.
#[inline(always)]
pub(crate) fn prefetch_read<T>(ptr: *const T) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        // SAFETY: prefetch is a pure hint; it cannot fault on any address.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(ptr as *const i8) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = ptr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(ways: usize, policy: Policy) -> SetEngine {
        SetEngine::new(ways, policy)
    }

    #[test]
    fn prepare_is_consistent_with_geometry_and_hashing() {
        let e = engine(8, Policy::Lru);
        let geo = Geometry::new(1024, 8);
        for key in 0..1000u64 {
            let pk = e.prepare(key, geo);
            assert_eq!(pk.key, key);
            assert_eq!(pk.ik, Geometry::encode_key(key));
            assert_eq!(pk.fp, hash::fingerprint(key));
            assert_eq!(pk.hash, Geometry::hash_of(key));
            assert_eq!(pk.set, geo.set_of(key));
            assert_eq!(geo.set_of_hash(pk.hash), pk.set);
        }
    }

    #[test]
    fn probe_get_revalidates() {
        let e = engine(4, Policy::Lru);
        // A match that disappears between value read and re-validation
        // must be skipped (simulated with a counter-driven closure).
        use std::cell::Cell;
        let calls = Cell::new(0u32);
        let hit = e.probe_get(
            4,
            |i| {
                if i == 1 {
                    calls.set(calls.get() + 1);
                    calls.get() == 1 // first check passes, re-check fails
                } else {
                    false
                }
            },
            |_| false,
            |_| 42,
        );
        assert_eq!(hit, None);
        // A stable match is returned with its way index.
        let hit = e.probe_get(4, |i| i == 2, |_| false, |i| (i as u64) * 10);
        assert_eq!(hit, Some((2, 20)));
        // An expired match is a miss, even though the key matches.
        let hit = e.probe_get(4, |i| i == 2, |i| i == 2, |i| (i as u64) * 10);
        assert_eq!(hit, None);
    }

    #[test]
    fn choose_victim_avoids_max_meta_ways() {
        let e = engine(4, Policy::Lru);
        let metas = [5u64, u64::MAX, 3, 9];
        let guards = [100u64, 101, 102, 103];
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], false));
        assert_eq!(choice.way, 2);
        assert_eq!(choice.guard, 102);
    }

    #[test]
    fn choose_victim_prefers_expired_lines() {
        let e = engine(4, Policy::Lru);
        let metas = [5u64, 7, 3, 9];
        let guards = [100u64, 101, 102, 103];
        // Way 3 is expired: it wins over the LRU minimum (way 2).
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], i == 3));
        assert_eq!(choice.way, 3);
        assert_eq!(choice.guard, 103);
        // A mid-publish way (meta MAX) is never taken via the expired
        // shortcut.
        let metas = [5u64, u64::MAX, 3, 9];
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], i == 1));
        assert_eq!(choice.way, 2);
    }

    #[test]
    fn place_migrated_is_the_policy_order() {
        let e = engine(4, Policy::Lru);
        // Migrated entry older than every resident: it is the victim.
        assert_eq!(e.place_migrated(4, 100, &[50, 10, 90, 30], 5), None);
        // Migrated entry fresher than the LRU minimum: that resident loses.
        assert_eq!(e.place_migrated(4, 100, &[50, 10, 90, 30], 60), Some(1));
        // A mid-publish resident (u64::MAX meta) is never displaced.
        assert_eq!(e.place_migrated(2, 100, &[u64::MAX, u64::MAX], 60), None);
    }

    #[test]
    fn lifetime_flags_latch_and_gate() {
        use crate::lifetime::EntryOpts;
        use std::time::Duration;
        let e = engine(4, Policy::Lru);
        assert!(!e.ttl_active());
        assert!(!e.weight_active());
        assert_eq!(e.expiry_now(), 0, "TTL-free caches never read the clock");
        e.note_opts(&EntryOpts::default());
        assert!(!e.ttl_active() && !e.weight_active(), "plain opts must not latch");
        e.note_opts(&EntryOpts::ttl(Duration::from_millis(1)));
        assert!(e.ttl_active());
        e.note_opts(&EntryOpts::weight(3));
        assert!(e.weight_active());
        assert_eq!(e.set_budget(), 4);
    }

    #[test]
    fn byte_mode_budget_scales_per_way() {
        let mut e = engine(4, Policy::Lru);
        assert!(!e.values_active());
        assert_eq!(e.set_budget(), 4, "word caches keep the k-entries bound");
        e.release_value(0x1234); // word cache: must be a no-op
        let store = Arc::new(SlabStore::new(1 << 22));
        e.attach_values(store, 16);
        assert!(e.values_active());
        assert_eq!(e.set_budget(), 64, "ways x per-way granules");
        e.set_budget_per_way(8);
        assert_eq!(e.set_budget(), 32);
        e.set_budget_per_way(0);
        assert_eq!(e.set_budget(), 4, "budget is clamped to >= 1 granule per way");
        e.release_value(0); // the no-bytes word is never freed
    }

    #[test]
    fn sweep_start_rotates_over_all_sets() {
        let e = engine(4, Policy::Lru);
        let n = 16usize;
        let mut covered = vec![false; n];
        for _ in 0..n {
            let start = e.sweep_start(1, n);
            covered[start] = true;
        }
        assert!(covered.iter().all(|&c| c), "cursor must cover every set");
    }

    #[test]
    fn peek_victim_with_contract() {
        let e = engine(4, Policy::Lru);
        let immortal = crate::lifetime::immortal_unit();
        // Any empty way -> no eviction needed.
        let keys =
            [Geometry::encode_key(1), EMPTY, Geometry::encode_key(3), Geometry::encode_key(4)];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |_| 0, |_| immortal), None);
        // Full set -> the policy minimum's decoded key.
        let keys = [10u64, 11, 12, 13].map(Geometry::encode_key);
        let metas = [50u64, 10, 90, 30];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |_| immortal), Some(11));
        // Mid-publish victim -> None.
        let keys = [
            Geometry::encode_key(10),
            RESERVED,
            Geometry::encode_key(12),
            Geometry::encode_key(13),
        ];
        let metas = [50u64, 0, 90, 30];
        // RESERVED way is masked to u64::MAX, so the victim is way 3 (30).
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |_| immortal), Some(13));
    }

    #[test]
    fn peek_victim_treats_expired_lines_as_free_room() {
        use crate::lifetime::{life_of, EntryOpts};
        use std::time::Duration;
        let e = engine(4, Policy::Lru);
        e.note_opts(&EntryOpts::ttl(Duration::ZERO)); // activate TTLs
        let keys = [10u64, 11, 12, 13].map(Geometry::encode_key);
        let metas = [50u64, 10, 90, 30];
        let now = crate::lifetime::now_ms();
        let dead = life_of(&EntryOpts::ttl(Duration::ZERO), now);
        let live = life_of(&EntryOpts::default(), now);
        // Way 2 is expired: the preview reports "no live victim needed".
        let lives = [live, live, dead, live];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |i| lives[i]), None);
        // All live: back to the policy minimum.
        let lives = [live; 4];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |i| lives[i]), Some(11));
    }

    #[test]
    fn atomic_and_plain_touch_flavours_agree_for_every_policy() {
        // The engine has two touch flavours — atomic (WFA/WFSC) and plain
        // (KW-LS) — that must encode the *same* policy semantics: driven
        // single-threaded over a scripted access sequence they must
        // produce identical metadata and identical victim choices. This
        // pins the refactor-safety of engine.rs: a change to one flavour
        // that forgets the other diverges the k-way variants' behaviour.
        use crate::util::rng::Rng;
        let k = 8usize;
        // (way, logical time) hit script; strictly increasing times.
        let script: [(usize, u64); 12] = [
            (0, 100),
            (1, 101),
            (0, 102),
            (3, 110),
            (5, 111),
            (0, 112),
            (6, 120),
            (3, 121),
            (2, 130),
            (7, 131),
            (0, 140),
            (4, 141),
        ];
        for policy in Policy::ALL {
            let e = engine(k, policy);
            let atomic: Vec<AtomicU64> =
                (0..k).map(|i| AtomicU64::new(e.initial_meta(10 * i as u64))).collect();
            let mut plain: Vec<u64> = (0..k).map(|i| e.initial_meta(10 * i as u64)).collect();
            for &(way, now) in &script {
                e.touch_atomic(&atomic[way], now);
                e.touch_plain(&mut plain[way], now);
            }
            let metas_atomic: Vec<u64> =
                atomic.iter().map(|m| m.load(Ordering::Relaxed)).collect();
            assert_eq!(metas_atomic, plain, "{policy:?}: metadata flavours diverged");
            // Victim selection over the two flavours' metadata must agree
            // (identically-seeded RNGs make Random comparable too).
            let now = 200;
            let va = policy.select_victim(&metas_atomic, now, &mut Rng::new(99));
            let vp = policy.select_victim(&plain, now, &mut Rng::new(99));
            assert_eq!(va, vp, "{policy:?}: victim choice diverged");
        }
    }

    #[test]
    fn for_batch_visits_every_item_in_order_across_chunks() {
        let e = engine(8, Policy::Lru);
        let geo = Geometry::new(4096, 8);
        let keys: Vec<u64> = (0..(3 * BATCH_CHUNK as u64 + 7)).collect();
        let mut seen = Vec::new();
        e.for_batch(
            geo,
            &keys,
            |&k| k,
            |set| assert!(set < geo.num_sets()),
            |pk, &orig| {
                assert_eq!(pk.key, orig);
                seen.push(pk.key);
            },
        );
        assert_eq!(seen, keys);
    }

    #[test]
    fn elastic_epochs_transition_and_keep_old_tables_alive() {
        let geo = Geometry::new(64, 4); // 16 sets
        let elastic: Elastic<Vec<u64>> = Elastic::new(geo, vec![0; geo.capacity()]);
        assert!(!elastic.resizing());
        let first = elastic.snapshot().table.clone();

        // Same-shape begin (capacity within the same power of two): the
        // geometry swaps, the table is shared, no migration starts.
        assert!(elastic.begin(geo.resized(60), |g| vec![0; g.capacity()]));
        assert!(!elastic.resizing());
        assert_eq!(elastic.snapshot().geo.requested_capacity(), 60);
        assert!(Arc::ptr_eq(&elastic.snapshot().table, &first));

        // A real grow: prev is set, steps drain source sets, the final
        // step retires the source epoch.
        let grown = geo.resized(128); // 32 sets
        assert!(elastic.begin(grown, |g| vec![0; g.capacity()]));
        assert!(elastic.resizing());
        assert!(!elastic.begin(grown, |g| vec![0; g.capacity()]), "no overlapping resizes");
        let mut drained = Vec::new();
        while elastic.resizing() {
            elastic.step(3, |ep, prev, set| {
                assert_eq!(ep.geo.num_sets(), 32);
                assert_eq!(prev.geo.num_sets(), 16);
                drained.push(set);
            });
        }
        drained.sort_unstable();
        assert_eq!(drained, (0..16).collect::<Vec<_>>(), "every source set drained once");
        assert_eq!(elastic.snapshot().geo, grown);
        // The retired table is still reachable through the old snapshot
        // (readers never observe freed memory).
        assert_eq!(first.len(), geo.capacity());
        // Steps with no resize pending are no-ops.
        assert_eq!(elastic.step(4, |_, _, _| panic!("no drain without a resize")), 0);
    }

    #[test]
    fn prefetch_is_safe_on_any_pointer() {
        let v = [1u64, 2, 3];
        prefetch_read(&v[0]);
        prefetch_read(std::ptr::null::<u64>());
    }
}
