//! The shared set-engine: everything the three k-way variants have in
//! common, in one place.
//!
//! The paper's observation is that limited associativity reduces every
//! cache operation to (a) hash the key to a set, (b) scan at most K ways,
//! (c) update one metadata word — and that only the *synchronization
//! protocol* around those steps differs between designs. This module owns
//! steps (a)–(c):
//!
//! * key preparation — one hash pass yields the set index, the encoded
//!   key word and the fingerprint ([`SetEngine::prepare`]);
//! * the probe/re-validate read loop ([`SetEngine::probe_get`]);
//! * policy *touch* semantics on hits, in an atomic flavour for the
//!   wait-free variants and a plain flavour for the locked one;
//! * the victim scan over a set snapshot ([`SetEngine::choose_victim`],
//!   [`SetEngine::peek_victim_with`]);
//! * the batched access driver ([`SetEngine::for_batch`]) that pre-hashes
//!   a chunk of keys and software-prefetches their set lines before the
//!   first probe, amortizing hashing and overlapping memory latency —
//!   the same trick data-plane limited-associativity caches use.
//!
//! [`KwWfa`](super::KwWfa), [`KwWfsc`](super::KwWfsc) and
//! [`KwLs`](super::KwLs) are thin storage adapters over this engine: each
//! contributes its memory layout and its claim/publish protocol, nothing
//! else. See DESIGN.md §Set engine.

use super::geometry::{Geometry, EMPTY, RESERVED};
use super::with_thread_rng;
use crate::lifetime::{self, EntryOpts};
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::util::hash;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Upper bound on ways so victim scans can use stack buffers.
pub(crate) const MAX_WAYS: usize = 128;

/// How many keys a batched operation prepares (hashes + prefetches) ahead
/// of probing. Deep enough to cover DRAM latency with independent set
/// lines in flight, small enough not to wash the prefetched lines out of
/// L1 before they are probed.
pub(crate) const BATCH_CHUNK: usize = 32;

/// A key prepared for probing: hashing is done exactly once here, so the
/// batched paths can amortize it across a whole chunk before touching any
/// set memory.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct PreparedKey {
    /// The user key.
    pub key: u64,
    /// Encoded key word (sentinel-free; see [`Geometry::encode_key`]).
    pub ik: u64,
    /// Non-zero fingerprint (only WFSC stores it, but it is one `mix64`
    /// to derive, so preparing it unconditionally keeps one code path).
    pub fp: u64,
    /// Set index.
    pub set: usize,
}

/// The victim a [`SetEngine::choose_victim`] scan picked.
#[derive(Debug, Clone, Copy)]
pub(crate) struct VictimChoice {
    /// Way index within the set.
    pub way: usize,
    /// Snapshot of that way's claim-guard word (whatever word the
    /// variant's claim CAS races on: WFA the key word, WFSC the
    /// fingerprint, KW-LS the plain key).
    pub guard: u64,
}

/// Geometry + policy + logical clock — the state every variant shares —
/// plus the probe / touch / victim logic over it.
///
/// The engine also owns the *lifetime activity flags*: whether any put so
/// far carried a TTL or a non-unit weight. Until a flag flips, the
/// corresponding checks (life-word loads on probes, weight-repair scans
/// on puts) are skipped entirely, so a cache that never sees
/// [`EntryOpts`] runs the exact pre-lifetime code path (DESIGN.md
/// §Expiration: "bit-identical when no TTLs are set").
pub(crate) struct SetEngine {
    geo: Geometry,
    policy: Policy,
    clock: LogicalClock,
    /// Any put so far carried a TTL.
    ttl_active: AtomicBool,
    /// Any put so far carried a weight != 1.
    weight_active: AtomicBool,
    /// Rotating start position for the incremental expiry sweep.
    sweep_cursor: AtomicUsize,
}

impl SetEngine {
    /// An engine for (at least) `capacity` slots in sets of `ways`.
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        assert!(ways <= MAX_WAYS, "ways must be <= {MAX_WAYS}");
        Self {
            geo: Geometry::new(capacity, ways),
            policy,
            clock: LogicalClock::new(),
            ttl_active: AtomicBool::new(false),
            weight_active: AtomicBool::new(false),
            sweep_cursor: AtomicUsize::new(0),
        }
    }

    /// Record which lifetime dimensions `opts` activates (latching —
    /// once a cache has seen a TTL or a weight it keeps checking them).
    #[inline]
    pub fn note_opts(&self, opts: &EntryOpts) {
        if opts.ttl.is_some() && !self.ttl_active.load(Ordering::Relaxed) {
            self.ttl_active.store(true, Ordering::Relaxed);
        }
        if opts.weight != 1 && !self.weight_active.load(Ordering::Relaxed) {
            self.weight_active.store(true, Ordering::Relaxed);
        }
    }

    /// Has any put carried a TTL? Gates every expiry check.
    #[inline]
    pub fn ttl_active(&self) -> bool {
        self.ttl_active.load(Ordering::Relaxed)
    }

    /// Has any put carried a non-unit weight? Gates the weight repair.
    #[inline]
    pub fn weight_active(&self) -> bool {
        self.weight_active.load(Ordering::Relaxed)
    }

    /// Per-set weight budget. Capacity is interpreted as the total
    /// *weight* budget, so each set's share is its way count — with unit
    /// weights the bound degenerates to "at most k entries", exactly the
    /// pre-lifetime semantics (DESIGN.md §Weighted capacity).
    #[inline]
    pub fn set_budget(&self) -> u64 {
        self.geo.ways() as u64
    }

    /// Coarse wall-clock for expiry checks: the shared millisecond clock
    /// when TTLs are active, 0 (against which nothing is expired, since
    /// every check is also gated on [`SetEngine::ttl_active`]) otherwise.
    #[inline]
    pub fn expiry_now(&self) -> u64 {
        if self.ttl_active() {
            lifetime::now_ms()
        } else {
            0
        }
    }

    /// Hand out the rotating start set for an incremental sweep of
    /// `max_sets` sets; consecutive calls cover the whole cache.
    #[inline]
    pub fn sweep_start(&self, max_sets: usize) -> usize {
        self.sweep_cursor.fetch_add(max_sets, Ordering::Relaxed) % self.geo.num_sets()
    }

    /// The rounded geometry.
    #[inline]
    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    /// The eviction policy.
    #[inline]
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Advance the logical clock (one tick per cache operation).
    #[inline]
    pub fn tick(&self) -> u64 {
        self.clock.tick()
    }

    /// Read the logical clock without advancing it.
    #[inline]
    pub fn now(&self) -> u64 {
        self.clock.now()
    }

    /// Hash a key once into everything a probe needs.
    #[inline]
    pub fn prepare(&self, key: u64) -> PreparedKey {
        PreparedKey {
            key,
            ik: Geometry::encode_key(key),
            fp: hash::fingerprint(key),
            set: self.geo.set_of(key),
        }
    }

    /// The probe loop shared by every variant's `get`: scan the k ways and
    /// on a candidate match read the value, then *re-validate* the match so
    /// a mid-replace (torn) read is detected and skipped. For KW-LS the
    /// re-validation is trivially true (the read lock excludes writers) and
    /// folds away after inlining.
    ///
    /// `expired` is the lazy-expiration filter: a way that matches but has
    /// outlived its TTL is treated as a miss, so an expired key is never
    /// returned. Variants gate the life-word load behind
    /// [`SetEngine::ttl_active`] and pass `|_| false` until a TTL exists,
    /// keeping the TTL-free probe identical to the pre-lifetime one.
    #[inline]
    pub fn probe_get(
        &self,
        k: usize,
        matches: impl Fn(usize) -> bool,
        expired: impl Fn(usize) -> bool,
        read_value: impl Fn(usize) -> u64,
    ) -> Option<(usize, u64)> {
        for i in 0..k {
            if matches(i) {
                if expired(i) {
                    continue;
                }
                let value = read_value(i);
                if matches(i) {
                    return Some((i, value));
                }
            }
        }
        None
    }

    /// Pass-1 scan of a put: the way already holding this key, if any.
    #[inline]
    pub fn find_match(&self, k: usize, matches: impl Fn(usize) -> bool) -> Option<usize> {
        (0..k).find(|&i| matches(i))
    }

    /// Apply the policy's on-hit metadata update with the cheapest atomic
    /// op that implements it. A lost race here only blurs the recency /
    /// frequency signal by one access — the same semantics as the paper's
    /// non-synchronized Java counter updates.
    #[inline]
    pub fn touch_atomic(&self, meta: &AtomicU64, now: u64) {
        match self.policy {
            Policy::Lru => meta.store(now, Ordering::Relaxed),
            Policy::Lfu => {
                meta.fetch_add(1, Ordering::Relaxed);
            }
            Policy::Hyperbolic => {
                let old = meta.load(Ordering::Relaxed);
                let new = self.policy.on_hit_meta(old, now);
                // Single *strong* CAS attempt; on contention we drop the
                // update. Strong so the uncontended (and single-threaded)
                // path never fails spuriously on LL/SC targets — the
                // atomic/plain touch-flavour parity depends on it.
                let _ = meta.compare_exchange(old, new, Ordering::Relaxed, Ordering::Relaxed);
            }
            Policy::Fifo | Policy::Random => {}
        }
    }

    /// On-hit metadata update for plain (lock-protected) storage.
    #[inline]
    pub fn touch_plain(&self, meta: &mut u64, now: u64) {
        *meta = self.policy.on_hit_meta(*meta, now);
    }

    /// Metadata word for a fresh insert.
    #[inline]
    pub fn initial_meta(&self, now: u64) -> u64 {
        self.policy.initial_meta(now)
    }

    /// Does a hit need a metadata write at all?
    #[inline]
    pub fn updates_on_hit(&self) -> bool {
        self.policy.updates_on_hit()
    }

    /// Victim selection over an explicit metadata snapshot.
    #[inline]
    pub fn select_victim(&self, metas: &[u64], now: u64) -> usize {
        with_thread_rng(|rng| self.policy.select_victim(metas, now, rng))
    }

    /// Snapshot a full set through `snap` — per way, the claim-guard word,
    /// the metadata and whether the way holds an *expired* entry — and
    /// pick the victim. An expired line is the victim of first resort
    /// (reclaiming it costs the hit ratio nothing — lazy expiration,
    /// DESIGN.md §Expiration); otherwise the policy chooses. Variants
    /// report a way that must not be chosen (mid-publish) by returning
    /// `u64::MAX` metadata, which only loses to other `u64::MAX` ways and
    /// disables the expired shortcut for that way.
    #[inline]
    pub fn choose_victim(
        &self,
        k: usize,
        now: u64,
        snap: impl Fn(usize) -> (u64, u64, bool),
    ) -> VictimChoice {
        let mut guards = [0u64; MAX_WAYS];
        let mut metas = [u64::MAX; MAX_WAYS];
        for i in 0..k {
            let (guard, meta, expired) = snap(i);
            if expired && meta != u64::MAX {
                return VictimChoice { way: i, guard };
            }
            guards[i] = guard;
            metas[i] = meta;
        }
        let way = self.select_victim(&metas[..k], now);
        VictimChoice { way, guard: guards[way] }
    }

    /// Shared `peek_victim` (the advisory preview used by TinyLFU
    /// admission). `load_key` must yield the *effective* key word of a
    /// way: [`EMPTY`] when the way is free, [`RESERVED`] when it is
    /// mid-publish, the encoded key otherwise; `load_life` the way's life
    /// word (only consulted while TTLs are active). Returns `None` when
    /// the set still has room (no eviction needed) or the victim is
    /// mid-publish.
    ///
    /// The victim-preview **contract** every variant upholds (pinned by
    /// `rust/tests/peek_victim.rs` and relied on by
    /// [`crate::tinylfu::TlfuCache`]):
    ///
    /// * a returned key was resident in the probed key's set at snapshot
    ///   time — never a sentinel, never a made-up key;
    /// * `None` ⇒ the insert needs no eviction *or* the set is mid-churn
    ///   (callers must treat `None` as "admit") — an *expired* resident
    ///   line counts as free room, since displacing it costs nothing;
    /// * under concurrency the preview is *advisory*: the put that follows
    ///   may evict a different way. Admission is a probabilistic filter,
    ///   so acting on a stale preview mis-scores at most one insert —
    ///   safety is untouched (DESIGN.md §Admission).
    pub fn peek_victim_with(
        &self,
        k: usize,
        load_key: impl Fn(usize) -> u64,
        load_meta: impl Fn(usize) -> u64,
        load_life: impl Fn(usize) -> u64,
    ) -> Option<u64> {
        let now = self.now();
        let ttl_active = self.ttl_active();
        let now_ms = self.expiry_now();
        let mut keys = [0u64; MAX_WAYS];
        let mut metas = [0u64; MAX_WAYS];
        for i in 0..k {
            keys[i] = load_key(i);
            if keys[i] == EMPTY {
                return None; // room available, no eviction needed
            }
            if keys[i] != RESERVED && ttl_active && lifetime::is_expired(load_life(i), now_ms) {
                return None; // expired line: the insert evicts a dead entry
            }
            metas[i] = if keys[i] == RESERVED { u64::MAX } else { load_meta(i) };
        }
        let vi = self.select_victim(&metas[..k], now);
        (keys[vi] != RESERVED).then(|| Geometry::decode_key(keys[vi]))
    }

    /// Drive a batched pass: prepare (hash) a chunk of items up front,
    /// issue a software prefetch for each item's set line, then run `op`
    /// per item in input order. Preparing a whole chunk before the first
    /// probe amortizes hashing and overlaps the set lines' memory latency
    /// with useful work instead of stalling on each miss in turn.
    #[inline]
    pub fn for_batch<I>(
        &self,
        items: &[I],
        key_of: impl Fn(&I) -> u64,
        prefetch_set: impl Fn(usize),
        mut op: impl FnMut(PreparedKey, &I),
    ) {
        let mut prepared = [PreparedKey::default(); BATCH_CHUNK];
        for chunk in items.chunks(BATCH_CHUNK) {
            for (i, item) in chunk.iter().enumerate() {
                let pk = self.prepare(key_of(item));
                prefetch_set(pk.set);
                prepared[i] = pk;
            }
            for (i, item) in chunk.iter().enumerate() {
                op(prepared[i], item);
            }
        }
    }
}

/// Best-effort software prefetch of the cache line holding `ptr` into all
/// cache levels. A no-op on targets without a stable prefetch intrinsic —
/// the batched path still wins there from amortized hashing and fewer
/// virtual calls.
#[inline(always)]
pub(crate) fn prefetch_read<T>(ptr: *const T) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        // SAFETY: prefetch is a pure hint; it cannot fault on any address.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(ptr as *const i8) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = ptr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(capacity: usize, ways: usize, policy: Policy) -> SetEngine {
        SetEngine::new(capacity, ways, policy)
    }

    #[test]
    fn prepare_is_consistent_with_geometry_and_hashing() {
        let e = engine(1024, 8, Policy::Lru);
        for key in 0..1000u64 {
            let pk = e.prepare(key);
            assert_eq!(pk.key, key);
            assert_eq!(pk.ik, Geometry::encode_key(key));
            assert_eq!(pk.fp, hash::fingerprint(key));
            assert_eq!(pk.set, e.geometry().set_of(key));
        }
    }

    #[test]
    fn probe_get_revalidates() {
        let e = engine(64, 4, Policy::Lru);
        // A match that disappears between value read and re-validation
        // must be skipped (simulated with a counter-driven closure).
        use std::cell::Cell;
        let calls = Cell::new(0u32);
        let hit = e.probe_get(
            4,
            |i| {
                if i == 1 {
                    calls.set(calls.get() + 1);
                    calls.get() == 1 // first check passes, re-check fails
                } else {
                    false
                }
            },
            |_| false,
            |_| 42,
        );
        assert_eq!(hit, None);
        // A stable match is returned with its way index.
        let hit = e.probe_get(4, |i| i == 2, |_| false, |i| (i as u64) * 10);
        assert_eq!(hit, Some((2, 20)));
        // An expired match is a miss, even though the key matches.
        let hit = e.probe_get(4, |i| i == 2, |i| i == 2, |i| (i as u64) * 10);
        assert_eq!(hit, None);
    }

    #[test]
    fn choose_victim_avoids_max_meta_ways() {
        let e = engine(64, 4, Policy::Lru);
        let metas = [5u64, u64::MAX, 3, 9];
        let guards = [100u64, 101, 102, 103];
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], false));
        assert_eq!(choice.way, 2);
        assert_eq!(choice.guard, 102);
    }

    #[test]
    fn choose_victim_prefers_expired_lines() {
        let e = engine(64, 4, Policy::Lru);
        let metas = [5u64, 7, 3, 9];
        let guards = [100u64, 101, 102, 103];
        // Way 3 is expired: it wins over the LRU minimum (way 2).
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], i == 3));
        assert_eq!(choice.way, 3);
        assert_eq!(choice.guard, 103);
        // A mid-publish way (meta MAX) is never taken via the expired
        // shortcut.
        let metas = [5u64, u64::MAX, 3, 9];
        let choice = e.choose_victim(4, 50, |i| (guards[i], metas[i], i == 1));
        assert_eq!(choice.way, 2);
    }

    #[test]
    fn lifetime_flags_latch_and_gate() {
        use crate::lifetime::EntryOpts;
        use std::time::Duration;
        let e = engine(64, 4, Policy::Lru);
        assert!(!e.ttl_active());
        assert!(!e.weight_active());
        assert_eq!(e.expiry_now(), 0, "TTL-free caches never read the clock");
        e.note_opts(&EntryOpts::default());
        assert!(!e.ttl_active() && !e.weight_active(), "plain opts must not latch");
        e.note_opts(&EntryOpts::ttl(Duration::from_millis(1)));
        assert!(e.ttl_active());
        e.note_opts(&EntryOpts::weight(3));
        assert!(e.weight_active());
        assert_eq!(e.set_budget(), 4);
    }

    #[test]
    fn sweep_start_rotates_over_all_sets() {
        let e = engine(64, 4, Policy::Lru); // 16 sets
        let n = e.geometry().num_sets();
        let mut covered = vec![false; n];
        for _ in 0..n {
            let start = e.sweep_start(1);
            covered[start] = true;
        }
        assert!(covered.iter().all(|&c| c), "cursor must cover every set");
    }

    #[test]
    fn peek_victim_with_contract() {
        let e = engine(64, 4, Policy::Lru);
        let immortal = crate::lifetime::immortal_unit();
        // Any empty way -> no eviction needed.
        let keys =
            [Geometry::encode_key(1), EMPTY, Geometry::encode_key(3), Geometry::encode_key(4)];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |_| 0, |_| immortal), None);
        // Full set -> the policy minimum's decoded key.
        let keys = [10u64, 11, 12, 13].map(Geometry::encode_key);
        let metas = [50u64, 10, 90, 30];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |_| immortal), Some(11));
        // Mid-publish victim -> None.
        let keys = [
            Geometry::encode_key(10),
            RESERVED,
            Geometry::encode_key(12),
            Geometry::encode_key(13),
        ];
        let metas = [50u64, 0, 90, 30];
        // RESERVED way is masked to u64::MAX, so the victim is way 3 (30).
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |_| immortal), Some(13));
    }

    #[test]
    fn peek_victim_treats_expired_lines_as_free_room() {
        use crate::lifetime::{life_of, EntryOpts};
        use std::time::Duration;
        let e = engine(64, 4, Policy::Lru);
        e.note_opts(&EntryOpts::ttl(Duration::ZERO)); // activate TTLs
        let keys = [10u64, 11, 12, 13].map(Geometry::encode_key);
        let metas = [50u64, 10, 90, 30];
        let now = crate::lifetime::now_ms();
        let dead = life_of(&EntryOpts::ttl(Duration::ZERO), now);
        let live = life_of(&EntryOpts::default(), now);
        // Way 2 is expired: the preview reports "no live victim needed".
        let lives = [live, live, dead, live];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |i| lives[i]), None);
        // All live: back to the policy minimum.
        let lives = [live; 4];
        assert_eq!(e.peek_victim_with(4, |i| keys[i], |i| metas[i], |i| lives[i]), Some(11));
    }

    #[test]
    fn atomic_and_plain_touch_flavours_agree_for_every_policy() {
        // The engine has two touch flavours — atomic (WFA/WFSC) and plain
        // (KW-LS) — that must encode the *same* policy semantics: driven
        // single-threaded over a scripted access sequence they must
        // produce identical metadata and identical victim choices. This
        // pins the refactor-safety of engine.rs: a change to one flavour
        // that forgets the other diverges the k-way variants' behaviour.
        use crate::util::rng::Rng;
        let k = 8usize;
        // (way, logical time) hit script; strictly increasing times.
        let script: [(usize, u64); 12] = [
            (0, 100),
            (1, 101),
            (0, 102),
            (3, 110),
            (5, 111),
            (0, 112),
            (6, 120),
            (3, 121),
            (2, 130),
            (7, 131),
            (0, 140),
            (4, 141),
        ];
        for policy in Policy::ALL {
            let e = engine(64, k, policy);
            let atomic: Vec<AtomicU64> =
                (0..k).map(|i| AtomicU64::new(e.initial_meta(10 * i as u64))).collect();
            let mut plain: Vec<u64> =
                (0..k).map(|i| e.initial_meta(10 * i as u64)).collect();
            for &(way, now) in &script {
                e.touch_atomic(&atomic[way], now);
                e.touch_plain(&mut plain[way], now);
            }
            let metas_atomic: Vec<u64> =
                atomic.iter().map(|m| m.load(Ordering::Relaxed)).collect();
            assert_eq!(metas_atomic, plain, "{policy:?}: metadata flavours diverged");
            // Victim selection over the two flavours' metadata must agree
            // (identically-seeded RNGs make Random comparable too).
            let now = 200;
            let va = policy.select_victim(&metas_atomic, now, &mut Rng::new(99));
            let vp = policy.select_victim(&plain, now, &mut Rng::new(99));
            assert_eq!(va, vp, "{policy:?}: victim choice diverged");
        }
    }

    #[test]
    fn for_batch_visits_every_item_in_order_across_chunks() {
        let e = engine(4096, 8, Policy::Lru);
        let keys: Vec<u64> = (0..(3 * BATCH_CHUNK as u64 + 7)).collect();
        let mut seen = Vec::new();
        e.for_batch(
            &keys,
            |&k| k,
            |set| assert!(set < e.geometry().num_sets()),
            |pk, &orig| {
                assert_eq!(pk.key, orig);
                seen.push(pk.key);
            },
        );
        assert_eq!(seen, keys);
    }

    #[test]
    fn prefetch_is_safe_on_any_pointer() {
        let v = [1u64, 2, 3];
        prefetch_read(&v[0]);
        prefetch_read(std::ptr::null::<u64>());
    }
}
