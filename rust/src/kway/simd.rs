//! Vectorized fingerprint probing for KW-WFSC.
//!
//! A WFSC probe first scans the set's `ways` fingerprint words for
//! `fingerprint(key)`; only matching ways pay for the key/value loads.
//! This module turns that scan into a single pass that compares every way
//! at once and returns a bitmask of candidate ways (bit `i` set ⇔ way `i`
//! equals the needle). Four flavours are always compiled:
//!
//! * [`ProbeKind::Scalar`] — the plain per-word loop (the pre-SIMD code).
//! * [`ProbeKind::Swar`] — portable "SIMD within a register": per-word
//!   XOR with the needle, then a branch-free is-zero reduction. No
//!   target-feature requirements; the default on non-x86_64.
//! * [`ProbeKind::Sse2`] — 2 ways per `__m128i`. SSE2 is part of the
//!   x86_64 baseline, so this needs no runtime detection. SSE2 has no
//!   64-bit compare, so equality is built from `cmpeq_epi32` + a lane
//!   swap + AND (both 32-bit halves must match).
//! * [`ProbeKind::Avx2`] — 4 ways per `__m256i` via `cmpeq_epi64`,
//!   behind cached `is_x86_feature_detected!("avx2")`.
//!
//! [`match_mask`] dispatches to the best available flavour; the `simd`
//! cargo feature (on by default) only controls *dispatch* — with it
//! disabled every probe takes the scalar loop, which is what the
//! differential tests compare the vector flavours against.
//!
//! # Safety argument: relaxed loads and vector loads over atomics
//!
//! The fingerprint array is `[AtomicU64]` and is written concurrently.
//! The mask produced here is a **prefilter, not a truth**: every caller
//! (see `engine::SetEngine::probe_get_masked` and the wfsc put passes)
//! re-reads each candidate way through the normal atomic protocol (key
//! word Acquire, value re-validation) before acting, and stale *misses*
//! are acceptable by the same argument as the scalar scan — a concurrent
//! writer racing a reader may always be ordered after it. Therefore:
//!
//! * The scalar and SWAR flavours use `Relaxed` atomic loads: no
//!   happens-before edge is needed from a prefilter.
//! * The SSE2/AVX2 flavours read the words with plain vector loads
//!   (`_mm_load_si128`/`_mm256_loadu_si256`) over the atomic storage.
//!   Each 8-byte lane is naturally aligned, and on x86_64 an aligned
//!   8-byte load is single-copy atomic at the hardware level, so a lane
//!   observes some value actually stored there — never a torn mix.
//!   Rust's memory model does not bless mixed-size/non-atomic access to
//!   atomics, so this is the one deliberate, documented divergence —
//!   confined to these two `unsafe` functions, justified by (a) the
//!   hardware guarantee above and (b) the fact that every lane that
//!   matters is re-verified through a genuine atomic load before use.
//!   The differential test in `tests/hotpath.rs` pins all flavours to
//!   identical results on quiescent sets, including the `MIGRATING`
//!   sentinel and colliding fingerprints.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// Which probe kernel to use for fingerprint scans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeKind {
    /// Plain per-word scalar loop.
    Scalar,
    /// Portable branch-free SWAR reduction.
    Swar,
    /// SSE2, 2 ways per vector (x86_64 baseline).
    Sse2,
    /// AVX2, 4 ways per vector (runtime-detected).
    Avx2,
}

impl ProbeKind {
    /// All flavours supported on the running CPU, for tests and benches.
    pub fn available() -> Vec<ProbeKind> {
        let mut v = vec![ProbeKind::Scalar, ProbeKind::Swar];
        #[cfg(target_arch = "x86_64")]
        {
            v.push(ProbeKind::Sse2);
            if avx2_available() {
                v.push(ProbeKind::Avx2);
            }
        }
        v
    }

    /// Canonical label for bench output.
    pub fn name(&self) -> &'static str {
        match self {
            ProbeKind::Scalar => "scalar",
            ProbeKind::Swar => "swar",
            ProbeKind::Sse2 => "sse2",
            ProbeKind::Avx2 => "avx2",
        }
    }

    /// Parse a bench-flag string.
    pub fn parse(s: &str) -> Option<ProbeKind> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(ProbeKind::Scalar),
            "swar" => Some(ProbeKind::Swar),
            "sse2" => Some(ProbeKind::Sse2),
            "avx2" => Some(ProbeKind::Avx2),
            _ => None,
        }
    }
}

// Encoding of the FORCED override: 0 = auto, else ProbeKind as u8 + 1.
const AUTO: u8 = 0;
static FORCED: AtomicU8 = AtomicU8::new(AUTO);

/// Force every subsequent [`match_mask`] call process-wide onto one
/// flavour (`None` restores auto-detection). Bench/test hook: the global
/// is process-wide, so under `cargo test`'s threaded runner only one test
/// function may use it (see `tests/hotpath.rs`).
pub fn force(kind: Option<ProbeKind>) {
    let code = match kind {
        None => AUTO,
        Some(ProbeKind::Scalar) => 1,
        Some(ProbeKind::Swar) => 2,
        Some(ProbeKind::Sse2) => 3,
        Some(ProbeKind::Avx2) => 4,
    };
    FORCED.store(code, Ordering::Relaxed);
}

/// The flavour [`match_mask`] currently dispatches to.
pub fn active_kind() -> ProbeKind {
    match FORCED.load(Ordering::Relaxed) {
        1 => ProbeKind::Scalar,
        2 => ProbeKind::Swar,
        3 => ProbeKind::Sse2,
        4 => ProbeKind::Avx2,
        _ => auto_kind(),
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn auto_kind() -> ProbeKind {
    if avx2_available() {
        ProbeKind::Avx2
    } else {
        ProbeKind::Sse2
    }
}

#[cfg(all(feature = "simd", not(target_arch = "x86_64")))]
#[inline]
fn auto_kind() -> ProbeKind {
    ProbeKind::Swar
}

#[cfg(not(feature = "simd"))]
#[inline]
fn auto_kind() -> ProbeKind {
    ProbeKind::Scalar
}

/// Cached AVX2 runtime detection (0 = unknown, 1 = no, 2 = yes).
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Bitmask of ways in `words` equal to `needle` (bit `i` ⇔ `words[i]`),
/// using the active flavour. `words` is one set's slice of a table array;
/// `u128` covers the engine's `MAX_WAYS = 128`.
#[inline]
pub fn match_mask(words: &[AtomicU64], needle: u64) -> u128 {
    match_mask_kind(active_kind(), words, needle)
}

/// [`match_mask`] pinned to a specific flavour — the entry point the
/// differential tests use so they never touch the process-wide override.
/// Falls back to SWAR if `kind` is not supported on this target.
#[inline]
pub fn match_mask_kind(kind: ProbeKind, words: &[AtomicU64], needle: u64) -> u128 {
    debug_assert!(words.len() <= 128, "mask is u128");
    match kind {
        ProbeKind::Scalar => mask_scalar(words, needle),
        ProbeKind::Swar => mask_swar(words, needle),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is part of the x86_64 baseline.
        ProbeKind::Sse2 => unsafe { mask_sse2(words, needle) },
        #[cfg(target_arch = "x86_64")]
        ProbeKind::Avx2 => {
            if avx2_available() {
                // SAFETY: AVX2 presence just checked.
                unsafe { mask_avx2(words, needle) }
            } else {
                mask_swar(words, needle)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => mask_swar(words, needle),
    }
}

/// The pre-SIMD loop, kept as the reference semantics.
fn mask_scalar(words: &[AtomicU64], needle: u64) -> u128 {
    let mut mask = 0u128;
    for (i, w) in words.iter().enumerate() {
        if w.load(Ordering::Relaxed) == needle {
            mask |= 1u128 << i;
        }
    }
    mask
}

/// Branch-free SWAR: `x == needle` ⇔ `x ^ needle == 0`, and
/// `is_zero(d) = 1 - ((d | -d) >> 63)` — `d | d.wrapping_neg()` has its
/// top bit set for every non-zero `d` and clear only for zero.
fn mask_swar(words: &[AtomicU64], needle: u64) -> u128 {
    let mut mask = 0u128;
    for (i, w) in words.iter().enumerate() {
        let d = w.load(Ordering::Relaxed) ^ needle;
        let nz = (d | d.wrapping_neg()) >> 63; // 1 if d != 0
        mask |= ((nz ^ 1) as u128) << i;
    }
    mask
}

/// SSE2 kernel: 2 ways per 128-bit vector. See the module-level safety
/// argument for why plain vector loads over `[AtomicU64]` are acceptable
/// here.
///
/// # Safety
///
/// Caller must be on x86_64 (SSE2 is baseline there).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn mask_sse2(words: &[AtomicU64], needle: u64) -> u128 {
    use std::arch::x86_64::*;
    let n = _mm_set1_epi64x(needle as i64);
    let mut mask = 0u128;
    let mut i = 0usize;
    while i + 2 <= words.len() {
        let p = words.as_ptr().add(i) as *const __m128i;
        // Table slices are 64B-aligned and sets start at way multiples,
        // so a pair beginning at an even way index is 16B-aligned; probe
        // callers always pass whole sets (even i here), but use loadu to
        // stay correct for arbitrary sub-slices in tests.
        let v = _mm_loadu_si128(p);
        // No _mm_cmpeq_epi64 in SSE2: compare 32-bit halves, then AND
        // each half with its partner (swapped via shuffle 0b10_11_00_01)
        // so a lane is all-ones iff both halves matched.
        let eq32 = _mm_cmpeq_epi32(v, n);
        let both = _mm_and_si128(eq32, _mm_shuffle_epi32(eq32, 0b1011_0001));
        // movemask_pd extracts one bit per 64-bit lane's sign bit.
        let m = _mm_movemask_pd(_mm_castsi128_pd(both)) as u32;
        mask |= (m as u128) << i;
        i += 2;
    }
    if i < words.len() {
        mask |= mask_swar(&words[i..], needle) << i;
    }
    mask
}

/// AVX2 kernel: 4 ways per 256-bit vector.
///
/// # Safety
///
/// Caller must have verified AVX2 support at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mask_avx2(words: &[AtomicU64], needle: u64) -> u128 {
    use std::arch::x86_64::*;
    let n = _mm256_set1_epi64x(needle as i64);
    let mut mask = 0u128;
    let mut i = 0usize;
    while i + 4 <= words.len() {
        let p = words.as_ptr().add(i) as *const __m256i;
        let v = _mm256_loadu_si256(p);
        let eq = _mm256_cmpeq_epi64(v, n);
        let m = _mm256_movemask_pd(_mm256_castsi256_pd(eq)) as u32;
        mask |= (m as u128) << i;
        i += 4;
    }
    if i < words.len() {
        mask |= mask_swar(&words[i..], needle) << i;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn atomics(vals: &[u64]) -> Vec<AtomicU64> {
        vals.iter().map(|&v| AtomicU64::new(v)).collect()
    }

    #[test]
    fn scalar_reference_semantics() {
        let ws = atomics(&[5, 0, 5, 7]);
        assert_eq!(mask_scalar(&ws, 5), 0b0101);
        assert_eq!(mask_scalar(&ws, 0), 0b0010);
        assert_eq!(mask_scalar(&ws, 9), 0);
        assert_eq!(mask_scalar(&[], 5), 0);
    }

    #[test]
    fn all_kinds_agree_on_edge_values() {
        // Sentinels and extremes: EMPTY (0), MIGRATING (2), odd real
        // fingerprints, u64::MAX, and values differing in only one half
        // (the SSE2 32-bit-halves trap).
        let vals =
            [0u64, 2, 1, u64::MAX, 0xFFFF_FFFF_0000_0000, 0x0000_0000_FFFF_FFFF, 5, 5, 6, 0];
        let ws = atomics(&vals);
        for needle in [0u64, 1, 2, 5, u64::MAX, 0xFFFF_FFFF_0000_0000, 99] {
            let want = mask_scalar(&ws, needle);
            for kind in ProbeKind::available() {
                assert_eq!(
                    match_mask_kind(kind, &ws, needle),
                    want,
                    "kind {} needle {needle:#x}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn all_kinds_agree_on_random_sets() {
        let mut rng = crate::util::rng::Rng::new(0x51D_77);
        for len in 0..=16usize {
            for _ in 0..200 {
                let vals: Vec<u64> = (0..len)
                    .map(|_| if rng.next_u64() % 3 == 0 { 5 } else { rng.next_u64() })
                    .collect();
                let ws = atomics(&vals);
                let needle = if rng.next_u64() % 2 == 0 { 5 } else { rng.next_u64() };
                let want = mask_scalar(&ws, needle);
                for kind in ProbeKind::available() {
                    let got = match_mask_kind(kind, &ws, needle);
                    assert_eq!(got, want, "len {len} {}", kind.name());
                }
            }
        }
    }

    #[test]
    fn max_ways_mask_fits() {
        // 128 ways exercises the top bit of the u128 mask.
        let vals: Vec<u64> = (0..128).map(|i| if i % 7 == 0 { 42 } else { i }).collect();
        let ws = atomics(&vals);
        let want = mask_scalar(&ws, 42);
        assert_ne!(want & (1u128 << 126), 0, "way 126 is a multiple of 7");
        for kind in ProbeKind::available() {
            assert_eq!(match_mask_kind(kind, &ws, 42), want, "{}", kind.name());
        }
    }

    #[test]
    fn kind_parse_and_names_roundtrip() {
        for kind in
            [ProbeKind::Scalar, ProbeKind::Swar, ProbeKind::Sse2, ProbeKind::Avx2]
        {
            assert_eq!(ProbeKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ProbeKind::parse("bogus"), None);
    }
}
