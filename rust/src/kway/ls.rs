//! KW-LS — K-Way cache, Lock per Set (paper Algorithms 7–9).
//!
//! Each set carries a [`StampedLock`] and *plain* (non-atomic) entry
//! storage. Operations take the read lock to scan; to mutate metadata or
//! contents they attempt the `tryConvertToWriteLock` upgrade exactly as the
//! paper does — and, exactly as the paper does, they *give up* when the
//! upgrade fails (Alg. 8 lines 8–10, Alg. 9 lines 8–10): a hit whose
//! upgrade fails still returns the value but skips the metadata update, and
//! a put whose upgrade fails drops the insert. Both are benign for a cache
//! and keep the lock protocol deadlock-free without lock re-acquisition.
//!
//! Each set is cache-line padded so sets stay as independent in memory as
//! they are logically — the paper's independence argument made physical.

use super::geometry::Geometry;
use super::stamped::StampedLock;
use super::with_thread_rng;
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;

const EMPTY: u64 = 0;

/// One entry: encoded key word (0 = empty), value, policy metadata.
#[derive(Clone, Copy, Default)]
struct Entry {
    key: u64,
    value: u64,
    meta: u64,
}

/// A set: lock + plain storage.
struct LsSet {
    lock: StampedLock,
    entries: UnsafeCell<Box<[Entry]>>,
}

// SAFETY: `entries` is only accessed while holding `lock` in the
// appropriate mode (shared for reads, exclusive for writes).
unsafe impl Sync for LsSet {}
unsafe impl Send for LsSet {}

impl LsSet {
    fn new(ways: usize) -> Self {
        Self {
            lock: StampedLock::new(),
            entries: UnsafeCell::new(vec![Entry::default(); ways].into_boxed_slice()),
        }
    }
}

/// Lock-per-set k-way cache.
pub struct KwLs {
    geo: Geometry,
    policy: Policy,
    clock: LogicalClock,
    sets: Box<[CachePadded<LsSet>]>,
}

impl KwLs {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        assert!(ways <= super::wfa::MAX_WAYS, "ways must be <= {}", super::wfa::MAX_WAYS);
        let geo = Geometry::new(capacity, ways);
        let sets = (0..geo.num_sets())
            .map(|_| CachePadded::new(LsSet::new(geo.ways())))
            .collect();
        Self { geo, policy, clock: LogicalClock::new(), sets }
    }

    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }
}

impl Cache for KwLs {
    fn get(&self, key: u64) -> Option<u64> {
        let ik = Geometry::encode_key(key);
        let now = self.clock.tick();
        let set = &self.sets[self.geo.set_of(key)];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        for i in 0..entries.len() {
            if entries[i].key == ik {
                let value = entries[i].value;
                if !self.policy.updates_on_hit() {
                    set.lock.unlock_read();
                    return Some(value);
                }
                // Alg. 8: upgrade to update the counter; on failure return
                // the value without the metadata update.
                if set.lock.try_convert_to_write() {
                    // SAFETY: write lock held.
                    let entries = unsafe { &mut *set.entries.get() };
                    entries[i].meta = self.policy.on_hit_meta(entries[i].meta, now);
                    set.lock.unlock_write();
                } else {
                    set.lock.unlock_read();
                }
                return Some(value);
            }
        }
        set.lock.unlock_read();
        None
    }

    fn put(&self, key: u64, value: u64) {
        let ik = Geometry::encode_key(key);
        let now = self.clock.tick();
        let set = &self.sets[self.geo.set_of(key)];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };

        // Pass 1 (Alg. 9 lines 4–13): overwrite an existing entry.
        for i in 0..entries.len() {
            if entries[i].key == ik {
                if set.lock.try_convert_to_write() {
                    // SAFETY: write lock held.
                    let entries = unsafe { &mut *set.entries.get() };
                    entries[i].value = value;
                    entries[i].meta = self.policy.on_hit_meta(entries[i].meta, now);
                    set.lock.unlock_write();
                } else {
                    // Paper: give up when the upgrade fails.
                    set.lock.unlock_read();
                }
                return;
            }
        }

        // Miss path (Alg. 9 lines 15–27): upgrade, then fill an empty way
        // or replace the policy victim.
        if !set.lock.try_convert_to_write() {
            set.lock.unlock_read();
            return;
        }
        // SAFETY: write lock held.
        let entries = unsafe { &mut *set.entries.get() };
        let target = match entries.iter().position(|e| e.key == EMPTY) {
            Some(i) => i,
            None => {
                let mut metas = [0u64; super::wfa::MAX_WAYS];
                for (i, e) in entries.iter().enumerate() {
                    metas[i] = e.meta;
                }
                with_thread_rng(|rng| {
                    self.policy.select_victim(&metas[..entries.len()], now, rng)
                })
            }
        };
        entries[target] =
            Entry { key: ik, value, meta: self.policy.initial_meta(now) };
        set.lock.unlock_write();
    }

    fn capacity(&self) -> usize {
        self.geo.capacity()
    }

    fn len(&self) -> usize {
        let mut n = 0;
        for set in self.sets.iter() {
            set.lock.read_lock();
            // SAFETY: read lock held.
            let entries = unsafe { &*set.entries.get() };
            n += entries.iter().filter(|e| e.key != EMPTY).count();
            set.lock.unlock_read();
        }
        n
    }

    fn name(&self) -> &'static str {
        "KW-LS"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let set = &self.sets[self.geo.set_of(key)];
        let now = self.clock.now();
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        let result = if entries.iter().any(|e| e.key == EMPTY) {
            None
        } else {
            let mut metas = [0u64; super::wfa::MAX_WAYS];
            for (i, e) in entries.iter().enumerate() {
                metas[i] = e.meta;
            }
            let vi = with_thread_rng(|rng| {
                self.policy.select_victim(&metas[..entries.len()], now, rng)
            });
            Some(Geometry::decode_key(entries[vi].key))
        };
        set.lock.unlock_read();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwLs::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwLs::new(64, 4, Policy::Hyperbolic);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwLs::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwLs::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key ^ 0xABCD);
                assert_eq!(c.get(key), Some(key ^ 0xABCD), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwLs::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(200 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn property_single_thread_model() {
        // Single-threaded: upgrades always succeed, so KW-LS behaves as an
        // exact sequential k-way cache against the model.
        check("ls-model", 20, |rng| {
            let c = KwLs::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
