//! KW-LS — K-Way cache, Lock per Set (paper Algorithms 7–9).
//!
//! Each set carries a [`StampedLock`] and *plain* (non-atomic) entry
//! storage. Operations take the read lock to scan; to mutate metadata or
//! contents they attempt the `tryConvertToWriteLock` upgrade exactly as the
//! paper does — and, exactly as the paper does, they *give up* when the
//! upgrade fails (Alg. 8 lines 8–10, Alg. 9 lines 8–10): a hit whose
//! upgrade fails still returns the value but skips the metadata update, and
//! a put whose upgrade fails drops the insert. Both are benign for a cache
//! and keep the lock protocol deadlock-free without lock re-acquisition.
//!
//! Each set is cache-line padded so sets stay as independent in memory as
//! they are logically — the paper's independence argument made physical.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the locked plain storage and the upgrade protocol.

use super::engine::{self, PreparedKey, SetEngine};
use super::geometry::{Geometry, EMPTY};
use super::stamped::StampedLock;
use crate::policy::Policy;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;

/// One entry: encoded key word (0 = empty), value, policy metadata.
#[derive(Clone, Copy, Default)]
struct Entry {
    key: u64,
    value: u64,
    meta: u64,
}

/// A set: lock + plain storage.
struct LsSet {
    lock: StampedLock,
    entries: UnsafeCell<Box<[Entry]>>,
}

// SAFETY: `entries` is only accessed while holding `lock` in the
// appropriate mode (shared for reads, exclusive for writes).
unsafe impl Sync for LsSet {}
unsafe impl Send for LsSet {}

impl LsSet {
    fn new(ways: usize) -> Self {
        Self {
            lock: StampedLock::new(),
            entries: UnsafeCell::new(vec![Entry::default(); ways].into_boxed_slice()),
        }
    }
}

/// Lock-per-set k-way cache.
pub struct KwLs {
    engine: SetEngine,
    sets: Box<[CachePadded<LsSet>]>,
}

impl KwLs {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let engine = SetEngine::new(capacity, ways, policy);
        let sets = (0..engine.geometry().num_sets())
            .map(|_| CachePadded::new(LsSet::new(engine.geometry().ways())))
            .collect();
        Self { engine, sets }
    }

    pub fn geometry(&self) -> Geometry {
        self.engine.geometry()
    }

    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths).
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let set = &self.sets[pk.set];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        let hit = self.engine.probe_get(
            entries.len(),
            |i| entries[i].key == pk.ik,
            |i| entries[i].value,
        );
        match hit {
            Some((i, value)) => {
                if !self.engine.updates_on_hit() {
                    set.lock.unlock_read();
                    return Some(value);
                }
                // Alg. 8: upgrade to update the counter; on failure return
                // the value without the metadata update.
                if set.lock.try_convert_to_write() {
                    // SAFETY: write lock held.
                    let entries = unsafe { &mut *set.entries.get() };
                    self.engine.touch_plain(&mut entries[i].meta, now);
                    set.lock.unlock_write();
                } else {
                    set.lock.unlock_read();
                }
                Some(value)
            }
            None => {
                set.lock.unlock_read();
                None
            }
        }
    }

    /// `put` with the hashing already done.
    fn put_prepared(&self, pk: PreparedKey, value: u64) {
        let now = self.engine.tick();
        let set = &self.sets[pk.set];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };

        // Pass 1 (Alg. 9 lines 4–13): overwrite an existing entry.
        if let Some(i) = self.engine.find_match(entries.len(), |i| entries[i].key == pk.ik) {
            if set.lock.try_convert_to_write() {
                // SAFETY: write lock held.
                let entries = unsafe { &mut *set.entries.get() };
                entries[i].value = value;
                self.engine.touch_plain(&mut entries[i].meta, now);
                set.lock.unlock_write();
            } else {
                // Paper: give up when the upgrade fails.
                set.lock.unlock_read();
            }
            return;
        }

        // Miss path (Alg. 9 lines 15–27): upgrade, then fill an empty way
        // or replace the policy victim.
        if !set.lock.try_convert_to_write() {
            set.lock.unlock_read();
            return;
        }
        // SAFETY: write lock held.
        let entries = unsafe { &mut *set.entries.get() };
        let target = match entries.iter().position(|e| e.key == EMPTY) {
            Some(i) => i,
            None => {
                self.engine
                    .choose_victim(entries.len(), now, |i| (entries[i].key, entries[i].meta))
                    .way
            }
        };
        entries[target] = Entry { key: pk.ik, value, meta: self.engine.initial_meta(now) };
        set.lock.unlock_write();
    }
}

impl Cache for KwLs {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(self.engine.prepare(key), value)
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        self.engine.for_batch(
            keys,
            |&key| key,
            // Prefetch the set header (lock word + entries pointer); the
            // entries themselves sit behind one more indirection.
            |set| {
                let header: &LsSet = &self.sets[set];
                engine::prefetch_read(header);
            },
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        self.engine.for_batch(
            items,
            |item| item.0,
            |set| {
                let header: &LsSet = &self.sets[set];
                engine::prefetch_read(header);
            },
            |pk, item| self.put_prepared(pk, item.1),
        );
    }

    fn capacity(&self) -> usize {
        self.engine.geometry().capacity()
    }

    fn len(&self) -> usize {
        let mut n = 0;
        for set in self.sets.iter() {
            set.lock.read_lock();
            // SAFETY: read lock held.
            let entries = unsafe { &*set.entries.get() };
            n += entries.iter().filter(|e| e.key != EMPTY).count();
            set.lock.unlock_read();
        }
        n
    }

    fn name(&self) -> &'static str {
        "KW-LS"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let set = &self.sets[self.engine.geometry().set_of(key)];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        let result = self.engine.peek_victim_with(
            entries.len(),
            |i| entries[i].key,
            |i| entries[i].meta,
        );
        set.lock.unlock_read();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwLs::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwLs::new(64, 4, Policy::Hyperbolic);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwLs::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwLs::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key ^ 0xABCD);
                assert_eq!(c.get(key), Some(key ^ 0xABCD), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwLs::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key + 1);
        }
        let keys: Vec<u64> = (0..800u64).collect();
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwLs::new(4096, 8, Policy::Lru);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k * 5)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwLs::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(200 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn property_single_thread_model() {
        // Single-threaded: upgrades always succeed, so KW-LS behaves as an
        // exact sequential k-way cache against the model.
        check("ls-model", 20, |rng| {
            let c = KwLs::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
