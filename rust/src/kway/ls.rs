//! KW-LS — K-Way cache, Lock per Set (paper Algorithms 7–9).
//!
//! Each set carries a [`StampedLock`] and *plain* (non-atomic) entry
//! storage. Operations take the read lock to scan; to mutate metadata or
//! contents they attempt the `tryConvertToWriteLock` upgrade exactly as the
//! paper does — and, exactly as the paper does, they *give up* when the
//! upgrade fails (Alg. 8 lines 8–10, Alg. 9 lines 8–10): a hit whose
//! upgrade fails still returns the value but skips the metadata update, and
//! a put whose upgrade fails drops the insert. Both are benign for a cache
//! and keep the lock protocol deadlock-free without lock re-acquisition.
//!
//! Each set is cache-line padded so sets stay as independent in memory as
//! they are logically — the paper's independence argument made physical.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the locked plain storage and the upgrade protocol. Because every
//! mutation happens under the write lock, KW-LS is the *exact* member of
//! the family for the lifetime dimension — and for the **elastic-resize
//! dimension**: a source set is migrated *entirely under its write lock*
//! (acquired outright, not by upgrade: migration is an infrastructure
//! move, not an optional metadata touch), each surviving entry re-locks
//! its target set for the install, and the lock order is always
//! source-table-then-target-table, so the migration cannot deadlock
//! against puts or other drains (DESIGN.md §Elastic resizing).
//!
//! Byte values (DESIGN.md §Value store) are the easy case here: every
//! mutation already holds the write lock, so a displaced slab handle is
//! owned by construction — each site that overwrites or clears a live
//! entry releases its value word first, and the word path stays exactly
//! the paper's protocol ([`SetEngine::release_value`] is a no-op with no
//! store attached).

use super::engine::{self, Elastic, Epoch, PreparedKey, SetEngine, MAX_WAYS};
use super::geometry::{Geometry, EMPTY};
use super::slab::SlabStore;
use super::stamped::StampedLock;
use crate::lifetime::{self, BatchEntry, EntryOpts};
use crate::policy::Policy;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;
use std::sync::Arc;

/// One entry: encoded key word (0 = empty), value, policy metadata and
/// the packed (weight, expiry) life word.
#[derive(Clone, Copy, Default)]
struct Entry {
    key: u64,
    value: u64,
    meta: u64,
    life: u64,
}

/// A set: lock + plain storage.
struct LsSet {
    lock: StampedLock,
    entries: UnsafeCell<Box<[Entry]>>,
}

// SAFETY: `entries` is only accessed while holding `lock` in the
// appropriate mode (shared for reads, exclusive for writes).
unsafe impl Sync for LsSet {}
unsafe impl Send for LsSet {}

impl LsSet {
    fn new(ways: usize) -> Self {
        Self {
            lock: StampedLock::new(),
            entries: UnsafeCell::new(vec![Entry::default(); ways].into_boxed_slice()),
        }
    }
}

/// One geometry epoch's storage: the padded set array.
struct LsTable {
    sets: Box<[CachePadded<LsSet>]>,
}

impl LsTable {
    fn new(num_sets: usize, ways: usize) -> Self {
        Self { sets: (0..num_sets).map(|_| CachePadded::new(LsSet::new(ways))).collect() }
    }
}

/// Lock-per-set k-way cache.
pub struct KwLs {
    engine: SetEngine,
    elastic: Elastic<LsTable>,
}

impl KwLs {
    /// Build a cache of (at least) `capacity` weight units in sets of
    /// `ways` entries, evicting under `policy`.
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let geo = Geometry::new(capacity, ways);
        Self {
            engine: SetEngine::new(ways, policy),
            elastic: Elastic::new(geo, LsTable::new(geo.num_sets(), geo.ways())),
        }
    }

    /// Build a byte-value cache: `capacity` entry slots backed by (about)
    /// `value_bytes` of slab value memory; see `KwWfa::with_value_store`
    /// for the budget arithmetic (DESIGN.md §Value store).
    pub fn with_value_store(
        capacity: usize,
        ways: usize,
        policy: Policy,
        value_bytes: usize,
    ) -> Self {
        let geo = Geometry::new(capacity, ways);
        let store = Arc::new(SlabStore::for_budget(value_bytes));
        let per_way = SlabStore::budget_per_way(value_bytes, geo.capacity());
        let mut engine = SetEngine::new(ways, policy);
        engine.attach_values(store, per_way);
        Self { engine, elastic: Elastic::new(geo, LsTable::new(geo.num_sets(), geo.ways())) }
    }

    /// The attached byte-value store, when built by
    /// [`KwLs::with_value_store`].
    pub fn value_store(&self) -> Option<&Arc<SlabStore>> {
        self.engine.values()
    }

    /// The rounded geometry this cache currently runs with (the resize
    /// *target* geometry while a migration is in flight).
    pub fn geometry(&self) -> Geometry {
        self.elastic.snapshot().geo
    }

    /// The eviction policy.
    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    /// Largest per-set total weight currently held. Diagnostic for the
    /// weighted-capacity tests; for KW-LS the bound is exact (every
    /// mutation holds the write lock).
    pub fn max_set_weight(&self) -> u64 {
        let ep = self.elastic.snapshot();
        let mut max = 0u64;
        for set in ep.table.sets.iter() {
            set.lock.read_lock();
            // SAFETY: read lock held.
            let entries = unsafe { &*set.entries.get() };
            let w: u64 = entries
                .iter()
                .filter(|e| e.key != EMPTY)
                .map(|e| lifetime::weight_of(e.life))
                .sum();
            set.lock.unlock_read();
            max = max.max(w);
        }
        max
    }

    fn table_len(table: &LsTable) -> usize {
        let mut n = 0;
        for set in table.sets.iter() {
            set.lock.read_lock();
            // SAFETY: read lock held.
            let entries = unsafe { &*set.entries.get() };
            n += entries.iter().filter(|e| e.key != EMPTY).count();
            set.lock.unlock_read();
        }
        n
    }

    fn table_weight(table: &LsTable) -> u64 {
        let mut total = 0u64;
        for set in table.sets.iter() {
            set.lock.read_lock();
            // SAFETY: read lock held.
            let entries = unsafe { &*set.entries.get() };
            total += entries
                .iter()
                .filter(|e| e.key != EMPTY)
                .map(|e| lifetime::weight_of(e.life))
                .sum::<u64>();
            set.lock.unlock_read();
        }
        total
    }

    /// Probe one set of one table under its read lock; touches metadata
    /// through the upgrade protocol.
    fn probe_set(&self, set: &LsSet, pk: &PreparedKey, now: u64) -> Option<u64> {
        let ttl_active = self.engine.ttl_active();
        let now_ms = self.engine.expiry_now();
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        let hit = self.engine.probe_get(
            entries.len(),
            |i| entries[i].key == pk.ik,
            |i| ttl_active && lifetime::is_expired(entries[i].life, now_ms),
            |i| entries[i].value,
        );
        match hit {
            Some((i, value)) => {
                if !self.engine.updates_on_hit() {
                    set.lock.unlock_read();
                    return Some(value);
                }
                // Alg. 8: upgrade to update the counter; on failure return
                // the value without the metadata update.
                if set.lock.try_convert_to_write() {
                    // SAFETY: write lock held.
                    let entries = unsafe { &mut *set.entries.get() };
                    self.engine.touch_plain(&mut entries[i].meta, now);
                    set.lock.unlock_write();
                } else {
                    set.lock.unlock_read();
                }
                Some(value)
            }
            None => {
                set.lock.unlock_read();
                None
            }
        }
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths). Misses fall through old→new while a resize is
    /// migrating; the two set locks are taken strictly one after the
    /// other, never nested.
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let ep = self.elastic.snapshot();
        let set = &ep.table.sets[ep.geo.set_of_hash(pk.hash)];
        if let Some(value) = self.probe_set(set, &pk, now) {
            return Some(value);
        }
        let prev = ep.prev()?;
        self.probe_set(&prev.table.sets[prev.geo.set_of_hash(pk.hash)], &pk, now)
    }

    /// `put` with the hashing already done. Returns whether the entry
    /// was installed — a `false` means the insert was dropped (heavier
    /// than a set, or a failed lock upgrade), and in byte mode tells the
    /// caller it still owns the freshly allocated handle.
    fn put_prepared(&self, pk: PreparedKey, value: u64, opts: EntryOpts) -> bool {
        self.engine.note_opts(&opts);
        if opts.weight as u64 > self.engine.set_budget() {
            return false; // heavier than a whole set: can never fit, dropped
        }
        let ep = self.elastic.snapshot();
        if let Some(prev) = ep.prev() {
            // Help-on-write: drain the key's source set (under its write
            // lock) before inserting, so no second copy can linger.
            self.migrate_set(ep, prev, prev.geo.set_of_hash(pk.hash));
        }
        let now = self.engine.tick();
        let now_ms = self.engine.expiry_now();
        let life = lifetime::life_of(&opts, now_ms);
        let ttl_active = self.engine.ttl_active();
        let set = &ep.table.sets[ep.geo.set_of_hash(pk.hash)];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };

        // Pass 1 (Alg. 9 lines 4–13): overwrite an existing entry (and
        // refresh its life word — an overwrite restarts the TTL).
        if let Some(i) = self.engine.find_match(entries.len(), |i| entries[i].key == pk.ik) {
            if set.lock.try_convert_to_write() {
                // SAFETY: write lock held.
                let entries = unsafe { &mut *set.entries.get() };
                // Byte mode: the write lock owns the displaced handle.
                let old = entries[i].value;
                entries[i].value = value;
                entries[i].life = life;
                self.engine.touch_plain(&mut entries[i].meta, now);
                self.engine.release_value(old);
                Self::repair_weight_locked(&self.engine, entries, pk.ik, now, now_ms);
                set.lock.unlock_write();
                return true;
            }
            // Paper: give up when the upgrade fails.
            set.lock.unlock_read();
            return false;
        }

        // Miss path (Alg. 9 lines 15–27): upgrade, then fill an empty way
        // or replace the victim (an expired line first, the policy choice
        // otherwise).
        if !set.lock.try_convert_to_write() {
            set.lock.unlock_read();
            return false;
        }
        // SAFETY: write lock held.
        let entries = unsafe { &mut *set.entries.get() };
        let target = match entries.iter().position(|e| e.key == EMPTY) {
            Some(i) => i,
            None => {
                self.engine
                    .choose_victim(entries.len(), now, |i| {
                        let expired = ttl_active && lifetime::is_expired(entries[i].life, now_ms);
                        (entries[i].key, entries[i].meta, expired)
                    })
                    .way
            }
        };
        // An empty way's value word is 0, so this frees exactly the
        // replaced victim's slab item (and nothing on a clean fill).
        self.engine.release_value(entries[target].value);
        entries[target] = Entry { key: pk.ik, value, meta: self.engine.initial_meta(now), life };
        Self::repair_weight_locked(&self.engine, entries, pk.ik, now, now_ms);
        set.lock.unlock_write();
        true
    }

    /// Drain one source set of an in-flight resize *exactly*: the source
    /// set's write lock is held for the whole move, so concurrent puts to
    /// that set serialize behind the migration and nothing can race the
    /// copy-out. Each surviving entry is installed into its target set
    /// under that set's write lock; lock order is always source (old
    /// table) before target (new table), so drains, puts and the
    /// background walk cannot deadlock.
    fn migrate_set(&self, ep: &Epoch<LsTable>, prev: &Epoch<LsTable>, old_set: usize) {
        let src = &prev.table.sets[old_set];
        src.lock.write_lock();
        // SAFETY: write lock held.
        let entries = unsafe { &mut *src.entries.get() };
        let now_ms = self.engine.expiry_now();
        let ttl_active = self.engine.ttl_active();
        for e in entries.iter_mut() {
            if e.key == EMPTY {
                continue;
            }
            let moved = *e;
            *e = Entry::default();
            if ttl_active && lifetime::is_expired(moved.life, now_ms) {
                // Dead line: reclaim, don't move — and recycle its slab
                // item (the write lock made this thread the owner).
                self.engine.release_value(moved.value);
                continue;
            }
            let pk = self.engine.prepare(Geometry::decode_key(moved.key), ep.geo);
            self.install_migrated(ep, &pk, moved);
        }
        src.lock.unlock_write();
    }

    /// Install one migrated entry into its target set under that set's
    /// write lock, preserving metadata and life word. Placement follows
    /// the shared contract: a fresher copy wins, a full set (shrink
    /// merge) resolves through [`SetEngine::place_migrated`], and the
    /// weight budget is repaired exactly afterwards.
    fn install_migrated(&self, ep: &Epoch<LsTable>, pk: &PreparedKey, moved: Entry) {
        let dst = &ep.table.sets[ep.geo.set_of_hash(pk.hash)];
        dst.lock.write_lock();
        // SAFETY: write lock held.
        let entries = unsafe { &mut *dst.entries.get() };
        let now = self.engine.now();
        let now_ms = self.engine.expiry_now();
        if entries.iter().any(|e| e.key == pk.ik) {
            dst.lock.unlock_write();
            // A fresher insert already landed in the target: the old
            // copy is dropped, and this thread owns its handle.
            self.engine.release_value(moved.value);
            return;
        }
        let slot = match entries.iter().position(|e| e.key == EMPTY) {
            Some(i) => Some(i),
            None => {
                let metas: Vec<u64> = entries.iter().map(|e| e.meta).collect();
                self.engine.place_migrated(entries.len(), now, &metas, moved.meta)
            }
        };
        if let Some(i) = slot {
            // Displacing a live victim (shrink merge) frees its item;
            // an empty way's value word is 0 and frees nothing.
            self.engine.release_value(entries[i].value);
            entries[i] = Entry { key: pk.ik, ..moved };
            Self::repair_weight_locked(&self.engine, entries, pk.ik, now, now_ms);
        } else {
            // The migrated entry is the policy victim: drop it (and
            // recycle its slab item — this thread owns the handle).
            self.engine.release_value(moved.value);
        }
        dst.lock.unlock_write();
    }

    /// Exact weighted-capacity repair, run under the write lock: evict
    /// victims (expired lines first, the policy choice otherwise, sparing
    /// `keep`) until the set's total weight fits the budget.
    fn repair_weight_locked(
        engine: &SetEngine,
        entries: &mut [Entry],
        keep: u64,
        now: u64,
        now_ms: u64,
    ) {
        if !engine.weight_active() {
            return;
        }
        let budget = engine.set_budget();
        let ttl_active = engine.ttl_active();
        loop {
            let total: u64 = entries
                .iter()
                .filter(|e| e.key != EMPTY)
                .map(|e| lifetime::weight_of(e.life))
                .sum();
            if total <= budget {
                return;
            }
            let mut eligible = [0usize; MAX_WAYS];
            let mut metas = [0u64; MAX_WAYS];
            let mut n = 0usize;
            let mut victim: Option<usize> = None;
            for (i, e) in entries.iter().enumerate() {
                if e.key == EMPTY || e.key == keep {
                    continue;
                }
                if victim.is_none() && ttl_active && lifetime::is_expired(e.life, now_ms) {
                    victim = Some(i);
                }
                eligible[n] = i;
                metas[n] = e.meta;
                n += 1;
            }
            let target = match victim {
                Some(i) => i,
                None if n > 0 => eligible[engine.select_victim(&metas[..n], now)],
                None => return, // only the spared entry remains
            };
            engine.release_value(entries[target].value);
            entries[target] = Entry::default();
        }
    }
}

impl Cache for KwLs {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key, self.elastic.snapshot().geo))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(
            self.engine.prepare(key, self.elastic.snapshot().geo),
            value,
            EntryOpts::default(),
        );
    }

    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        self.put_prepared(self.engine.prepare(key, self.elastic.snapshot().geo), value, opts);
    }

    fn supports_values(&self) -> bool {
        self.engine.values_active()
    }

    fn put_bytes_with(&self, key: u64, value: &[u8], opts: EntryOpts) -> bool {
        let Some((handle, opts)) = self.engine.alloc_value(value, opts) else {
            return false;
        };
        let pk = self.engine.prepare(key, self.elastic.snapshot().geo);
        if self.put_prepared(pk, handle, opts) {
            true
        } else {
            // The insert was dropped (upgrade failure / over-budget): the
            // fresh item never became reachable, recycle it here.
            self.engine.release_value(handle);
            false
        }
    }

    fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        let store = self.engine.values()?;
        // The hit's value word is a generation-stamped handle; a slot
        // recycled between the probe and this read fails the generation
        // check and reports the eviction as a miss.
        store.read(self.get(key)?)
    }

    fn value_bytes(&self) -> u64 {
        self.engine.values().map_or(0, |s| s.used_bytes())
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        let ep = self.elastic.snapshot();
        self.engine.for_batch(
            ep.geo,
            keys,
            |&key| key,
            // Prefetch the set header (lock word + entries pointer); the
            // entries themselves sit behind one more indirection.
            |set| {
                let header: &LsSet = &ep.table.sets[set];
                engine::prefetch_read(header);
            },
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        let ep = self.elastic.snapshot();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.0,
            |set| {
                let header: &LsSet = &ep.table.sets[set];
                engine::prefetch_read(header);
            },
            |pk, item| {
                self.put_prepared(pk, item.1, EntryOpts::default());
            },
        );
    }

    fn put_batch_with(&self, items: &[BatchEntry]) {
        let ep = self.elastic.snapshot();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.key,
            |set| {
                let header: &LsSet = &ep.table.sets[set];
                engine::prefetch_read(header);
            },
            |pk, item| {
                self.put_prepared(pk, item.value, item.opts);
            },
        );
    }

    fn capacity(&self) -> usize {
        let ep = self.elastic.snapshot();
        match ep.prev() {
            Some(prev) => ep.geo.capacity().max(prev.geo.capacity()),
            None => ep.geo.capacity(),
        }
    }

    fn requested_capacity(&self) -> usize {
        self.elastic.snapshot().geo.requested_capacity()
    }

    fn len(&self) -> usize {
        let ep = self.elastic.snapshot();
        let mut n = Self::table_len(&ep.table);
        if let Some(prev) = ep.prev() {
            n += Self::table_len(&prev.table);
        }
        n
    }

    fn weight(&self) -> u64 {
        if !self.engine.weight_active() {
            return self.len() as u64;
        }
        let ep = self.elastic.snapshot();
        let mut total = Self::table_weight(&ep.table);
        if let Some(prev) = ep.prev() {
            total += Self::table_weight(&prev.table);
        }
        total
    }

    fn name(&self) -> &'static str {
        "KW-LS"
    }

    fn supports_lifetime(&self) -> bool {
        true
    }

    fn supports_resize(&self) -> bool {
        true
    }

    fn resize(&self, new_capacity: usize) -> bool {
        while self.elastic.resizing() {
            if self.resize_step(64) == 0 {
                std::thread::yield_now();
            }
        }
        let geo = self.elastic.snapshot().geo;
        self.elastic.begin(geo.resized(new_capacity), |g| LsTable::new(g.num_sets(), g.ways()))
    }

    fn resize_step(&self, max_sets: usize) -> usize {
        self.elastic.step(max_sets, |ep, prev, set| self.migrate_set(ep, prev, set))
    }

    fn resize_pending(&self) -> bool {
        self.elastic.resizing()
    }

    fn sweep_expired(&self, max_sets: usize) -> usize {
        if max_sets == 0 || !self.engine.ttl_active() {
            return 0;
        }
        let ep = self.elastic.snapshot();
        let num_sets = ep.geo.num_sets();
        let span = max_sets.min(num_sets);
        let start = self.engine.sweep_start(span, num_sets);
        let now_ms = lifetime::now_ms();
        let mut reclaimed = 0;
        for j in 0..span {
            let set = &ep.table.sets[(start + j) % num_sets];
            set.lock.read_lock();
            // Like every KW-LS mutation: upgrade or give up (the next
            // sweep pass will revisit this set).
            if !set.lock.try_convert_to_write() {
                set.lock.unlock_read();
                continue;
            }
            // SAFETY: write lock held.
            let entries = unsafe { &mut *set.entries.get() };
            for e in entries.iter_mut() {
                if e.key != EMPTY && lifetime::is_expired(e.life, now_ms) {
                    self.engine.release_value(e.value);
                    *e = Entry::default();
                    reclaimed += 1;
                }
            }
            set.lock.unlock_write();
        }
        reclaimed
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let ep = self.elastic.snapshot();
        let set = &ep.table.sets[ep.geo.set_of(key)];
        set.lock.read_lock();
        // SAFETY: read lock held.
        let entries = unsafe { &*set.entries.get() };
        let result = self.engine.peek_victim_with(
            entries.len(),
            |i| entries[i].key,
            |i| entries[i].meta,
            |i| entries[i].life,
        );
        set.lock.unlock_read();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn put_get_overwrite() {
        let c = KwLs::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwLs::new(64, 4, Policy::Hyperbolic);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwLs::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwLs::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key ^ 0xABCD);
                assert_eq!(c.get(key), Some(key ^ 0xABCD), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwLs::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key + 1);
        }
        let keys: Vec<u64> = (0..800u64).collect();
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwLs::new(4096, 8, Policy::Lru);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k * 5)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn expired_entries_probe_as_misses() {
        let c = KwLs::new(64, 4, Policy::Lru);
        c.put_with(1, 10, EntryOpts::ttl(Duration::ZERO));
        assert_eq!(c.get(1), None);
        c.put_with(2, 20, EntryOpts::ttl(Duration::from_secs(3600)));
        assert_eq!(c.get(2), Some(20));
    }

    #[test]
    fn expired_line_is_victim_of_first_resort() {
        let c = KwLs::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::ttl(Duration::ZERO));
        for key in 1..4u64 {
            c.put(key, key);
        }
        c.put(100, 100);
        for key in 1..4u64 {
            assert_eq!(c.get(key), Some(key), "immortal {key} must survive");
        }
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn weight_budget_is_exact_under_the_lock() {
        let c = KwLs::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::weight(3));
        c.put(1, 1);
        assert_eq!(c.max_set_weight(), 4);
        c.put(2, 2); // 3+1+1 > 4: repair must evict on insert
        assert!(c.max_set_weight() <= 4);
        assert_eq!(c.get(2), Some(2), "the inserting key is spared");
        c.put_with(9, 9, EntryOpts::weight(5));
        assert_eq!(c.get(9), None, "oversized entries are dropped");
    }

    #[test]
    fn sweep_reclaims_expired_lines() {
        let c = KwLs::new(4096, 8, Policy::Lru);
        for key in 0..10u64 {
            c.put_with(key, key, EntryOpts::ttl(Duration::ZERO));
        }
        for key in 10..20u64 {
            c.put(key, key);
        }
        assert_eq!(c.sweep_expired(c.geometry().num_sets()), 10);
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn grow_keeps_every_entry_readable() {
        // 100 keys over 256 sets: no set can overflow its 8 ways, so a
        // missing key is a resize bug, not an eviction.
        let c = KwLs::new(2048, 8, Policy::Lru);
        for key in 0..100u64 {
            c.put(key, key + 3);
        }
        assert!(c.resize(4096));
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key + 3), "key {key} lost mid-resize");
        }
        while c.resize_pending() {
            c.resize_step(16);
        }
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key + 3), "key {key} lost after migration");
        }
        assert_eq!(c.capacity(), 4096);
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwLs::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(200 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn byte_values_roundtrip_and_recycle() {
        // Word caches refuse the byte API outright.
        let c = KwLs::new(64, 4, Policy::Lru);
        assert!(!c.supports_values());
        assert!(!c.put_bytes(1, b"nope"));
        assert_eq!(c.get_bytes(1), None);

        let c = KwLs::with_value_store(64, 4, Policy::Lru, 1 << 22);
        assert!(c.supports_values());
        assert!(c.put_bytes(1, b"hello slab"));
        assert_eq!(c.get_bytes(1).as_deref(), Some(&b"hello slab"[..]));
        let store = c.value_store().unwrap();
        assert_eq!(store.used_bytes(), 64, "10 bytes occupy one 64-byte item");
        // An overwrite recycles the displaced item: ledger swaps to the
        // new size instead of accumulating.
        assert!(c.put_bytes(1, &[7u8; 300]));
        assert_eq!(c.get_bytes(1).unwrap(), vec![7u8; 300]);
        assert_eq!(store.used_bytes(), 320, "300 bytes land in the 320-byte class");
        assert_eq!(c.value_bytes(), 320);
        // The word-path tombstone (put 0) frees the blob too.
        c.put(1, 0);
        assert_eq!(c.get_bytes(1), None);
        assert_eq!(store.used_bytes(), 0, "tombstoned blob recycled");
    }

    #[test]
    fn byte_eviction_recycles_items() {
        // Single set of 4 ways: inserting 40 distinct keys forces ~36
        // victim replacements; every displaced handle must come back to
        // the free list (ledger == live residents only).
        let c = KwLs::with_value_store(4, 4, Policy::Lru, 1 << 20);
        for key in 0..40u64 {
            c.put_bytes(key, &[key as u8; 100]);
        }
        let store = c.value_store().unwrap();
        let live = (0..40u64).filter(|&k| c.get_bytes(k).is_some()).count() as u64;
        assert!(live <= 4);
        assert_eq!(store.used_bytes(), live * 128, "only residents hold items");
        let stats = store.stats();
        for cl in &stats.classes {
            assert_eq!(cl.carved, cl.live + cl.free, "free-list ledger balances");
        }
    }

    #[test]
    fn property_single_thread_model() {
        // Single-threaded: upgrades always succeed, so KW-LS behaves as an
        // exact sequential k-way cache against the model.
        check("ls-model", 20, |rng| {
            let c = KwLs::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
