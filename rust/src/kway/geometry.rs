//! Cache geometry: capacity → (power-of-two set count, ways), plus the
//! key→set mapping and the internal key encoding shared by the wait-free
//! variants.
//!
//! Since the elastic-resize refactor a `Geometry` is no longer frozen for
//! the lifetime of a cache: the k-way variants hold an *epoch-stamped*
//! geometry (see `engine::Elastic`) and move between geometries by linear
//! hashing — the set count is a power of two, so doubling it splits set
//! `s` deterministically into `s` and `s + old_num_sets`, and halving it
//! merges them back. `ways` stays fixed across resizes (the associativity
//! threshold literature says scan width, not set count, is the knob that
//! changes behaviour — PAPERS.md), so only the set count moves.

use crate::util::hash;

/// Geometry of a k-way cache: `num_sets` is always a power of two so the
/// set index is `hash(key) & (num_sets - 1)`, exactly as in the paper's
/// Algorithms 2–9.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    num_sets: usize,
    ways: usize,
    /// The capacity the caller asked for, before power-of-two rounding.
    requested: usize,
}

/// Internal key-word sentinels for the wait-free variants. User keys are
/// shifted by [`Geometry::encode_key`] so they can never collide with
/// these.
pub(crate) const EMPTY: u64 = 0;
pub(crate) const RESERVED: u64 = 1;
const KEY_OFFSET: u64 = 2;

/// Assumed cache-line size in bytes. The SoA/AoS table slices are
/// allocated at this alignment (see `kway::alloc`) so that, with the
/// power-of-two way counts [`Geometry::new`] produces, a set of up to 8
/// u64 words occupies exactly one line and a wider set spans whole lines —
/// the layout invariant both the paper's §3 locality argument and the
/// SIMD fingerprint probe (`kway::simd`) rely on.
pub(crate) const CACHE_LINE: usize = 64;

impl Geometry {
    /// Smallest geometry with at least `capacity` slots and exactly `ways`
    /// ways per set. `capacity` is rounded up so that the set count is a
    /// power of two (the paper's cache sizes are powers of two, so for the
    /// evaluation this is exact); [`Geometry::requested_capacity`] keeps
    /// the pre-rounding figure so reports can show both.
    pub fn new(capacity: usize, ways: usize) -> Self {
        assert!(ways >= 1, "need at least one way");
        assert!(capacity >= ways, "capacity must be >= ways");
        let num_sets = capacity.div_ceil(ways).next_power_of_two();
        Self { num_sets, ways, requested: capacity }
    }

    /// The geometry an online resize toward `new_capacity` targets: same
    /// ways, set count re-derived (and re-rounded) from the new capacity.
    pub fn resized(&self, new_capacity: usize) -> Self {
        Self::new(new_capacity.max(self.ways), self.ways)
    }

    /// Number of sets (always a power of two).
    #[inline]
    pub fn num_sets(&self) -> usize {
        self.num_sets
    }

    /// Ways (entries) per set.
    #[inline]
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Total slots = num_sets × ways. Power-of-two rounding of the set
    /// count can inflate this up to ~2× over the requested capacity.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.num_sets * self.ways
    }

    /// The capacity that was asked for at construction (or as a resize
    /// target), before power-of-two rounding — the honest figure for
    /// reports and resize-target bookkeeping.
    #[inline]
    pub fn requested_capacity(&self) -> usize {
        self.requested
    }

    /// The full 64-bit set hash of a key (mask-independent; see
    /// [`Geometry::set_of_hash`]).
    #[inline]
    pub fn hash_of(key: u64) -> u64 {
        hash::set_hash(key)
    }

    /// Set index for a key (xxh64, masked).
    #[inline]
    pub fn set_of(&self, key: u64) -> usize {
        self.set_of_hash(Self::hash_of(key))
    }

    /// Set index from an already-computed set hash — the elastic-resize
    /// path derives a key's set under both the old and the new geometry
    /// from one hash pass this way.
    #[inline]
    pub fn set_of_hash(&self, h: u64) -> usize {
        (h as usize) & (self.num_sets - 1)
    }

    /// Range of flat slot indices for a set (for SoA layouts).
    #[inline]
    pub fn slots_of(&self, set: usize) -> std::ops::Range<usize> {
        let start = set * self.ways;
        start..start + self.ways
    }

    /// Encode a user key into the internal key word (avoids the EMPTY and
    /// RESERVED sentinels). Keys above `u64::MAX - 2` are not supported.
    #[inline]
    pub(crate) fn encode_key(key: u64) -> u64 {
        debug_assert!(key <= u64::MAX - KEY_OFFSET, "key too large");
        key + KEY_OFFSET
    }

    /// Inverse of [`Geometry::encode_key`].
    #[inline]
    pub(crate) fn decode_key(word: u64) -> u64 {
        debug_assert!(word >= KEY_OFFSET);
        word - KEY_OFFSET
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_set_count_to_power_of_two() {
        let g = Geometry::new(2048, 8);
        assert_eq!(g.num_sets(), 256);
        assert_eq!(g.capacity(), 2048);
        assert_eq!(g.requested_capacity(), 2048);
        let g = Geometry::new(1000, 8); // 125 sets -> 128
        assert_eq!(g.num_sets(), 128);
        assert_eq!(g.capacity(), 1024);
        assert_eq!(g.requested_capacity(), 1000, "rounding must not hide the request");
    }

    #[test]
    fn set_of_in_range() {
        let g = Geometry::new(4096, 16);
        for key in 0..10_000u64 {
            assert!(g.set_of(key) < g.num_sets());
            assert_eq!(g.set_of(key), g.set_of_hash(Geometry::hash_of(key)));
        }
    }

    #[test]
    fn resized_doubles_and_halves_by_linear_hashing() {
        let g = Geometry::new(1024, 8); // 128 sets
        let grown = g.resized(2048); // 256 sets
        assert_eq!(grown.num_sets(), 2 * g.num_sets());
        assert_eq!(grown.ways(), g.ways());
        assert_eq!(grown.requested_capacity(), 2048);
        let shrunk = grown.resized(1024);
        assert_eq!(shrunk.num_sets(), g.num_sets());
        // Every key's grown set is its old set or old set + old_num_sets.
        for key in 0..5_000u64 {
            let s = g.set_of(key);
            let sg = grown.set_of(key);
            assert!(sg == s || sg == s + g.num_sets(), "key {key}: {s} -> {sg}");
        }
        // Resizing below `ways` clamps instead of violating the invariant.
        assert_eq!(g.resized(1).num_sets(), 1);
    }

    #[test]
    fn slots_of_partitions_capacity() {
        let g = Geometry::new(64, 4);
        let mut seen = vec![false; g.capacity()];
        for set in 0..g.num_sets() {
            for slot in g.slots_of(set) {
                assert!(!seen[slot]);
                seen[slot] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn key_encoding_avoids_sentinels() {
        for key in [0u64, 1, 2, 12345, u64::MAX - 2] {
            let w = Geometry::encode_key(key);
            assert_ne!(w, EMPTY);
            assert_ne!(w, RESERVED);
            assert_eq!(Geometry::decode_key(w), key);
        }
    }

    #[test]
    fn one_way_cache_is_direct_mapped() {
        let g = Geometry::new(16, 1);
        assert_eq!(g.num_sets(), 16);
        assert_eq!(g.ways(), 1);
    }
}
