//! A StampedLock-alike: one atomic word giving shared read locks, an
//! exclusive write lock, and — the part KW-LS needs — a *read→write
//! upgrade* (`try_convert_to_write`), mirroring the
//! `java.util.concurrent.locks.StampedLock` API used by the paper's
//! Algorithms 7–9.
//!
//! State word: bit 63 = writer, bits 0..63 = reader count.

use std::sync::atomic::{AtomicU64, Ordering};

const WRITER: u64 = 1 << 63;

/// A per-set read/write lock with upgrade.
#[derive(Debug, Default)]
pub struct StampedLock {
    state: AtomicU64,
}

impl StampedLock {
    /// A fresh unlocked lock.
    pub fn new() -> Self {
        Self { state: AtomicU64::new(0) }
    }

    #[inline]
    fn spin(iter: &mut u32) {
        *iter += 1;
        if *iter % 64 == 0 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
    }

    /// Acquire a shared read lock (blocks while a writer holds the lock).
    #[inline]
    pub fn read_lock(&self) {
        let mut it = 0;
        loop {
            let s = self.state.load(Ordering::Relaxed);
            if s & WRITER == 0
                && self
                    .state
                    .compare_exchange_weak(s, s + 1, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                return;
            }
            Self::spin(&mut it);
        }
    }

    /// Release a shared read lock.
    #[inline]
    pub fn unlock_read(&self) {
        let prev = self.state.fetch_sub(1, Ordering::Release);
        debug_assert!(prev & !WRITER >= 1, "unlock_read without read_lock");
    }

    /// Try to upgrade: succeeds only when the caller is the *sole* reader
    /// and no writer holds the lock (the `tryConvertToWriteLock` semantics
    /// the paper relies on). On success the caller holds the write lock.
    #[inline]
    pub fn try_convert_to_write(&self) -> bool {
        self.state
            .compare_exchange(1, WRITER, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    /// Acquire the exclusive write lock.
    #[inline]
    pub fn write_lock(&self) {
        let mut it = 0;
        loop {
            if self
                .state
                .compare_exchange_weak(0, WRITER, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
            Self::spin(&mut it);
        }
    }

    /// Release the write lock.
    #[inline]
    pub fn unlock_write(&self) {
        let prev = self.state.swap(0, Ordering::Release);
        debug_assert_eq!(prev, WRITER, "unlock_write without write_lock");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn readers_share() {
        let l = StampedLock::new();
        l.read_lock();
        l.read_lock();
        l.unlock_read();
        l.unlock_read();
    }

    #[test]
    fn upgrade_requires_sole_reader() {
        let l = StampedLock::new();
        l.read_lock();
        l.read_lock();
        assert!(!l.try_convert_to_write(), "upgrade must fail with two readers");
        l.unlock_read();
        assert!(l.try_convert_to_write(), "sole reader upgrades");
        l.unlock_write();
    }

    #[test]
    fn writer_excludes_readers() {
        let l = Arc::new(StampedLock::new());
        l.write_lock();
        let l2 = l.clone();
        let h = std::thread::spawn(move || {
            l2.read_lock();
            l2.unlock_read();
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(!h.is_finished(), "reader must wait for the writer");
        l.unlock_write();
        h.join().unwrap();
    }

    #[test]
    fn mutual_exclusion_counter() {
        // Classic race detector: protected counter increments never lost.
        struct Shared {
            lock: StampedLock,
            counter: std::cell::UnsafeCell<u64>,
        }
        unsafe impl Sync for Shared {}
        let s = Arc::new(Shared { lock: StampedLock::new(), counter: 0.into() });
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    s.lock.write_lock();
                    unsafe { *s.counter.get() += 1 };
                    s.lock.unlock_write();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(unsafe { *s.counter.get() }, 40_000);
    }
}
