//! KW-WFSC — K-Way cache, Wait-Free with Separate Counters (paper
//! Algorithms 4–6).
//!
//! Structure-of-arrays: the whole cache is five flat atomic arrays —
//! fingerprints, counters, keys, values, life words — indexed
//! `set * k + way`. A probe scans only the *fingerprint* slice of the set
//! and a victim search scans only the *counter* slice, so for k ≤ 8 each
//! scan touches a single 64-byte cache line. That contiguity is exactly
//! the optimization the paper introduces WFSC for; the cost is that a
//! replacement needs several atomic operations (one CAS + four stores
//! here, "three atomic operations" in the paper's Java version) instead
//! of WFA's single node-swap CAS.
//!
//! Publication protocol: a put claims the way by CASing the fingerprint
//! word (0 = empty), then publishes value, counter and life word, and
//! stores the key word last. Readers match on the fingerprint but
//! *validate on the key word* and re-validate after reading the value, so
//! fingerprint collisions and mid-replace reads are both detected and
//! skipped.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the SoA storage and the fingerprint claim/publish protocol —
//! including the lifetime dimension (DESIGN.md §Expiration, §Weighted
//! capacity) and the **elastic-resize dimension**: the five arrays live
//! behind an epoch-stamped [`Elastic`] holder and a migration claims each
//! source line by CASing its fingerprint to the dedicated [`MIGRATING`]
//! sentinel (fingerprints are odd by construction, so the even sentinel
//! can never collide with a probe), republishes the entry into the new
//! table, and frees the source line (DESIGN.md §Elastic resizing).
//! The SoA layout also makes WFSC the best batching target: one prefetch
//! of the set's fingerprint line covers the whole probe — the arrays are
//! allocated cache-line-aligned (`kway::alloc`) so that claim holds by
//! construction, and the fingerprint scan itself is vectorized
//! (`kway::simd`): the set's fingerprint words are compared against the
//! probe fingerprint in one SIMD/SWAR pass that yields a candidate
//! bitmask, and only candidate ways pay for atomic verification.
//!
//! # Memory ordering (safety argument)
//!
//! Every ordering below is the weakest that preserves the protocol; this
//! section is the per-site justification the hot-path audit (DESIGN.md
//! §Hot path) demands. Notation: a way's words are F(ingerprint),
//! K(ey), V(alue), C(ounter), L(ife).
//!
//! * **Publish** ([`KwWfsc::publish`]): V is stored `Release`, C and L
//!   `Relaxed`, K `Release` *last*. The trailing K-Release covers the
//!   Relaxed C/L stores: any thread that loads K with `Acquire` and sees
//!   the published key word gets a happens-before edge to everything
//!   sequenced before the K store, so its subsequent C/L loads (even
//!   `Relaxed` ones) cannot read older values (happens-before +
//!   per-word coherence). V additionally carries its own `Release` —
//!   see the re-validation argument next.
//! * **Get probe** ([`KwWfsc::probe_set`]): the SIMD fingerprint mask is
//!   a *prefilter with no ordering role* (see `kway::simd`); each
//!   candidate is verified by `F==fp (Relaxed) && K==ik (Acquire)`,
//!   V is loaded `Acquire`, and the match is re-verified. Two edges are
//!   load-bearing. (a) K-Acquire ⇒ the V load observes at least the V
//!   the publisher stored before K, so a verified hit can never return
//!   a value older than its key word. (b) The *re-validation* detects
//!   mid-replace phantoms: a replacement CASes F to the new
//!   fingerprint, then stores V'. If the probe's V load returned V', the
//!   V'-Release/V-Acquire edge makes the F CAS (sequenced before V' in
//!   the replacer) happen-before the probe's re-validation F load, which
//!   therefore reads the new fingerprint and rejects the torn
//!   (old key, new value) pair. This is why the F load in verification
//!   may be `Relaxed` (coherence under happens-before is enough) but
//!   the V load/store pair must stay `Acquire`/`Release`.
//! * **Claim CASes** (empty claim, victim claim, `MIGRATING` claim,
//!   repair free): all `AcqRel` on success. The Release half publishes
//!   the fingerprint transition; the Acquire half pins the *subsequent
//!   publish stores* after the claim in program order, so a way is never
//!   written before it is owned (an Acquire load forbids later memory
//!   operations from moving before it). Pre-CAS peeks are `Relaxed`
//!   everywhere: the CAS re-verifies the peeked value, so a stale peek
//!   costs at worst a skipped way, never a safety violation.
//! * **Victim / repair / sweep snapshots**: F is loaded `Relaxed` (any
//!   action on the way is guarded by a CAS on F); K stays `Acquire`
//!   because a non-sentinel K *gates the interpretation of L and C* —
//!   the K-Acquire edge is what makes the Relaxed L/C loads read the
//!   published entry's words rather than a predecessor's (the publish
//!   argument above).
//! * **Pass-1 overwrite**: the resident check uses `F (Relaxed) &&
//!   K (Relaxed)` — equality with our own ik is all that is decided, no
//!   other word is interpreted, and coherence alone keeps the check
//!   exact once racing publishes quiesce. The value overwrite stays
//!   `Release` (re-validation anchor, above); the L refresh is `Relaxed`
//!   — a racing reader may briefly pair the new value with the old life
//!   word, which only blurs lazy expiry by one access, the same
//!   tolerance the TTL design already grants (DESIGN.md §Expiration).
//! * **The one SeqCst** ([`KwWfsc::repair_weight`]): the publish/repair
//!   fence is *irreducible*, see that function's comment. Everything
//!   else in this file is Release/Acquire/Relaxed.
//!
//! Known (pre-existing, unaffected by this audit) narrow race: a pass-1
//! overwrite that loses a race with a pass-3 replacement of the same way
//! can store its value over the replacement's publish, pairing the
//! replacement's key with the overwriter's value until the next write to
//! the way. Both orderings of the two writers are sequentially plausible
//! (the overwrite's key *was* resident when pass 1 ran), readers still
//! never return a value for a key that was never put, and no ordering
//! strengthening short of a per-way lock removes it — it is the
//! documented cost of wait-free puts, not a consequence of the relaxed
//! orderings introduced here. **Byte-value caches are exempt**: a
//! byte-mode pass-1 overwrite claims the fingerprint word first (next
//! section), so it can never land on top of a replacement's publish.
//!
//! # Byte values (DESIGN.md §Value store)
//!
//! With a slab store attached ([`KwWfsc::with_value_store`]) the value
//! word is a generation-stamped slab handle, and a handle must be
//! *owned* before it is recycled. The fingerprint word is the claim
//! token throughout: a pass-1 overwrite CASes it to the [`MIGRATING`]
//! sentinel for the duration of the value swap (probes miss the line
//! for those few instructions — an acceptable transient under "it is a
//! cache" semantics), a pass-3 replacement or shrink merge already owns
//! its line via the victim CAS and obtains the displaced handle with a
//! value-word `swap` inside [`KwWfsc::publish`], and repair/sweep
//! evictions claim the fingerprint, swap the value word to zero,
//! release the handle, and only then free the line. The invariant that
//! discipline buys: an EMPTY line's value word is always zero, so an
//! empty-claim publish's swap returns nothing to free and every handle
//! is released exactly once, always by its exclusive owner.

use super::alloc::AlignedSlice;
use super::engine::{self, Elastic, Epoch, PreparedKey, SetEngine, MAX_WAYS};
use super::geometry::{Geometry, EMPTY, RESERVED};
use super::simd;
use super::slab::SlabStore;
use crate::lifetime::{self, BatchEntry, EntryOpts};
use crate::policy::Policy;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Fingerprint-word sentinel of a line claimed by a resize migration.
/// [`crate::util::hash::fingerprint`] always sets bit 0, so every real
/// fingerprint is odd and this even value matches no probe.
const MIGRATING: u64 = 2;

/// One geometry epoch's storage: the five flat atomic arrays. Each array
/// is cache-line-aligned ([`AlignedSlice`]), so with the power-of-two way
/// counts geometry produces no set's slice of any array straddles a line
/// it did not have to — one prefetch per array covers a whole set, and
/// the SIMD probe reads the fingerprint set as one aligned vector span.
struct WfscTable {
    /// Non-zero fingerprint per occupied way; 0 = empty, 2 = migrating.
    fps: AlignedSlice<AtomicU64>,
    /// Policy metadata (the paper's separate counters array).
    counters: AlignedSlice<AtomicU64>,
    /// Encoded key words (validation + exact identification).
    keys: AlignedSlice<AtomicU64>,
    /// Values.
    values: AlignedSlice<AtomicU64>,
    /// Packed (weight, expiry) life words.
    lives: AlignedSlice<AtomicU64>,
}

fn atomic_array(n: usize) -> AlignedSlice<AtomicU64> {
    // SAFETY: the all-zero AtomicU64 is exactly the EMPTY sentinel every
    // slot must start as, and AtomicU64 has no Drop.
    unsafe { AlignedSlice::new_zeroed(n) }
}

impl WfscTable {
    fn new(capacity: usize) -> Self {
        Self {
            fps: atomic_array(capacity),
            counters: atomic_array(capacity),
            keys: atomic_array(capacity),
            values: atomic_array(capacity),
            lives: atomic_array(capacity),
        }
    }
}

/// Wait-free separate-counters k-way cache.
pub struct KwWfsc {
    engine: SetEngine,
    elastic: Elastic<WfscTable>,
}

impl KwWfsc {
    /// Build a cache of (at least) `capacity` weight units in sets of
    /// `ways` entries, evicting under `policy`.
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let geo = Geometry::new(capacity, ways);
        Self {
            engine: SetEngine::new(ways, policy),
            elastic: Elastic::new(geo, WfscTable::new(geo.capacity())),
        }
    }

    /// Build a byte-value cache: `capacity` entry slots backed by (about)
    /// `value_bytes` of slab value memory; see `KwWfa::with_value_store`
    /// for the budget arithmetic (DESIGN.md §Value store).
    pub fn with_value_store(
        capacity: usize,
        ways: usize,
        policy: Policy,
        value_bytes: usize,
    ) -> Self {
        let geo = Geometry::new(capacity, ways);
        let store = Arc::new(SlabStore::for_budget(value_bytes));
        let per_way = SlabStore::budget_per_way(value_bytes, geo.capacity());
        let mut engine = SetEngine::new(ways, policy);
        engine.attach_values(store, per_way);
        Self { engine, elastic: Elastic::new(geo, WfscTable::new(geo.capacity())) }
    }

    /// The attached byte-value store, when built by
    /// [`KwWfsc::with_value_store`].
    pub fn value_store(&self) -> Option<&Arc<SlabStore>> {
        self.engine.values()
    }

    /// The rounded geometry this cache currently runs with (the resize
    /// *target* geometry while a migration is in flight).
    pub fn geometry(&self) -> Geometry {
        self.elastic.snapshot().geo
    }

    /// The eviction policy.
    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    /// Largest per-set total weight currently held. Diagnostic for the
    /// weighted-capacity tests: after churn quiesces this never exceeds
    /// the per-set budget (= `ways`).
    pub fn max_set_weight(&self) -> u64 {
        let ep = self.elastic.snapshot();
        (0..ep.geo.num_sets())
            .map(|s| Self::set_weight(&ep.table, s * ep.geo.ways(), ep.geo.ways()))
            .max()
            .unwrap_or(0)
    }

    fn set_weight(table: &WfscTable, start: usize, k: usize) -> u64 {
        (0..k)
            .map(|i| {
                // Quiesced-state diagnostic: Relaxed reads are exact once
                // writers have joined (coherence), which is the only state
                // the weight-bound tests assert about.
                let fp = table.fps[start + i].load(Ordering::Relaxed);
                if fp == EMPTY || fp == MIGRATING {
                    0
                } else {
                    lifetime::weight_of(table.lives[start + i].load(Ordering::Relaxed))
                }
            })
            .sum()
    }

    fn table_len(table: &WfscTable) -> usize {
        table
            .fps
            .iter()
            .filter(|f| {
                let fp = f.load(Ordering::Relaxed);
                fp != EMPTY && fp != MIGRATING
            })
            .count()
    }

    /// Publish (value, counter, life, key) into a way whose fingerprint
    /// we own. Orderings per the module-level argument: the trailing
    /// key-word Release covers the Relaxed counter/life stores, and the
    /// value keeps its own Release as the probe's re-validation anchor.
    /// In byte mode the value store is a swap: the claim CAS made this
    /// thread the line's exclusive owner, so the displaced word — the
    /// victim's handle on a replacement, zero on an empty claim — is
    /// recycled here, exactly once.
    #[inline]
    fn publish(&self, table: &WfscTable, idx: usize, ik: u64, value: u64, life: u64, meta: u64) {
        if self.engine.values_active() {
            let old = table.values[idx].swap(value, Ordering::Release);
            self.engine.release_value(old);
        } else {
            table.values[idx].store(value, Ordering::Release);
        }
        table.counters[idx].store(meta, Ordering::Relaxed);
        table.lives[idx].store(life, Ordering::Relaxed);
        table.keys[idx].store(ik, Ordering::Release);
    }

    /// Probe one set of one table; touches the hit's counter.
    #[inline]
    fn probe_set(
        &self,
        table: &WfscTable,
        start: usize,
        k: usize,
        pk: &PreparedKey,
        now: u64,
    ) -> Option<u64> {
        let ttl_active = self.engine.ttl_active();
        let now_ms = self.engine.expiry_now();
        // Contiguous fingerprint scan (Alg. 5): one cache line for k <= 8,
        // compared in a single SIMD/SWAR pass. The mask is only a
        // prefilter; every candidate is re-verified atomically below (see
        // the module-level ordering argument for why F may be Relaxed
        // there while K stays Acquire and V Acquire/Release).
        let mask = simd::match_mask(&table.fps[start..start + k], pk.fp);
        let (way, value) = self.engine.probe_get_masked(
            mask,
            |i| {
                table.fps[start + i].load(Ordering::Relaxed) == pk.fp
                    && table.keys[start + i].load(Ordering::Acquire) == pk.ik
            },
            |i| {
                ttl_active
                    && lifetime::is_expired(table.lives[start + i].load(Ordering::Relaxed), now_ms)
            },
            |i| table.values[start + i].load(Ordering::Acquire),
        )?;
        self.engine.touch_atomic(&table.counters[start + way], now);
        Some(value)
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths). Misses fall through old→new while a resize is
    /// migrating, exactly like KW-WFA.
    #[inline]
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let ep = self.elastic.snapshot();
        let k = ep.geo.ways();
        let start = ep.geo.set_of_hash(pk.hash) * k;
        if let Some(value) = self.probe_set(&ep.table, start, k, &pk, now) {
            return Some(value);
        }
        let prev = ep.prev()?;
        let old_start = prev.geo.set_of_hash(pk.hash) * k;
        self.probe_set(&prev.table, old_start, k, &pk, now)
    }

    /// `put` with the hashing already done. Returns whether the entry
    /// was installed — a `false` means the insert was dropped (heavier
    /// than a set, or lost a wait-free race), and in byte mode tells the
    /// caller it still owns the freshly allocated handle.
    fn put_prepared(&self, pk: PreparedKey, value: u64, opts: EntryOpts) -> bool {
        self.engine.note_opts(&opts);
        if opts.weight as u64 > self.engine.set_budget() {
            return false; // heavier than a whole set: can never fit, dropped
        }
        let ep = self.elastic.snapshot();
        if let Some(prev) = ep.prev() {
            // Help-on-write: drain the key's source set first, so the
            // insert below can never leave a second copy behind.
            self.migrate_set(ep, prev, prev.geo.set_of_hash(pk.hash));
        }
        let now = self.engine.tick();
        let now_ms = self.engine.expiry_now();
        let life = lifetime::life_of(&opts, now_ms);
        let ttl_active = self.engine.ttl_active();
        let k = ep.geo.ways();
        let start = ep.geo.set_of_hash(pk.hash) * k;
        let table = &*ep.table;

        // Pass 1 (Alg. 6 lines 3–9): overwrite an existing entry (and
        // refresh its life word — an overwrite restarts the TTL). The
        // resident check decides only ik-equality, so Relaxed loads
        // suffice (module-level argument); the mask prefilter narrows it
        // to fingerprint candidates first.
        let pass1 = simd::match_mask(&table.fps[start..start + k], pk.fp);
        if let Some(i) = self.engine.find_match_masked(pass1, |i| {
            table.fps[start + i].load(Ordering::Relaxed) == pk.fp
                && table.keys[start + i].load(Ordering::Relaxed) == pk.ik
        }) {
            if self.engine.values_active() {
                // Byte mode claims the fingerprint for the overwrite so
                // the displaced handle is obtained exclusively (never
                // freed twice) and the new one can never land in a line
                // a racing replacement just gave to another key. The key
                // word is re-verified under the claim: a fingerprint ABA
                // (replacement by a colliding key) passes the CAS.
                if table.fps[start + i]
                    .compare_exchange(pk.fp, MIGRATING, Ordering::AcqRel, Ordering::Relaxed)
                    .is_err()
                {
                    return false; // line mid-churn: drop ("it is a cache")
                }
                if table.keys[start + i].load(Ordering::Acquire) != pk.ik {
                    table.fps[start + i].store(pk.fp, Ordering::Release);
                    return false; // fp collision replaced the entry
                }
                let old = table.values[start + i].swap(value, Ordering::Release);
                table.lives[start + i].store(life, Ordering::Relaxed);
                table.fps[start + i].store(pk.fp, Ordering::Release);
                self.engine.release_value(old);
            } else {
                table.values[start + i].store(value, Ordering::Release);
                table.lives[start + i].store(life, Ordering::Relaxed);
            }
            self.engine.touch_atomic(&table.counters[start + i], now);
            self.repair_weight(table, start, pk.ik);
            return true;
        }

        // Pass 2: claim an empty way (fingerprint CAS 0 -> fp). The empty
        // scan is the same vector compare with EMPTY as the needle; the
        // AcqRel CAS re-verifies every candidate, so the mask being a
        // stale prefilter is harmless.
        let mut empties = simd::match_mask(&table.fps[start..start + k], EMPTY);
        while empties != 0 {
            let i = empties.trailing_zeros() as usize;
            empties &= empties - 1;
            if table.fps[start + i]
                .compare_exchange(EMPTY, pk.fp, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                self.publish(table, start + i, pk.ik, value, life, self.engine.initial_meta(now));
                self.repair_weight(table, start, pk.ik);
                return true;
            }
        }

        // Pass 3 (Alg. 6 lines 11–15): select the victim — an expired line
        // first, otherwise from the counters array alone — then claim it
        // by CASing its fingerprint. A failed CAS means a concurrent
        // replacement won the way; like the paper we give up rather than
        // loop (wait-free). The expired shortcut only trusts a way whose
        // key word is fully published: a mid-publish way's life word is
        // the previous occupant's (or the initial zero, which reads as
        // expired), and taking it as the victim of first resort would
        // race the in-flight publish — same rule as repair_weight below.
        let choice = self.engine.choose_victim(k, now, |i| {
            // F Relaxed: the victim claim CAS below re-verifies it. K
            // stays Acquire — it gates trusting the life word (module-
            // level ordering argument).
            let fp = table.fps[start + i].load(Ordering::Relaxed);
            if fp == MIGRATING {
                return (fp, u64::MAX, false); // mid-migration: never the victim
            }
            let expired = if ttl_active && fp != EMPTY {
                let word = table.keys[start + i].load(Ordering::Acquire);
                word != EMPTY
                    && word != RESERVED
                    && lifetime::is_expired(table.lives[start + i].load(Ordering::Relaxed), now_ms)
            } else {
                false
            };
            (fp, table.counters[start + i].load(Ordering::Relaxed), expired)
        });
        if choice.guard == MIGRATING {
            return false;
        }
        let idx = start + choice.way;
        let installed = table.fps[idx]
            .compare_exchange(choice.guard, pk.fp, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok();
        if installed {
            self.publish(table, idx, pk.ik, value, life, self.engine.initial_meta(now));
        }
        self.repair_weight(table, start, pk.ik);
        installed
    }

    /// Drain one source set of an in-flight resize into the target table:
    /// each live line is claimed by CASing its fingerprint to
    /// [`MIGRATING`] (no probe can match it from that moment), its words
    /// are read, the source line is freed, and the entry is republished
    /// carrying its earned metadata. Expired lines are dropped; claims
    /// lost to concurrent drains or replacements are skipped.
    fn migrate_set(&self, ep: &Epoch<WfscTable>, prev: &Epoch<WfscTable>, old_set: usize) {
        let k = prev.geo.ways();
        let start = old_set * k;
        let table = &*prev.table;
        for i in 0..k {
            // Pre-claim peeks are Relaxed: the MIGRATING CAS re-verifies
            // the fingerprint, and a stale peek only skips a line the
            // background walk retries.
            let fp = table.fps[start + i].load(Ordering::Relaxed);
            if fp == EMPTY || fp == MIGRATING {
                continue;
            }
            let word = table.keys[start + i].load(Ordering::Relaxed);
            if word == EMPTY || word == RESERVED {
                continue; // mid-publish: the background walk will retry
            }
            if table.fps[start + i]
                .compare_exchange(fp, MIGRATING, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue; // lost to a concurrent drain/replacement
            }
            // We own the line now; re-read the words under the claim. A
            // fp-colliding republish that raced the claim shows up as a
            // sentinel key word here — treat it as a dropped insert. The
            // K Acquire synchronizes with the publisher's trailing
            // K-Release, covering the Relaxed V/C/L reads below.
            let word = table.keys[start + i].load(Ordering::Acquire);
            let value = if self.engine.values_active() {
                // Byte mode zeroes the source value word under the
                // claim: the handle now has exactly one owner (us).
                table.values[start + i].swap(EMPTY, Ordering::Relaxed)
            } else {
                table.values[start + i].load(Ordering::Relaxed)
            };
            let meta = table.counters[start + i].load(Ordering::Relaxed);
            let life = table.lives[start + i].load(Ordering::Relaxed);
            // Free the line: K cleared first (Relaxed), then F Released —
            // the F-Release covers the K clear for the next claimer.
            table.keys[start + i].store(EMPTY, Ordering::Relaxed);
            table.fps[start + i].store(EMPTY, Ordering::Release);
            if word == EMPTY || word == RESERVED {
                // Dropped insert: recycle whatever value had landed
                // (zero — a no-op — when the racing publisher's value
                // store was still in flight; that item stays leaked, a
                // cost bounded by the rarity of claiming mid-publish).
                self.engine.release_value(value);
                continue;
            }
            if self.engine.ttl_active() && lifetime::is_expired(life, self.engine.expiry_now()) {
                // Dead line: reclaim, don't move — and recycle its slab
                // item (the claim made this thread the handle's owner).
                self.engine.release_value(value);
                continue;
            }
            let pk = self.engine.prepare(Geometry::decode_key(word), ep.geo);
            self.install_migrated(ep, &pk, value, meta, life);
        }
    }

    /// Republish one migrated entry into its target set, preserving its
    /// counter and life word; see `KwWfa::install_migrated` for the
    /// placement contract (fresher copy wins, full sets merge by policy
    /// order through [`SetEngine::place_migrated`]).
    fn install_migrated(
        &self,
        ep: &Epoch<WfscTable>,
        pk: &PreparedKey,
        value: u64,
        meta: u64,
        life: u64,
    ) {
        let k = ep.geo.ways();
        let start = ep.geo.set_of_hash(pk.hash) * k;
        let table = &*ep.table;
        // Resident check decides only ik-equality: Relaxed (see pass 1).
        let resident = self.engine.find_match_masked(
            simd::match_mask(&table.fps[start..start + k], pk.fp),
            |i| {
                table.fps[start + i].load(Ordering::Relaxed) == pk.fp
                    && table.keys[start + i].load(Ordering::Relaxed) == pk.ik
            },
        );
        if resident.is_some() {
            // A fresher insert already landed in the target: the old
            // copy is dropped, and this thread owns its handle.
            self.engine.release_value(value);
            return;
        }
        let mut empties = simd::match_mask(&table.fps[start..start + k], EMPTY);
        while empties != 0 {
            let i = empties.trailing_zeros() as usize;
            empties &= empties - 1;
            if table.fps[start + i]
                .compare_exchange(EMPTY, pk.fp, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                self.publish(table, start + i, pk.ik, value, life, meta);
                self.repair_weight(table, start, pk.ik);
                return;
            }
        }
        // Full target set: merge by policy order. F Relaxed (the claim
        // CAS re-verifies), K Acquire (gates trusting the counter).
        let now = self.engine.now();
        let mut guards = [0u64; MAX_WAYS];
        let mut metas = [u64::MAX; MAX_WAYS];
        for i in 0..k {
            let fp = table.fps[start + i].load(Ordering::Relaxed);
            guards[i] = fp;
            let word = table.keys[start + i].load(Ordering::Acquire);
            if fp != EMPTY && fp != MIGRATING && word != EMPTY && word != RESERVED {
                metas[i] = table.counters[start + i].load(Ordering::Relaxed);
            }
        }
        let Some(victim) = self.engine.place_migrated(k, now, &metas, meta) else {
            // The migrated entry is the policy victim: drop it (and
            // recycle its slab item — this thread owns the handle).
            self.engine.release_value(value);
            return;
        };
        let idx = start + victim;
        if guards[victim] != MIGRATING
            && table.fps[idx]
                .compare_exchange(guards[victim], pk.fp, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
        {
            self.publish(table, idx, pk.ik, value, life, meta);
        } else {
            // Lost the displacement race (or the chosen way is under a
            // byte-mode overwrite claim): the migrated copy is dropped.
            self.engine.release_value(value);
        }
        self.repair_weight(table, start, pk.ik);
    }

    /// Weighted-capacity repair: evict victims (expired lines first, the
    /// policy choice otherwise, sparing the just-inserted key) until the
    /// set's total weight fits its budget. A no-op until any put carries
    /// a non-unit weight; see [`KwWfa`](super::KwWfa) for the protocol
    /// discussion — here a way is freed by CASing its fingerprint back
    /// to 0.
    fn repair_weight(&self, table: &WfscTable, start: usize, keep_ik: u64) {
        if !self.engine.weight_active() {
            return;
        }
        // Publish-then-snapshot: this fence is the one deliberately
        // SeqCst site left by the hot-path ordering audit, and it is
        // irreducible. With only Release/Acquire, two racing puts can
        // each publish, then each snapshot the set *before* observing the
        // other's publish (the classic store-buffer outcome): both
        // repairs compute `total <= budget`, neither evicts, and the
        // quiesced set ends over budget — the PR 3 weight-bound claim
        // would silently become "eventual". SeqCst fences are totally
        // ordered ([atomics.fences]): whichever racing repair's fence is
        // last in that order happens-after every earlier publish-fence
        // pair, so its snapshot counts all racing inserts and restores
        // the budget. Hence the quiesced bound stays *exact* under the
        // weakened publish orderings — the re-derivation demanded by the
        // audit (DESIGN.md §Hot path). Note the fence is gated on
        // weight_active: the unit-weight hot path never executes it.
        std::sync::atomic::fence(Ordering::SeqCst);
        let budget = self.engine.set_budget();
        let ttl_active = self.engine.ttl_active();
        let k = self.engine.ways();
        for _ in 0..k {
            let now = self.engine.now();
            let now_ms = self.engine.expiry_now();
            let mut total = 0u64;
            let mut eligible = [0usize; MAX_WAYS];
            let mut metas = [0u64; MAX_WAYS];
            let mut guards = [0u64; MAX_WAYS];
            let mut n = 0usize;
            let mut expired_pick: Option<(usize, u64)> = None;
            for i in 0..k {
                // F Relaxed (the eviction CAS re-verifies the guard);
                // K Acquire gates trusting the life/counter words.
                let fp = table.fps[start + i].load(Ordering::Relaxed);
                if fp == EMPTY || fp == MIGRATING {
                    continue;
                }
                let key = table.keys[start + i].load(Ordering::Acquire);
                if key == EMPTY || key == RESERVED {
                    continue; // mid-publish: its own put will repair
                }
                let life = table.lives[start + i].load(Ordering::Relaxed);
                total += lifetime::weight_of(life);
                if key == keep_ik {
                    continue; // spare the entry this put installed
                }
                if expired_pick.is_none() && ttl_active && lifetime::is_expired(life, now_ms) {
                    expired_pick = Some((i, fp));
                }
                eligible[n] = i;
                guards[n] = fp;
                metas[n] = table.counters[start + i].load(Ordering::Relaxed);
                n += 1;
            }
            if total <= budget {
                return;
            }
            let (way, guard) = match expired_pick {
                Some(pick) => pick,
                None if n > 0 => {
                    let j = self.engine.select_victim(&metas[..n], now);
                    (eligible[j], guards[j])
                }
                None => return,
            };
            if self.engine.values_active() {
                // Byte mode evicts through a full claim: swap the value
                // word to 0 *before* releasing the line to EMPTY, so the
                // handle is freed exactly once and a later claimer of
                // the empty line never sees (or frees) a stale handle.
                if table.fps[start + way]
                    .compare_exchange(guard, MIGRATING, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    let old = table.values[start + way].swap(EMPTY, Ordering::Relaxed);
                    self.engine.release_value(old);
                    table.keys[start + way].store(EMPTY, Ordering::Relaxed);
                    table.fps[start + way].store(EMPTY, Ordering::Release);
                }
            } else {
                let _ = table.fps[start + way].compare_exchange(
                    guard,
                    EMPTY,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
            }
        }
    }
}

impl Cache for KwWfsc {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key, self.elastic.snapshot().geo))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(
            self.engine.prepare(key, self.elastic.snapshot().geo),
            value,
            EntryOpts::default(),
        );
    }

    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        self.put_prepared(self.engine.prepare(key, self.elastic.snapshot().geo), value, opts);
    }

    fn supports_values(&self) -> bool {
        self.engine.values_active()
    }

    fn put_bytes_with(&self, key: u64, value: &[u8], opts: EntryOpts) -> bool {
        let Some((handle, opts)) = self.engine.alloc_value(value, opts) else {
            return false;
        };
        let pk = self.engine.prepare(key, self.elastic.snapshot().geo);
        if self.put_prepared(pk, handle, opts) {
            true
        } else {
            // The insert was dropped (contention / over-budget): the
            // fresh item never became reachable, recycle it here.
            self.engine.release_value(handle);
            false
        }
    }

    fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        let store = self.engine.values()?;
        // The hit's value word is a generation-stamped handle; a slot
        // recycled between the probe and this read fails the generation
        // check and reports the eviction as a miss.
        store.read(self.get(key)?)
    }

    fn value_bytes(&self) -> u64 {
        self.engine.values().map_or(0, |s| s.used_bytes())
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            keys,
            |&key| key,
            // The lines a get touches: one fingerprint line covers the
            // whole probe for k <= 8; key validation and the value read
            // each land on one more line.
            |set| {
                let base = set * ways;
                engine::prefetch_read(&ep.table.fps[base]);
                engine::prefetch_read(&ep.table.keys[base]);
                engine::prefetch_read(&ep.table.values[base]);
            },
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.0,
            // The lines a put touches first: fingerprints (pass 1/2 scan +
            // claim), keys (pass-1 validation), counters (victim scan).
            |set| {
                let base = set * ways;
                engine::prefetch_read(&ep.table.fps[base]);
                engine::prefetch_read(&ep.table.keys[base]);
                engine::prefetch_read(&ep.table.counters[base]);
            },
            |pk, item| {
                self.put_prepared(pk, item.1, EntryOpts::default());
            },
        );
    }

    fn put_batch_with(&self, items: &[BatchEntry]) {
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.key,
            |set| {
                let base = set * ways;
                engine::prefetch_read(&ep.table.fps[base]);
                engine::prefetch_read(&ep.table.keys[base]);
                engine::prefetch_read(&ep.table.counters[base]);
            },
            |pk, item| {
                self.put_prepared(pk, item.value, item.opts);
            },
        );
    }

    fn capacity(&self) -> usize {
        let ep = self.elastic.snapshot();
        match ep.prev() {
            Some(prev) => ep.geo.capacity().max(prev.geo.capacity()),
            None => ep.geo.capacity(),
        }
    }

    fn requested_capacity(&self) -> usize {
        self.elastic.snapshot().geo.requested_capacity()
    }

    fn len(&self) -> usize {
        let ep = self.elastic.snapshot();
        let mut n = Self::table_len(&ep.table);
        if let Some(prev) = ep.prev() {
            n += Self::table_len(&prev.table);
        }
        n
    }

    fn weight(&self) -> u64 {
        if !self.engine.weight_active() {
            return self.len() as u64;
        }
        let ep = self.elastic.snapshot();
        let k = ep.geo.ways();
        let mut total: u64 =
            (0..ep.geo.num_sets()).map(|s| Self::set_weight(&ep.table, s * k, k)).sum();
        if let Some(prev) = ep.prev() {
            total += (0..prev.geo.num_sets())
                .map(|s| Self::set_weight(&prev.table, s * k, k))
                .sum::<u64>();
        }
        total
    }

    fn name(&self) -> &'static str {
        "KW-WFSC"
    }

    fn supports_lifetime(&self) -> bool {
        true
    }

    fn supports_resize(&self) -> bool {
        true
    }

    fn resize(&self, new_capacity: usize) -> bool {
        while self.elastic.resizing() {
            if self.resize_step(64) == 0 {
                std::thread::yield_now();
            }
        }
        let geo = self.elastic.snapshot().geo;
        self.elastic.begin(geo.resized(new_capacity), |g| WfscTable::new(g.capacity()))
    }

    fn resize_step(&self, max_sets: usize) -> usize {
        self.elastic.step(max_sets, |ep, prev, set| self.migrate_set(ep, prev, set))
    }

    fn resize_pending(&self) -> bool {
        self.elastic.resizing()
    }

    fn sweep_expired(&self, max_sets: usize) -> usize {
        if max_sets == 0 || !self.engine.ttl_active() {
            return 0;
        }
        let ep = self.elastic.snapshot();
        let geo = ep.geo;
        let span = max_sets.min(geo.num_sets());
        let start_set = self.engine.sweep_start(span, geo.num_sets());
        let now_ms = lifetime::now_ms();
        let mut reclaimed = 0;
        for j in 0..span {
            let base = ((start_set + j) % geo.num_sets()) * geo.ways();
            for i in 0..geo.ways() {
                // F Relaxed (the reclaim CAS re-verifies); K Acquire
                // gates trusting the life word.
                let fp = ep.table.fps[base + i].load(Ordering::Relaxed);
                if fp == EMPTY || fp == MIGRATING {
                    continue;
                }
                let key = ep.table.keys[base + i].load(Ordering::Acquire);
                if key == EMPTY || key == RESERVED {
                    continue; // mid-publish
                }
                if !lifetime::is_expired(ep.table.lives[base + i].load(Ordering::Relaxed), now_ms) {
                    continue;
                }
                if self.engine.values_active() {
                    // Byte mode: claim, zero the value word, recycle the
                    // handle, then free the line (see repair_weight).
                    if ep.table.fps[base + i]
                        .compare_exchange(fp, MIGRATING, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok()
                    {
                        let old = ep.table.values[base + i].swap(EMPTY, Ordering::Relaxed);
                        self.engine.release_value(old);
                        ep.table.keys[base + i].store(EMPTY, Ordering::Relaxed);
                        ep.table.fps[base + i].store(EMPTY, Ordering::Release);
                        reclaimed += 1;
                    }
                } else if ep.table.fps[base + i]
                    .compare_exchange(fp, EMPTY, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    reclaimed += 1;
                }
            }
        }
        reclaimed
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let ep = self.elastic.snapshot();
        let start = ep.geo.set_of(key) * ep.geo.ways();
        self.engine.peek_victim_with(
            ep.geo.ways(),
            |i| {
                // Effective key word: EMPTY when the way is free, RESERVED
                // when the fingerprint is claimed (by a publish or a
                // migration) but the key word is not trustworthy, the
                // encoded key otherwise. Advisory preview: F Relaxed, K
                // Acquire (gates the life/counter reads).
                let fp = ep.table.fps[start + i].load(Ordering::Relaxed);
                if fp == EMPTY {
                    EMPTY
                } else if fp == MIGRATING {
                    RESERVED
                } else {
                    let word = ep.table.keys[start + i].load(Ordering::Acquire);
                    if word == EMPTY || word == RESERVED {
                        RESERVED
                    } else {
                        word
                    }
                }
            },
            |i| ep.table.counters[start + i].load(Ordering::Relaxed),
            |i| ep.table.lives[start + i].load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfsc::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfsc::new(64, 4, Policy::Lfu);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwWfsc::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn fifo_evicts_insertion_order_regardless_of_hits() {
        let c = KwWfsc::new(4, 4, Policy::Fifo);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Heavy hits on key 0 must not save it under FIFO.
        for _ in 0..100 {
            c.get(0);
        }
        c.put(100, 100);
        assert_eq!(c.get(0), None);
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfsc::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 3);
                assert_eq!(c.get(key), Some(key * 3), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwWfsc::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key ^ 0xA5);
        }
        let keys: Vec<u64> = (0..800u64).collect();
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwWfsc::new(4096, 8, Policy::Lru);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k + 11)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn expired_entries_probe_as_misses_scalar_and_batched() {
        let c = KwWfsc::new(4096, 8, Policy::Lru);
        c.put_with(1, 10, EntryOpts::ttl(Duration::ZERO));
        c.put_with(2, 20, EntryOpts::ttl(Duration::from_secs(3600)));
        c.put(3, 30);
        assert_eq!(c.get(1), None);
        assert_eq!(c.get(2), Some(20));
        let mut out = Vec::new();
        c.get_batch(&[1, 2, 3], &mut out);
        assert_eq!(out, vec![None, Some(20), Some(30)]);
    }

    #[test]
    fn batched_put_with_carries_per_item_opts() {
        let c = KwWfsc::new(4096, 8, Policy::Lru);
        let items: Vec<BatchEntry> = (0..100u64)
            .map(|k| {
                let opts = if k % 2 == 0 {
                    EntryOpts::ttl(Duration::ZERO)
                } else {
                    EntryOpts::default()
                };
                BatchEntry::new(k, k + 5, opts)
            })
            .collect();
        c.put_batch_with(&items);
        for k in 0..100u64 {
            let expect = if k % 2 == 0 { None } else { Some(k + 5) };
            assert_eq!(c.get(k), expect, "key {k}");
        }
    }

    #[test]
    fn expired_line_is_victim_of_first_resort() {
        let c = KwWfsc::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::ttl(Duration::ZERO));
        for key in 1..4u64 {
            c.put(key, key);
        }
        c.put(100, 100);
        for key in 1..4u64 {
            assert_eq!(c.get(key), Some(key), "immortal {key} must survive");
        }
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn weighted_inserts_respect_set_budget() {
        let c = KwWfsc::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::weight(2));
        c.put_with(1, 1, EntryOpts::weight(2));
        assert_eq!(c.max_set_weight(), 4);
        c.put_with(2, 2, EntryOpts::weight(2));
        assert!(c.max_set_weight() <= 4, "repair must restore the budget");
        assert_eq!(c.get(2), Some(2), "the inserting key is spared");
        // An entry heavier than the whole set is dropped.
        c.put_with(9, 9, EntryOpts::weight(5));
        assert_eq!(c.get(9), None);
    }

    #[test]
    fn sweep_reclaims_expired_lines() {
        let c = KwWfsc::new(4096, 8, Policy::Lru);
        for key in 0..10u64 {
            c.put_with(key, key, EntryOpts::ttl(Duration::ZERO));
        }
        for key in 10..20u64 {
            c.put(key, key);
        }
        assert_eq!(c.sweep_expired(c.geometry().num_sets()), 10);
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn grow_and_shrink_round_trip_keeps_working_set() {
        // 60 keys over 128 sets (1024 capacity, 8 ways) never overflow a
        // set, before, during or after the round trip.
        let c = KwWfsc::new(1024, 8, Policy::Lru);
        for key in 0..60u64 {
            c.put(key, key * 7);
        }
        assert!(c.resize(2048));
        while c.resize_pending() {
            c.resize_step(8);
        }
        assert_eq!(c.capacity(), 2048);
        for key in 0..60u64 {
            assert_eq!(c.get(key), Some(key * 7), "key {key} lost in grow");
        }
        assert!(c.resize(1024));
        while c.resize_pending() {
            c.resize_step(8);
        }
        assert_eq!(c.capacity(), 1024);
        for key in 0..60u64 {
            assert_eq!(c.get(key), Some(key * 7), "key {key} lost in shrink");
        }
        assert_eq!(c.len(), 60);
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwWfsc::new(1024, 8, Policy::Lfu));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn concurrent_batched_get_no_phantoms() {
        // Batched readers race scalar writers; every returned value must
        // belong to the key at its input position.
        let c = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(77 + t);
                for _ in 0..40_000 {
                    let key = rng.below(4096);
                    c.put(key, key.wrapping_mul(31));
                }
            }));
        }
        for t in 0..2u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(177 + t);
                let mut out = Vec::new();
                for _ in 0..1_000 {
                    let keys: Vec<u64> = (0..64).map(|_| rng.below(4096)).collect();
                    out.clear();
                    c.get_batch(&keys, &mut out);
                    assert_eq!(out.len(), keys.len());
                    for (i, &key) in keys.iter().enumerate() {
                        if let Some(v) = out[i] {
                            assert_eq!(v, key.wrapping_mul(31), "phantom at position {i}");
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn byte_values_roundtrip_and_recycle() {
        // Word caches refuse the byte API outright.
        let c = KwWfsc::new(64, 4, Policy::Lru);
        assert!(!c.supports_values());
        assert!(!c.put_bytes(1, b"nope"));
        assert_eq!(c.get_bytes(1), None);

        let c = KwWfsc::with_value_store(64, 4, Policy::Lru, 1 << 22);
        assert!(c.supports_values());
        assert!(c.put_bytes(1, b"hello slab"));
        assert_eq!(c.get_bytes(1).as_deref(), Some(&b"hello slab"[..]));
        let store = c.value_store().unwrap();
        assert_eq!(store.used_bytes(), 64, "10 bytes occupy one 64-byte item");
        // An overwrite recycles the displaced item: ledger swaps to the
        // new size instead of accumulating.
        assert!(c.put_bytes(1, &[7u8; 300]));
        assert_eq!(c.get_bytes(1).unwrap(), vec![7u8; 300]);
        assert_eq!(store.used_bytes(), 320, "300 bytes land in the 320-byte class");
        assert_eq!(c.value_bytes(), 320);
        // The word-path tombstone (put 0) frees the blob too.
        c.put(1, 0);
        assert_eq!(c.get_bytes(1), None);
        assert_eq!(store.used_bytes(), 0, "tombstoned blob recycled");
    }

    #[test]
    fn byte_eviction_recycles_items() {
        // Single set of 4 ways: inserting 40 distinct keys forces ~36
        // pass-3 replacements; every displaced handle must come back to
        // the free list (ledger == live residents only).
        let c = KwWfsc::with_value_store(4, 4, Policy::Lru, 1 << 20);
        for key in 0..40u64 {
            c.put_bytes(key, &[key as u8; 100]);
        }
        let store = c.value_store().unwrap();
        let live = (0..40u64).filter(|&k| c.get_bytes(k).is_some()).count() as u64;
        assert!(live <= 4);
        assert_eq!(store.used_bytes(), live * 128, "only residents hold items");
        let stats = store.stats();
        for cl in &stats.classes {
            assert_eq!(cl.carved, cl.live + cl.free, "free-list ledger balances");
        }
    }

    #[test]
    fn byte_values_survive_resize_and_ledger_balances() {
        // Migration republishes handles (never the bytes): blobs survive
        // a grow verbatim and the slab ledger still balances after the
        // old epoch retires.
        let c = KwWfsc::with_value_store(1024, 8, Policy::Lru, 1 << 22);
        for key in 0..60u64 {
            assert!(c.put_bytes(key, &[key as u8; 200]));
        }
        assert!(c.resize(2048));
        while c.resize_pending() {
            c.resize_step(8);
        }
        for key in 0..60u64 {
            assert_eq!(c.get_bytes(key).unwrap(), vec![key as u8; 200], "key {key} lost in grow");
        }
        let store = c.value_store().unwrap();
        assert_eq!(store.used_bytes(), 60 * 256, "200 bytes land in the 256-byte class");
        let stats = store.stats();
        for cl in &stats.classes {
            assert_eq!(cl.carved, cl.live + cl.free, "free-list ledger balances");
        }
    }

    #[test]
    fn property_single_thread_model() {
        check("wfsc-model", 20, |rng| {
            let c = KwWfsc::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
