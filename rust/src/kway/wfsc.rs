//! KW-WFSC — K-Way cache, Wait-Free with Separate Counters (paper
//! Algorithms 4–6).
//!
//! Structure-of-arrays: the whole cache is four flat atomic arrays —
//! fingerprints, counters, keys, values — indexed `set * k + way`. A probe
//! scans only the *fingerprint* slice of the set and a victim search scans
//! only the *counter* slice, so for k ≤ 8 each scan touches a single
//! 64-byte cache line. That contiguity is exactly the optimization the
//! paper introduces WFSC for; the cost is that a replacement needs several
//! atomic operations (one CAS + three stores here, "three atomic
//! operations" in the paper's Java version) instead of WFA's single
//! node-swap CAS.
//!
//! Publication protocol: a put claims the way by CASing the fingerprint
//! word (0 = empty), then publishes value and counter, and stores the key
//! word last. Readers match on the fingerprint but *validate on the key
//! word* and re-validate it after reading the value, so fingerprint
//! collisions and mid-replace reads are both detected and skipped.

use super::geometry::{Geometry, EMPTY};
use super::wfa::MAX_WAYS;
use super::with_thread_rng;
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::util::hash;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};

/// Wait-free separate-counters k-way cache.
pub struct KwWfsc {
    geo: Geometry,
    policy: Policy,
    clock: LogicalClock,
    /// Non-zero fingerprint per occupied way; 0 = empty.
    fps: Box<[AtomicU64]>,
    /// Policy metadata (the paper's separate counters array).
    counters: Box<[AtomicU64]>,
    /// Encoded key words (validation + exact identification).
    keys: Box<[AtomicU64]>,
    /// Values.
    values: Box<[AtomicU64]>,
}

fn atomic_array(n: usize) -> Box<[AtomicU64]> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl KwWfsc {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        assert!(ways <= MAX_WAYS, "ways must be <= {MAX_WAYS}");
        let geo = Geometry::new(capacity, ways);
        let n = geo.capacity();
        Self {
            geo,
            policy,
            clock: LogicalClock::new(),
            fps: atomic_array(n),
            counters: atomic_array(n),
            keys: atomic_array(n),
            values: atomic_array(n),
        }
    }

    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    #[inline]
    fn touch(&self, idx: usize, now: u64) {
        let meta = &self.counters[idx];
        match self.policy {
            Policy::Lru => meta.store(now, Ordering::Relaxed),
            Policy::Lfu => {
                meta.fetch_add(1, Ordering::Relaxed);
            }
            Policy::Hyperbolic => {
                let old = meta.load(Ordering::Relaxed);
                let new = self.policy.on_hit_meta(old, now);
                let _ = meta.compare_exchange_weak(old, new, Ordering::Relaxed, Ordering::Relaxed);
            }
            Policy::Fifo | Policy::Random => {}
        }
    }

    /// Publish (value, counter, key) into a way whose fingerprint we own.
    #[inline]
    fn publish(&self, idx: usize, ik: u64, value: u64, now: u64) {
        self.values[idx].store(value, Ordering::Release);
        self.counters[idx].store(self.policy.initial_meta(now), Ordering::Release);
        self.keys[idx].store(ik, Ordering::Release);
    }
}

impl Cache for KwWfsc {
    fn get(&self, key: u64) -> Option<u64> {
        let ik = Geometry::encode_key(key);
        let fp = hash::fingerprint(key);
        let now = self.clock.tick();
        let slots = self.geo.slots_of(self.geo.set_of(key));
        // Contiguous fingerprint scan (Alg. 5): one cache line for k <= 8.
        for idx in slots {
            if self.fps[idx].load(Ordering::Acquire) == fp
                && self.keys[idx].load(Ordering::Acquire) == ik
            {
                let value = self.values[idx].load(Ordering::Acquire);
                if self.keys[idx].load(Ordering::Acquire) == ik {
                    self.touch(idx, now);
                    return Some(value);
                }
            }
        }
        None
    }

    fn put(&self, key: u64, value: u64) {
        let ik = Geometry::encode_key(key);
        let fp = hash::fingerprint(key);
        let now = self.clock.tick();
        let slots = self.geo.slots_of(self.geo.set_of(key));

        // Pass 1 (Alg. 6 lines 3–9): overwrite an existing entry.
        for idx in slots.clone() {
            if self.fps[idx].load(Ordering::Acquire) == fp
                && self.keys[idx].load(Ordering::Acquire) == ik
            {
                self.values[idx].store(value, Ordering::Release);
                self.touch(idx, now);
                return;
            }
        }

        // Pass 2: claim an empty way (fingerprint CAS 0 -> fp).
        for idx in slots.clone() {
            if self.fps[idx].load(Ordering::Acquire) == EMPTY
                && self.fps[idx]
                    .compare_exchange(EMPTY, fp, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                self.publish(idx, ik, value, now);
                return;
            }
        }

        // Pass 3 (Alg. 6 lines 11–15): select the victim from the counters
        // array alone — this scan never touches keys or values — then claim
        // it by CASing its fingerprint. A failed CAS means a concurrent
        // replacement won the way; like the paper we give up rather than
        // loop (wait-free).
        let start = slots.start;
        let k = slots.len();
        let mut metas = [0u64; MAX_WAYS];
        let mut snap_fps = [0u64; MAX_WAYS];
        for i in 0..k {
            metas[i] = self.counters[start + i].load(Ordering::Relaxed);
            snap_fps[i] = self.fps[start + i].load(Ordering::Acquire);
        }
        let vi = with_thread_rng(|rng| self.policy.select_victim(&metas[..k], now, rng));
        let idx = start + vi;
        if self.fps[idx]
            .compare_exchange(snap_fps[vi], fp, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            self.publish(idx, ik, value, now);
        }
    }

    fn capacity(&self) -> usize {
        self.geo.capacity()
    }

    fn len(&self) -> usize {
        self.fps.iter().filter(|f| f.load(Ordering::Relaxed) != EMPTY).count()
    }

    fn name(&self) -> &'static str {
        "KW-WFSC"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let slots = self.geo.slots_of(self.geo.set_of(key));
        let now = self.clock.now();
        let start = slots.start;
        let k = slots.len();
        let mut metas = [0u64; MAX_WAYS];
        for i in 0..k {
            if self.fps[start + i].load(Ordering::Acquire) == EMPTY {
                return None; // room available
            }
            metas[i] = self.counters[start + i].load(Ordering::Relaxed);
        }
        let vi = with_thread_rng(|rng| self.policy.select_victim(&metas[..k], now, rng));
        let word = self.keys[start + vi].load(Ordering::Acquire);
        (word >= 2).then(|| Geometry::decode_key(word))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfsc::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfsc::new(64, 4, Policy::Lfu);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwWfsc::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn fifo_evicts_insertion_order_regardless_of_hits() {
        let c = KwWfsc::new(4, 4, Policy::Fifo);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Heavy hits on key 0 must not save it under FIFO.
        for _ in 0..100 {
            c.get(0);
        }
        c.put(100, 100);
        assert_eq!(c.get(0), None);
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfsc::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 3);
                assert_eq!(c.get(key), Some(key * 3), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwWfsc::new(1024, 8, Policy::Lfu));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn property_single_thread_model() {
        check("wfsc-model", 20, |rng| {
            let c = KwWfsc::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
