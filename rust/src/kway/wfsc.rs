//! KW-WFSC — K-Way cache, Wait-Free with Separate Counters (paper
//! Algorithms 4–6).
//!
//! Structure-of-arrays: the whole cache is four flat atomic arrays —
//! fingerprints, counters, keys, values — indexed `set * k + way`. A probe
//! scans only the *fingerprint* slice of the set and a victim search scans
//! only the *counter* slice, so for k ≤ 8 each scan touches a single
//! 64-byte cache line. That contiguity is exactly the optimization the
//! paper introduces WFSC for; the cost is that a replacement needs several
//! atomic operations (one CAS + three stores here, "three atomic
//! operations" in the paper's Java version) instead of WFA's single
//! node-swap CAS.
//!
//! Publication protocol: a put claims the way by CASing the fingerprint
//! word (0 = empty), then publishes value and counter, and stores the key
//! word last. Readers match on the fingerprint but *validate on the key
//! word* and re-validate after reading the value, so fingerprint
//! collisions and mid-replace reads are both detected and skipped.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the SoA storage and the fingerprint claim/publish protocol. The
//! SoA layout also makes WFSC the best batching target: one prefetch of
//! the set's fingerprint line covers the whole probe.

use super::engine::{self, PreparedKey, SetEngine};
use super::geometry::{Geometry, EMPTY, RESERVED};
use crate::policy::Policy;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};

/// Wait-free separate-counters k-way cache.
pub struct KwWfsc {
    engine: SetEngine,
    /// Non-zero fingerprint per occupied way; 0 = empty.
    fps: Box<[AtomicU64]>,
    /// Policy metadata (the paper's separate counters array).
    counters: Box<[AtomicU64]>,
    /// Encoded key words (validation + exact identification).
    keys: Box<[AtomicU64]>,
    /// Values.
    values: Box<[AtomicU64]>,
}

fn atomic_array(n: usize) -> Box<[AtomicU64]> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl KwWfsc {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let engine = SetEngine::new(capacity, ways, policy);
        let n = engine.geometry().capacity();
        Self {
            engine,
            fps: atomic_array(n),
            counters: atomic_array(n),
            keys: atomic_array(n),
            values: atomic_array(n),
        }
    }

    pub fn geometry(&self) -> Geometry {
        self.engine.geometry()
    }

    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    /// Publish (value, counter, key) into a way whose fingerprint we own.
    #[inline]
    fn publish(&self, idx: usize, ik: u64, value: u64, now: u64) {
        self.values[idx].store(value, Ordering::Release);
        self.counters[idx].store(self.engine.initial_meta(now), Ordering::Release);
        self.keys[idx].store(ik, Ordering::Release);
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths).
    #[inline]
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let start = pk.set * self.engine.geometry().ways();
        let k = self.engine.geometry().ways();
        // Contiguous fingerprint scan (Alg. 5): one cache line for k <= 8.
        let (way, value) = self.engine.probe_get(
            k,
            |i| {
                self.fps[start + i].load(Ordering::Acquire) == pk.fp
                    && self.keys[start + i].load(Ordering::Acquire) == pk.ik
            },
            |i| self.values[start + i].load(Ordering::Acquire),
        )?;
        self.engine.touch_atomic(&self.counters[start + way], now);
        Some(value)
    }

    /// `put` with the hashing already done.
    fn put_prepared(&self, pk: PreparedKey, value: u64) {
        let now = self.engine.tick();
        let start = pk.set * self.engine.geometry().ways();
        let k = self.engine.geometry().ways();

        // Pass 1 (Alg. 6 lines 3–9): overwrite an existing entry.
        if let Some(i) = self.engine.find_match(k, |i| {
            self.fps[start + i].load(Ordering::Acquire) == pk.fp
                && self.keys[start + i].load(Ordering::Acquire) == pk.ik
        }) {
            self.values[start + i].store(value, Ordering::Release);
            self.engine.touch_atomic(&self.counters[start + i], now);
            return;
        }

        // Pass 2: claim an empty way (fingerprint CAS 0 -> fp).
        for i in 0..k {
            if self.fps[start + i].load(Ordering::Acquire) == EMPTY
                && self.fps[start + i]
                    .compare_exchange(EMPTY, pk.fp, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                self.publish(start + i, pk.ik, value, now);
                return;
            }
        }

        // Pass 3 (Alg. 6 lines 11–15): select the victim from the counters
        // array alone — this scan never touches keys or values — then claim
        // it by CASing its fingerprint. A failed CAS means a concurrent
        // replacement won the way; like the paper we give up rather than
        // loop (wait-free).
        let choice = self.engine.choose_victim(k, now, |i| {
            (
                self.fps[start + i].load(Ordering::Acquire),
                self.counters[start + i].load(Ordering::Relaxed),
            )
        });
        let idx = start + choice.way;
        if self.fps[idx]
            .compare_exchange(choice.guard, pk.fp, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            self.publish(idx, pk.ik, value, now);
        }
    }
}

impl Cache for KwWfsc {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(self.engine.prepare(key), value)
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        let ways = self.engine.geometry().ways();
        self.engine.for_batch(
            keys,
            |&key| key,
            // The lines a get touches: one fingerprint line covers the
            // whole probe for k <= 8; key validation and the value read
            // each land on one more line.
            |set| {
                let base = set * ways;
                engine::prefetch_read(&self.fps[base]);
                engine::prefetch_read(&self.keys[base]);
                engine::prefetch_read(&self.values[base]);
            },
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        let ways = self.engine.geometry().ways();
        self.engine.for_batch(
            items,
            |item| item.0,
            // The lines a put touches first: fingerprints (pass 1/2 scan +
            // claim), keys (pass-1 validation), counters (victim scan).
            |set| {
                let base = set * ways;
                engine::prefetch_read(&self.fps[base]);
                engine::prefetch_read(&self.keys[base]);
                engine::prefetch_read(&self.counters[base]);
            },
            |pk, item| self.put_prepared(pk, item.1),
        );
    }

    fn capacity(&self) -> usize {
        self.engine.geometry().capacity()
    }

    fn len(&self) -> usize {
        self.fps.iter().filter(|f| f.load(Ordering::Relaxed) != EMPTY).count()
    }

    fn name(&self) -> &'static str {
        "KW-WFSC"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let start = self.engine.geometry().set_of(key) * self.engine.geometry().ways();
        self.engine.peek_victim_with(
            self.engine.geometry().ways(),
            |i| {
                // Effective key word: EMPTY when the way is free, RESERVED
                // when the fingerprint is claimed but the key word is not
                // yet published, the encoded key otherwise.
                if self.fps[start + i].load(Ordering::Acquire) == EMPTY {
                    EMPTY
                } else {
                    let word = self.keys[start + i].load(Ordering::Acquire);
                    if word == EMPTY || word == RESERVED {
                        RESERVED
                    } else {
                        word
                    }
                }
            },
            |i| self.counters[start + i].load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfsc::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfsc::new(64, 4, Policy::Lfu);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let c = KwWfsc::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn fifo_evicts_insertion_order_regardless_of_hits() {
        let c = KwWfsc::new(4, 4, Policy::Fifo);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Heavy hits on key 0 must not save it under FIFO.
        for _ in 0..100 {
            c.get(0);
        }
        c.put(100, 100);
        assert_eq!(c.get(0), None);
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfsc::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 3);
                assert_eq!(c.get(key), Some(key * 3), "policy {p:?}");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwWfsc::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key ^ 0xA5);
        }
        let keys: Vec<u64> = (0..800u64).collect();
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwWfsc::new(4096, 8, Policy::Lru);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k + 11)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        let c = Arc::new(KwWfsc::new(1024, 8, Policy::Lfu));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn concurrent_batched_get_no_phantoms() {
        // Batched readers race scalar writers; every returned value must
        // belong to the key at its input position.
        let c = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(77 + t);
                for _ in 0..40_000 {
                    let key = rng.below(4096);
                    c.put(key, key.wrapping_mul(31));
                }
            }));
        }
        for t in 0..2u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(177 + t);
                let mut out = Vec::new();
                for _ in 0..1_000 {
                    let keys: Vec<u64> = (0..64).map(|_| rng.below(4096)).collect();
                    out.clear();
                    c.get_batch(&keys, &mut out);
                    assert_eq!(out.len(), keys.len());
                    for (i, &key) in keys.iter().enumerate() {
                        if let Some(v) = out[i] {
                            assert_eq!(v, key.wrapping_mul(31), "phantom at position {i}");
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn property_single_thread_model() {
        check("wfsc-model", 20, |rng| {
            let c = KwWfsc::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
