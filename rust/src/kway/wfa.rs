//! KW-WFA — K-Way cache, Wait-Free Array (paper Algorithms 1–3).
//!
//! Array-of-structs: each way is a `Way { key, value, meta }` triple of
//! atomic words. The paper's Java version holds an
//! `AtomicReferenceArray<Node>` and swaps whole nodes with one CAS, leaning
//! on the GC to reclaim the replaced node. Rust has no GC, so a way is
//! *claimed* by CASing its key word to a `RESERVED` sentinel, the value and
//! metadata words are published, and the key word is released last; readers
//! re-validate the key word after reading the value so a torn (mid-replace)
//! read is detected and skipped. Every operation is a bounded number of
//! steps — no locks, no retry loops.
//!
//! The AoS layout is deliberate: scanning the set strides over the ways'
//! key words (24-byte stride), reproducing the scattered-reads behaviour
//! the paper attributes to WFA when comparing it against WFSC's contiguous
//! fingerprint array.

use super::geometry::{Geometry, EMPTY, RESERVED};
use super::with_thread_rng;
use crate::policy::Policy;
use crate::util::clock::LogicalClock;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};

/// Upper bound on ways so victim scans can use stack buffers.
pub(crate) const MAX_WAYS: usize = 128;

struct Way {
    key: AtomicU64,
    value: AtomicU64,
    meta: AtomicU64,
}

impl Way {
    fn new() -> Self {
        Self {
            key: AtomicU64::new(EMPTY),
            value: AtomicU64::new(0),
            meta: AtomicU64::new(0),
        }
    }
}

/// Wait-free array k-way cache.
pub struct KwWfa {
    geo: Geometry,
    policy: Policy,
    clock: LogicalClock,
    ways: Box<[Way]>,
}

impl KwWfa {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        assert!(ways <= MAX_WAYS, "ways must be <= {MAX_WAYS}");
        let geo = Geometry::new(capacity, ways);
        let slots = (0..geo.capacity()).map(|_| Way::new()).collect();
        Self { geo, policy, clock: LogicalClock::new(), ways: slots }
    }

    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    #[inline]
    fn set_ways(&self, set: usize) -> &[Way] {
        &self.ways[self.geo.slots_of(set)]
    }

    /// Apply the policy's on-hit metadata update with the cheapest atomic
    /// op that implements it. A lost race here only blurs the recency /
    /// frequency signal by one access — the same semantics as the paper's
    /// non-synchronized Java counter updates.
    #[inline]
    fn touch(&self, meta: &AtomicU64, now: u64) {
        match self.policy {
            Policy::Lru => meta.store(now, Ordering::Relaxed),
            Policy::Lfu => {
                meta.fetch_add(1, Ordering::Relaxed);
            }
            Policy::Hyperbolic => {
                let old = meta.load(Ordering::Relaxed);
                let new = self.policy.on_hit_meta(old, now);
                // Single CAS attempt; on contention we drop the update.
                let _ = meta.compare_exchange_weak(old, new, Ordering::Relaxed, Ordering::Relaxed);
            }
            Policy::Fifo | Policy::Random => {}
        }
    }
}

impl Cache for KwWfa {
    fn get(&self, key: u64) -> Option<u64> {
        let ik = Geometry::encode_key(key);
        let now = self.clock.tick();
        for way in self.set_ways(self.geo.set_of(key)) {
            if way.key.load(Ordering::Acquire) == ik {
                let value = way.value.load(Ordering::Acquire);
                // Re-validate: if the key word changed while we read the
                // value, a concurrent put replaced this way — the value we
                // read may belong to the new entry, so skip it.
                if way.key.load(Ordering::Acquire) == ik {
                    self.touch(&way.meta, now);
                    return Some(value);
                }
            }
        }
        None
    }

    fn put(&self, key: u64, value: u64) {
        let ik = Geometry::encode_key(key);
        let now = self.clock.tick();
        let set = self.set_ways(self.geo.set_of(key));

        // Pass 1 (Alg. 3 lines 3–6): overwrite an existing entry.
        for way in set {
            if way.key.load(Ordering::Acquire) == ik {
                way.value.store(value, Ordering::Release);
                self.touch(&way.meta, now);
                return;
            }
        }

        // Pass 2 (Alg. 3 lines 12–16): claim an empty way.
        for way in set {
            if way.key.load(Ordering::Acquire) == EMPTY
                && way
                    .key
                    .compare_exchange(EMPTY, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                way.value.store(value, Ordering::Release);
                way.meta.store(self.policy.initial_meta(now), Ordering::Release);
                way.key.store(ik, Ordering::Release);
                return;
            }
        }

        // Pass 3 (Alg. 3 lines 7–11): evict the policy victim. Snapshot the
        // metadata, pick the victim, then try to claim it with a single
        // CAS. If the CAS fails, another thread is mutating this way
        // concurrently — like the paper's WFA we simply give up (the cache
        // is allowed to drop an insert under contention; it is a cache).
        let mut metas = [0u64; MAX_WAYS];
        let mut keys = [0u64; MAX_WAYS];
        let k = set.len();
        for i in 0..k {
            keys[i] = set[i].key.load(Ordering::Acquire);
            metas[i] = set[i].meta.load(Ordering::Relaxed);
            if keys[i] == RESERVED {
                // Mid-publish way: never pick it as the victim.
                metas[i] = u64::MAX;
            }
        }
        let vi =
            with_thread_rng(|rng| self.policy.select_victim(&metas[..k], now, rng));
        if keys[vi] == RESERVED {
            return;
        }
        let way = &set[vi];
        if way
            .key
            .compare_exchange(keys[vi], RESERVED, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            way.value.store(value, Ordering::Release);
            way.meta.store(self.policy.initial_meta(now), Ordering::Release);
            way.key.store(ik, Ordering::Release);
        }
    }

    fn capacity(&self) -> usize {
        self.geo.capacity()
    }

    fn len(&self) -> usize {
        self.ways
            .iter()
            .filter(|w| {
                let k = w.key.load(Ordering::Relaxed);
                k != EMPTY && k != RESERVED
            })
            .count()
    }

    fn name(&self) -> &'static str {
        "KW-WFA"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let set = self.set_ways(self.geo.set_of(key));
        let now = self.clock.now();
        let k = set.len();
        let mut metas = [0u64; MAX_WAYS];
        let mut keys = [0u64; MAX_WAYS];
        for i in 0..k {
            keys[i] = set[i].key.load(Ordering::Acquire);
            if keys[i] == EMPTY {
                return None; // room available, no eviction needed
            }
            metas[i] = set[i].meta.load(Ordering::Relaxed);
            if keys[i] == RESERVED {
                metas[i] = u64::MAX;
            }
        }
        let vi = with_thread_rng(|rng| self.policy.select_victim(&metas[..k], now, rng));
        (keys[vi] != RESERVED).then(|| Geometry::decode_key(keys[vi]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        // Single-set cache: capacity 4, 4 ways.
        let c = KwWfa::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Touch 0..3 except 2, then insert a new key: 2 must be evicted.
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
        for key in [0u64, 1, 3] {
            assert_eq!(c.get(key), Some(key), "key {key} should have survived");
        }
    }

    #[test]
    fn lfu_keeps_frequent() {
        let c = KwWfa::new(4, 4, Policy::Lfu);
        for key in 0..4u64 {
            c.put(key, key);
        }
        for _ in 0..10 {
            c.get(0);
            c.get(1);
            c.get(2);
        }
        c.put(100, 100); // victim must be 3 (count 1)
        assert_eq!(c.get(3), None);
        assert_eq!(c.get(0), Some(0));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfa::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 2);
                assert_eq!(c.get(key), Some(key * 2), "policy {p:?}: fresh insert readable");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        // Values always equal keys; any get must return its own key.
        let c = Arc::new(KwWfa::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn property_single_thread_model() {
        // Against a naive model: any key the model knows MUST come back
        // with the right value or not at all (never a wrong value), and a
        // get right after its put must hit (single-threaded).
        check("wfa-model", 20, |rng| {
            let c = KwWfa::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
