//! KW-WFA — K-Way cache, Wait-Free Array (paper Algorithms 1–3).
//!
//! Array-of-structs: each way is a `Way { key, value, meta }` triple of
//! atomic words. The paper's Java version holds an
//! `AtomicReferenceArray<Node>` and swaps whole nodes with one CAS, leaning
//! on the GC to reclaim the replaced node. Rust has no GC, so a way is
//! *claimed* by CASing its key word to a `RESERVED` sentinel, the value and
//! metadata words are published, and the key word is released last; readers
//! re-validate the key word after reading the value so a torn (mid-replace)
//! read is detected and skipped. Every operation is a bounded number of
//! steps — no locks, no retry loops.
//!
//! The AoS layout is deliberate: scanning the set strides over the ways'
//! key words (24-byte stride), reproducing the scattered-reads behaviour
//! the paper attributes to WFA when comparing it against WFSC's contiguous
//! fingerprint array.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the AoS storage and the CAS claim/publish protocol.

use super::engine::{self, PreparedKey, SetEngine};
use super::geometry::{Geometry, EMPTY, RESERVED};
use crate::policy::Policy;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};

struct Way {
    key: AtomicU64,
    value: AtomicU64,
    meta: AtomicU64,
}

impl Way {
    fn new() -> Self {
        Self {
            key: AtomicU64::new(EMPTY),
            value: AtomicU64::new(0),
            meta: AtomicU64::new(0),
        }
    }
}

/// Wait-free array k-way cache.
pub struct KwWfa {
    engine: SetEngine,
    ways: Box<[Way]>,
}

impl KwWfa {
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let engine = SetEngine::new(capacity, ways, policy);
        let slots = (0..engine.geometry().capacity()).map(|_| Way::new()).collect();
        Self { engine, ways: slots }
    }

    pub fn geometry(&self) -> Geometry {
        self.engine.geometry()
    }

    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    #[inline]
    fn set_ways(&self, set: usize) -> &[Way] {
        &self.ways[self.engine.geometry().slots_of(set)]
    }

    /// Prefetch the lines a set scan strides over: a `Way` is 24 bytes, so
    /// an 8-way set spans three cache lines (first / middle / last way).
    #[inline]
    fn prefetch_set(&self, set: usize, ways: usize) {
        let base = set * ways;
        engine::prefetch_read(&self.ways[base]);
        engine::prefetch_read(&self.ways[base + ways / 2]);
        engine::prefetch_read(&self.ways[base + ways - 1]);
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths).
    #[inline]
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let set = self.set_ways(pk.set);
        let (way, value) = self.engine.probe_get(
            set.len(),
            |i| set[i].key.load(Ordering::Acquire) == pk.ik,
            |i| set[i].value.load(Ordering::Acquire),
        )?;
        self.engine.touch_atomic(&set[way].meta, now);
        Some(value)
    }

    /// `put` with the hashing already done.
    fn put_prepared(&self, pk: PreparedKey, value: u64) {
        let now = self.engine.tick();
        let set = self.set_ways(pk.set);

        // Pass 1 (Alg. 3 lines 3–6): overwrite an existing entry.
        if let Some(i) = self
            .engine
            .find_match(set.len(), |i| set[i].key.load(Ordering::Acquire) == pk.ik)
        {
            set[i].value.store(value, Ordering::Release);
            self.engine.touch_atomic(&set[i].meta, now);
            return;
        }

        // Pass 2 (Alg. 3 lines 12–16): claim an empty way.
        for way in set {
            if way.key.load(Ordering::Acquire) == EMPTY
                && way
                    .key
                    .compare_exchange(EMPTY, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                way.value.store(value, Ordering::Release);
                way.meta.store(self.engine.initial_meta(now), Ordering::Release);
                way.key.store(pk.ik, Ordering::Release);
                return;
            }
        }

        // Pass 3 (Alg. 3 lines 7–11): evict the policy victim. Snapshot the
        // set, pick the victim, then try to claim it with a single CAS. If
        // the CAS fails, another thread is mutating this way concurrently —
        // like the paper's WFA we simply give up (the cache is allowed to
        // drop an insert under contention; it is a cache).
        let choice = self.engine.choose_victim(set.len(), now, |i| {
            let key = set[i].key.load(Ordering::Acquire);
            let meta = if key == RESERVED {
                u64::MAX // mid-publish way: never pick it as the victim
            } else {
                set[i].meta.load(Ordering::Relaxed)
            };
            (key, meta)
        });
        if choice.guard == RESERVED {
            return;
        }
        let way = &set[choice.way];
        if way
            .key
            .compare_exchange(choice.guard, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            way.value.store(value, Ordering::Release);
            way.meta.store(self.engine.initial_meta(now), Ordering::Release);
            way.key.store(pk.ik, Ordering::Release);
        }
    }
}

impl Cache for KwWfa {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(self.engine.prepare(key), value)
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        let ways = self.engine.geometry().ways();
        self.engine.for_batch(
            keys,
            |&key| key,
            |set| self.prefetch_set(set, ways),
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        let ways = self.engine.geometry().ways();
        self.engine.for_batch(
            items,
            |item| item.0,
            |set| self.prefetch_set(set, ways),
            |pk, item| self.put_prepared(pk, item.1),
        );
    }

    fn capacity(&self) -> usize {
        self.engine.geometry().capacity()
    }

    fn len(&self) -> usize {
        self.ways
            .iter()
            .filter(|w| {
                let k = w.key.load(Ordering::Relaxed);
                k != EMPTY && k != RESERVED
            })
            .count()
    }

    fn name(&self) -> &'static str {
        "KW-WFA"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let set = self.set_ways(self.engine.geometry().set_of(key));
        self.engine.peek_victim_with(
            set.len(),
            |i| set[i].key.load(Ordering::Acquire),
            |i| set[i].meta.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        // Single-set cache: capacity 4, 4 ways.
        let c = KwWfa::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Touch 0..3 except 2, then insert a new key: 2 must be evicted.
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
        for key in [0u64, 1, 3] {
            assert_eq!(c.get(key), Some(key), "key {key} should have survived");
        }
    }

    #[test]
    fn lfu_keeps_frequent() {
        let c = KwWfa::new(4, 4, Policy::Lfu);
        for key in 0..4u64 {
            c.put(key, key);
        }
        for _ in 0..10 {
            c.get(0);
            c.get(1);
            c.get(2);
        }
        c.put(100, 100); // victim must be 3 (count 1)
        assert_eq!(c.get(3), None);
        assert_eq!(c.get(0), Some(0));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfa::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 2);
                assert_eq!(c.get(key), Some(key * 2), "policy {p:?}: fresh insert readable");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwWfa::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key + 7);
        }
        let keys: Vec<u64> = (0..800u64).collect(); // half hits, half misses
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwWfa::new(4096, 8, Policy::Lfu);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k * 3)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        // Values always equal keys; any get must return its own key.
        let c = Arc::new(KwWfa::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn property_single_thread_model() {
        // Against a naive model: any key the model knows MUST come back
        // with the right value or not at all (never a wrong value), and a
        // get right after its put must hit (single-threaded).
        check("wfa-model", 20, |rng| {
            let c = KwWfa::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
