//! KW-WFA — K-Way cache, Wait-Free Array (paper Algorithms 1–3).
//!
//! Array-of-structs: each way is a `Way { key, value, meta, life }`
//! quadruple of atomic words. The paper's Java version holds an
//! `AtomicReferenceArray<Node>` and swaps whole nodes with one CAS, leaning
//! on the GC to reclaim the replaced node. Rust has no GC, so a way is
//! *claimed* by CASing its key word to a `RESERVED` sentinel, the value,
//! metadata and life words are published, and the key word is released
//! last; readers re-validate the key word after reading the value so a
//! torn (mid-replace) read is detected and skipped. Every operation is a
//! bounded number of steps — no locks, no retry loops.
//!
//! The AoS layout is deliberate: scanning the set strides over the ways'
//! key words (32-byte stride), reproducing the scattered-reads behaviour
//! the paper attributes to WFA when comparing it against WFSC's contiguous
//! fingerprint array.
//!
//! The probe / victim / touch logic lives in [`SetEngine`]; this file owns
//! only the AoS storage and the CAS claim/publish protocol — including
//! the lifetime dimension (the `life` word packs the expiry deadline and
//! the weight; DESIGN.md §Expiration, §Weighted capacity) and the
//! **elastic-resize dimension**: the table lives behind an epoch-stamped
//! [`Elastic`] holder, a migration *claims* each source line with the
//! same CAS-to-`RESERVED` protocol an eviction uses and republishes it
//! into the grown (or shrunk) table, readers that miss in the target
//! table fall through to the source table while the split watermark is
//! advancing, and writers drain their key's source set before inserting
//! so no admitted entry is ever lost (DESIGN.md §Elastic resizing).
//!
//! # Memory ordering (safety argument)
//!
//! The full per-edge derivation lives in the `wfsc` module doc; WFA is
//! the same protocol with the key word playing both roles (claim guard
//! *and* identity), which makes the mapping:
//!
//! * **Publish**: value `Release` (probe re-validation anchor), meta and
//!   life `Relaxed`, key `Release` last — the trailing key-Release
//!   covers the Relaxed stores for any thread that key-Acquires.
//! * **Probe**: key `Acquire` / value `Acquire`, match re-verified after
//!   the value read. The value-Release/Acquire edge makes a replacer's
//!   CAS-to-`RESERVED` (sequenced before its value store) visible to
//!   the re-validation, which is what rejects torn reads.
//! * **Claims**: every CAS on the key word is `AcqRel`. The Acquire
//!   half does double duty here: it pins the subsequent publish stores
//!   after ownership *and*, because the claimed word is the very word
//!   the previous publisher Release-stored last, it hands the claimer a
//!   happens-before edge to the old entry's value/meta/life — so a
//!   migration may read them `Relaxed` once its claim CAS succeeds.
//!   Pre-CAS peeks are `Relaxed` (the CAS re-verifies).
//! * **Snapshots** (victim scan, repair, sweep, peek): the key word
//!   stays `Acquire` wherever a non-sentinel key gates interpreting the
//!   life or meta words; quiesced diagnostics use `Relaxed`.
//! * **`repair_weight`'s `SeqCst` fence** is irreducible — see
//!   `KwWfsc::repair_weight` for the store-buffer argument; it is the
//!   only SeqCst in either wait-free variant and never runs on the
//!   unit-weight path.

use super::alloc::AlignedSlice;
use super::engine::{self, Elastic, Epoch, PreparedKey, SetEngine, MAX_WAYS};
use super::geometry::{Geometry, EMPTY, RESERVED};
use super::slab::SlabStore;
use crate::lifetime::{self, BatchEntry, EntryOpts};
use crate::policy::Policy;
use crate::Cache;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct Way {
    key: AtomicU64,
    value: AtomicU64,
    meta: AtomicU64,
    /// Packed (weight, expiry) life word; published under the same
    /// claim/publish protocol as the value.
    life: AtomicU64,
}

/// One geometry epoch's storage: the flat way array, cache-line-aligned
/// (`kway::alloc`) so a set of 32-byte `Way`s starts on a line boundary
/// and the stride scan touches exactly `ways/2` lines, never a straddling
/// extra one.
struct WfaTable {
    ways: AlignedSlice<Way>,
}

impl WfaTable {
    fn new(capacity: usize) -> Self {
        // SAFETY: an all-zero `Way` is exactly the initial state (key =
        // EMPTY = 0, value/meta/life 0), and `Way` has no Drop.
        Self { ways: unsafe { AlignedSlice::new_zeroed(capacity) } }
    }

    #[inline]
    fn set(&self, geo: Geometry, set: usize) -> &[Way] {
        &self.ways[geo.slots_of(set)]
    }
}

/// Wait-free array k-way cache.
pub struct KwWfa {
    engine: SetEngine,
    elastic: Elastic<WfaTable>,
}

impl KwWfa {
    /// Build a cache of (at least) `capacity` weight units in sets of
    /// `ways` entries, evicting under `policy`.
    pub fn new(capacity: usize, ways: usize, policy: Policy) -> Self {
        let geo = Geometry::new(capacity, ways);
        Self {
            engine: SetEngine::new(ways, policy),
            elastic: Elastic::new(geo, WfaTable::new(geo.capacity())),
        }
    }

    /// Build a byte-value cache: `capacity` entry slots backed by (about)
    /// `value_bytes` of slab value memory (DESIGN.md §Value store). The
    /// per-way weight budget becomes `value_bytes / capacity` in 64-byte
    /// granules, so eviction meters real memory; the slab itself is
    /// capped at twice the budget as a hard backstop (free items are
    /// retained as reuse capacity, mirroring the engine's
    /// retired-never-freed epochs).
    pub fn with_value_store(
        capacity: usize,
        ways: usize,
        policy: Policy,
        value_bytes: usize,
    ) -> Self {
        let geo = Geometry::new(capacity, ways);
        let store = Arc::new(SlabStore::for_budget(value_bytes));
        let per_way = SlabStore::budget_per_way(value_bytes, geo.capacity());
        let mut engine = SetEngine::new(ways, policy);
        engine.attach_values(store, per_way);
        Self { engine, elastic: Elastic::new(geo, WfaTable::new(geo.capacity())) }
    }

    /// The attached byte-value store, when built by
    /// [`KwWfa::with_value_store`] (tests assert its ledgers directly).
    pub fn value_store(&self) -> Option<&Arc<SlabStore>> {
        self.engine.values()
    }

    /// The rounded geometry this cache currently runs with (the resize
    /// *target* geometry while a migration is in flight).
    pub fn geometry(&self) -> Geometry {
        self.elastic.snapshot().geo
    }

    /// The eviction policy.
    pub fn policy(&self) -> Policy {
        self.engine.policy()
    }

    /// Largest per-set total weight currently held. Diagnostic for the
    /// weighted-capacity tests: after churn quiesces this never exceeds
    /// the per-set budget (= `ways`).
    pub fn max_set_weight(&self) -> u64 {
        let ep = self.elastic.snapshot();
        (0..ep.geo.num_sets()).map(|s| Self::set_weight(ep.table.set(ep.geo, s))).max().unwrap_or(0)
    }

    fn set_weight(set: &[Way]) -> u64 {
        set.iter()
            .map(|w| {
                // Quiesced-state diagnostic: Relaxed is exact once
                // writers have joined (coherence).
                let key = w.key.load(Ordering::Relaxed);
                if key == EMPTY || key == RESERVED {
                    0
                } else {
                    lifetime::weight_of(w.life.load(Ordering::Relaxed))
                }
            })
            .sum()
    }

    fn table_len(table: &WfaTable) -> usize {
        table
            .ways
            .iter()
            .filter(|w| {
                let k = w.key.load(Ordering::Relaxed);
                k != EMPTY && k != RESERVED
            })
            .count()
    }

    /// Prefetch the lines a set scan strides over: a `Way` is 32 bytes, so
    /// an 8-way set spans four cache lines (prefetch first / middle /
    /// last way).
    #[inline]
    fn prefetch_set(&self, table: &WfaTable, set: usize, ways: usize) {
        let base = set * ways;
        engine::prefetch_read(&table.ways[base]);
        engine::prefetch_read(&table.ways[base + ways / 2]);
        engine::prefetch_read(&table.ways[base + ways - 1]);
    }

    /// Probe one set of one table; touches the hit's metadata.
    #[inline]
    fn probe_set(&self, set: &[Way], pk: &PreparedKey, now: u64) -> Option<u64> {
        let ttl_active = self.engine.ttl_active();
        let now_ms = self.engine.expiry_now();
        let (way, value) = self.engine.probe_get(
            set.len(),
            |i| set[i].key.load(Ordering::Acquire) == pk.ik,
            |i| ttl_active && lifetime::is_expired(set[i].life.load(Ordering::Relaxed), now_ms),
            |i| set[i].value.load(Ordering::Acquire),
        )?;
        self.engine.touch_atomic(&set[way].meta, now);
        Some(value)
    }

    /// `get` with the hashing already done (shared by the scalar and
    /// batched paths). Misses in the target table fall through to the
    /// source table while a resize is migrating, so entries below the
    /// split watermark stay readable mid-move.
    #[inline]
    fn get_prepared(&self, pk: PreparedKey) -> Option<u64> {
        let now = self.engine.tick();
        let ep = self.elastic.snapshot();
        let set = ep.table.set(ep.geo, ep.geo.set_of_hash(pk.hash));
        if let Some(value) = self.probe_set(set, &pk, now) {
            return Some(value);
        }
        let prev = ep.prev()?;
        let old_set = prev.table.set(prev.geo, prev.geo.set_of_hash(pk.hash));
        self.probe_set(old_set, &pk, now)
    }

    /// `put` with the hashing already done. Returns whether the value
    /// word was published (word callers ignore it; `put_bytes` frees its
    /// freshly allocated handle on `false` so a dropped insert never
    /// leaks a slab item).
    fn put_prepared(&self, pk: PreparedKey, value: u64, opts: EntryOpts) -> bool {
        self.engine.note_opts(&opts);
        if opts.weight as u64 > self.engine.set_budget() {
            // Heavier than a whole set's budget: can never fit, dropped
            // ("it is a cache" — same as an insert lost to contention).
            return false;
        }
        let ep = self.elastic.snapshot();
        if let Some(prev) = ep.prev() {
            // Help-on-write: drain this key's source set before touching
            // the target table, so the insert below can never create a
            // second copy of a not-yet-migrated key.
            self.migrate_set(ep, prev, prev.geo.set_of_hash(pk.hash));
        }
        let now = self.engine.tick();
        let now_ms = self.engine.expiry_now();
        let life = lifetime::life_of(&opts, now_ms);
        let ttl_active = self.engine.ttl_active();
        let set_idx = ep.geo.set_of_hash(pk.hash);
        let set = ep.table.set(ep.geo, set_idx);

        // Pass 1 (Alg. 3 lines 3–6): overwrite an existing entry. The
        // life word is refreshed too: an overwrite restarts the TTL.
        // Relaxed resident check (ik-equality only) and Relaxed life
        // refresh — module-level ordering argument; the value store
        // keeps Release as the probe's re-validation anchor.
        if let Some(i) = self
            .engine
            .find_match(set.len(), |i| set[i].key.load(Ordering::Relaxed) == pk.ik)
        {
            if self.engine.values_active() {
                // Byte mode: claim the line for the overwrite, so the
                // displaced handle is obtained exclusively (never freed
                // twice) and the new one can never land in a line a
                // concurrent evictor just recycled to another key.
                if set[i]
                    .key
                    .compare_exchange(pk.ik, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_err()
                {
                    return false; // line mid-churn: drop ("it is a cache")
                }
                let old = set[i].value.swap(value, Ordering::Release);
                set[i].life.store(life, Ordering::Relaxed);
                set[i].key.store(pk.ik, Ordering::Release);
                self.engine.release_value(old);
            } else {
                set[i].value.store(value, Ordering::Release);
                set[i].life.store(life, Ordering::Relaxed);
            }
            self.engine.touch_atomic(&set[i].meta, now);
            self.repair_weight(set, pk.ik);
            return true;
        }

        // Pass 2 (Alg. 3 lines 12–16): claim an empty way (Relaxed peek,
        // the AcqRel CAS re-verifies; trailing key-Release covers the
        // Relaxed meta/life stores).
        for way in set {
            if way.key.load(Ordering::Relaxed) == EMPTY
                && way
                    .key
                    .compare_exchange(EMPTY, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                way.value.store(value, Ordering::Release);
                way.meta.store(self.engine.initial_meta(now), Ordering::Relaxed);
                way.life.store(life, Ordering::Relaxed);
                way.key.store(pk.ik, Ordering::Release);
                self.repair_weight(set, pk.ik);
                return true;
            }
        }

        // Pass 3 (Alg. 3 lines 7–11): evict the victim — an expired line
        // first, the policy choice otherwise. Snapshot the set, pick, then
        // try to claim with a single CAS. If the CAS fails, another thread
        // is mutating this way concurrently — like the paper's WFA we
        // simply give up (the cache is allowed to drop an insert under
        // contention; it is a cache).
        let choice = self.engine.choose_victim(set.len(), now, |i| {
            let key = set[i].key.load(Ordering::Acquire);
            if key == RESERVED {
                (key, u64::MAX, false) // mid-publish way: never the victim
            } else {
                let expired = ttl_active
                    && lifetime::is_expired(set[i].life.load(Ordering::Relaxed), now_ms);
                (key, set[i].meta.load(Ordering::Relaxed), expired)
            }
        });
        if choice.guard == RESERVED {
            return false;
        }
        let way = &set[choice.way];
        let installed = way
            .key
            .compare_exchange(choice.guard, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok();
        if installed {
            if self.engine.values_active() {
                // The claim made this thread the victim's exclusive
                // owner: swapping hands it the old handle to recycle.
                let old = way.value.swap(value, Ordering::Release);
                self.engine.release_value(old);
            } else {
                way.value.store(value, Ordering::Release);
            }
            way.meta.store(self.engine.initial_meta(now), Ordering::Relaxed);
            way.life.store(life, Ordering::Relaxed);
            way.key.store(pk.ik, Ordering::Release);
        }
        self.repair_weight(set, pk.ik);
        installed
    }

    /// Drain one source set of an in-flight resize into the target table
    /// (the linear-hash split step): each live line is *claimed* with the
    /// usual CAS-to-`RESERVED`, its words are read, the source line is
    /// freed, and the entry is republished into its target set carrying
    /// the metadata it earned. Expired lines are dropped instead of
    /// moved. A claim lost to a concurrent drain or eviction is skipped —
    /// whoever won the word owns the move. Runs from both the background
    /// `resize_step` watermark walk and the help-on-write path, and is
    /// idempotent over already-empty sets.
    fn migrate_set(&self, ep: &Epoch<WfaTable>, prev: &Epoch<WfaTable>, old_set: usize) {
        for way in prev.table.set(prev.geo, old_set) {
            // Relaxed peek: the claim CAS re-verifies the key word.
            let ik = way.key.load(Ordering::Relaxed);
            if ik == EMPTY || ik == RESERVED {
                continue;
            }
            if way
                .key
                .compare_exchange(ik, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue; // lost to a concurrent drain/eviction
            }
            // The CAS acquired the publisher's trailing key-Release (the
            // claimed word IS the last-published word), so the entry's
            // other words may be read Relaxed (module-level argument).
            let value = way.value.load(Ordering::Relaxed);
            let meta = way.meta.load(Ordering::Relaxed);
            let life = way.life.load(Ordering::Relaxed);
            way.key.store(EMPTY, Ordering::Release);
            if self.engine.ttl_active() && lifetime::is_expired(life, self.engine.expiry_now()) {
                // Dead line: reclaim, don't move — and recycle its slab
                // item (the claim made this thread the handle's owner).
                self.engine.release_value(value);
                continue;
            }
            let pk = self.engine.prepare(Geometry::decode_key(ik), ep.geo);
            self.install_migrated(ep, &pk, value, meta, life);
        }
    }

    /// Republish one migrated entry into its target set, preserving its
    /// policy metadata and life word. A fresher entry already present for
    /// the key wins (the old copy is simply dropped); a full target set
    /// (shrink merge) resolves through [`SetEngine::place_migrated`] —
    /// the policy's own order decides who survives.
    fn install_migrated(
        &self,
        ep: &Epoch<WfaTable>,
        pk: &PreparedKey,
        value: u64,
        meta: u64,
        life: u64,
    ) {
        let set = ep.table.set(ep.geo, ep.geo.set_of_hash(pk.hash));
        // Resident check decides only ik-equality: Relaxed (see pass 1).
        let resident = self
            .engine
            .find_match(set.len(), |i| set[i].key.load(Ordering::Relaxed) == pk.ik);
        if resident.is_some() {
            // A fresher insert already landed in the target: the old
            // copy is dropped, and this thread owns its handle.
            self.engine.release_value(value);
            return;
        }
        for way in set {
            if way.key.load(Ordering::Relaxed) == EMPTY
                && way
                    .key
                    .compare_exchange(EMPTY, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                way.value.store(value, Ordering::Release);
                way.meta.store(meta, Ordering::Relaxed);
                way.life.store(life, Ordering::Relaxed);
                way.key.store(pk.ik, Ordering::Release);
                self.repair_weight(set, pk.ik);
                return;
            }
        }
        // Full target set: merge by policy order.
        let now = self.engine.now();
        let mut guards = [0u64; MAX_WAYS];
        let mut metas = [u64::MAX; MAX_WAYS];
        for (i, way) in set.iter().enumerate() {
            let key = way.key.load(Ordering::Acquire);
            guards[i] = key;
            if key != RESERVED {
                metas[i] = way.meta.load(Ordering::Relaxed);
            }
        }
        let Some(victim) = self.engine.place_migrated(set.len(), now, &metas, meta) else {
            // The migrated entry is the policy victim: drop it (and
            // recycle its slab item — this thread owns the handle).
            self.engine.release_value(value);
            return;
        };
        let way = &set[victim];
        if way
            .key
            .compare_exchange(guards[victim], RESERVED, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            if self.engine.values_active() {
                let old = way.value.swap(value, Ordering::Release);
                self.engine.release_value(old);
            } else {
                way.value.store(value, Ordering::Release);
            }
            way.meta.store(meta, Ordering::Relaxed);
            way.life.store(life, Ordering::Relaxed);
            way.key.store(pk.ik, Ordering::Release);
        } else {
            // Lost the displacement race: the migrated copy is dropped.
            self.engine.release_value(value);
        }
        self.repair_weight(set, pk.ik);
    }

    /// Weighted-capacity repair (DESIGN.md §Weighted capacity): while the
    /// set's total weight exceeds its budget, evict victims — expired
    /// lines first, the policy choice otherwise — sparing the key just
    /// inserted so a legal oversized insert cannot bounce itself. A
    /// no-op until any put carries a non-unit weight; bounded by k
    /// passes, each freeing one way with a single CAS (a failed CAS
    /// means concurrent churn — the racing put's own repair finishes the
    /// job).
    fn repair_weight(&self, set: &[Way], keep_ik: u64) {
        if !self.engine.weight_active() {
            return;
        }
        // Make this thread's publish globally visible before snapshotting
        // the set: whichever racing put finishes *last* then observes
        // every earlier insert, so the quiesced set always fits its
        // budget (transient overshoot during the race is the usual "it
        // is a cache" window). This fence is irreducible — with only
        // Release/Acquire the two racing repairs form a store-buffer
        // litmus and can both under-count; see KwWfsc::repair_weight for
        // the full argument. Gated on weight_active, so the unit-weight
        // hot path never pays for it.
        std::sync::atomic::fence(Ordering::SeqCst);
        let budget = self.engine.set_budget();
        let ttl_active = self.engine.ttl_active();
        let k = set.len();
        for _ in 0..k {
            let now = self.engine.now();
            let now_ms = self.engine.expiry_now();
            let mut total = 0u64;
            let mut eligible = [0usize; MAX_WAYS];
            let mut metas = [0u64; MAX_WAYS];
            let mut guards = [0u64; MAX_WAYS];
            let mut n = 0usize;
            let mut expired_pick: Option<(usize, u64)> = None;
            for (i, way) in set.iter().enumerate() {
                let key = way.key.load(Ordering::Acquire);
                if key == EMPTY || key == RESERVED {
                    continue;
                }
                let life = way.life.load(Ordering::Relaxed);
                total += lifetime::weight_of(life);
                if key == keep_ik {
                    continue; // spare the entry this put installed
                }
                if expired_pick.is_none() && ttl_active && lifetime::is_expired(life, now_ms) {
                    expired_pick = Some((i, key));
                }
                eligible[n] = i;
                guards[n] = key;
                metas[n] = way.meta.load(Ordering::Relaxed);
                n += 1;
            }
            if total <= budget {
                return;
            }
            let (way, guard) = match expired_pick {
                Some(pick) => pick,
                None if n > 0 => {
                    let j = self.engine.select_victim(&metas[..n], now);
                    (eligible[j], guards[j])
                }
                None => return, // nothing evictable besides the new entry
            };
            if self.engine.values_active() {
                // Byte mode evicts through a full claim: swap the value
                // word to 0 *before* releasing the line to EMPTY, so the
                // handle is freed exactly once and a later claimer of
                // the empty line never sees (or frees) a stale handle.
                if set[way]
                    .key
                    .compare_exchange(guard, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    let old = set[way].value.swap(0, Ordering::Relaxed);
                    self.engine.release_value(old);
                    set[way].key.store(EMPTY, Ordering::Release);
                }
            } else {
                let _ = set[way].key.compare_exchange(
                    guard,
                    EMPTY,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
            }
        }
    }
}

impl Cache for KwWfa {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_prepared(self.engine.prepare(key, self.elastic.snapshot().geo))
    }

    fn put(&self, key: u64, value: u64) {
        self.put_prepared(
            self.engine.prepare(key, self.elastic.snapshot().geo),
            value,
            EntryOpts::default(),
        );
    }

    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        self.put_prepared(self.engine.prepare(key, self.elastic.snapshot().geo), value, opts);
    }

    fn supports_values(&self) -> bool {
        self.engine.values_active()
    }

    fn put_bytes_with(&self, key: u64, value: &[u8], opts: EntryOpts) -> bool {
        let Some((handle, opts)) = self.engine.alloc_value(value, opts) else {
            return false;
        };
        let pk = self.engine.prepare(key, self.elastic.snapshot().geo);
        if self.put_prepared(pk, handle, opts) {
            true
        } else {
            // The insert was dropped (contention / over-budget): the
            // fresh item never became reachable, recycle it here.
            self.engine.release_value(handle);
            false
        }
    }

    fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        let store = self.engine.values()?;
        // The hit's value word is a generation-stamped handle; a slot
        // recycled between the probe and this read fails the generation
        // check and reports the eviction as a miss.
        store.read(self.get(key)?)
    }

    fn value_bytes(&self) -> u64 {
        self.engine.values().map_or(0, |s| s.used_bytes())
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            keys,
            |&key| key,
            |set| self.prefetch_set(&ep.table, set, ways),
            |pk, _| out.push(self.get_prepared(pk)),
        );
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.0,
            |set| self.prefetch_set(&ep.table, set, ways),
            |pk, item| {
                self.put_prepared(pk, item.1, EntryOpts::default());
            },
        );
    }

    fn put_batch_with(&self, items: &[BatchEntry]) {
        let ep = self.elastic.snapshot();
        let ways = ep.geo.ways();
        self.engine.for_batch(
            ep.geo,
            items,
            |item| item.key,
            |set| self.prefetch_set(&ep.table, set, ways),
            |pk, item| {
                self.put_prepared(pk, item.value, item.opts);
            },
        );
    }

    fn capacity(&self) -> usize {
        let ep = self.elastic.snapshot();
        match ep.prev() {
            // Mid-resize both tables are live, so the instantaneous
            // entry bound is the larger geometry; it converges to the
            // target when the source epoch retires.
            Some(prev) => ep.geo.capacity().max(prev.geo.capacity()),
            None => ep.geo.capacity(),
        }
    }

    fn requested_capacity(&self) -> usize {
        self.elastic.snapshot().geo.requested_capacity()
    }

    fn len(&self) -> usize {
        let ep = self.elastic.snapshot();
        let mut n = Self::table_len(&ep.table);
        if let Some(prev) = ep.prev() {
            n += Self::table_len(&prev.table);
        }
        n
    }

    fn weight(&self) -> u64 {
        if !self.engine.weight_active() {
            return self.len() as u64;
        }
        let ep = self.elastic.snapshot();
        let mut total: u64 =
            (0..ep.geo.num_sets()).map(|s| Self::set_weight(ep.table.set(ep.geo, s))).sum();
        if let Some(prev) = ep.prev() {
            total += (0..prev.geo.num_sets())
                .map(|s| Self::set_weight(prev.table.set(prev.geo, s)))
                .sum::<u64>();
        }
        total
    }

    fn name(&self) -> &'static str {
        "KW-WFA"
    }

    fn supports_lifetime(&self) -> bool {
        true
    }

    fn supports_resize(&self) -> bool {
        true
    }

    fn resize(&self, new_capacity: usize) -> bool {
        // An admin op serializes on any in-flight migration: finish it,
        // then begin the new epoch. Migration itself stays incremental
        // (resize_step / help-on-write).
        while self.elastic.resizing() {
            if self.resize_step(64) == 0 {
                std::thread::yield_now();
            }
        }
        let geo = self.elastic.snapshot().geo;
        self.elastic.begin(geo.resized(new_capacity), |g| WfaTable::new(g.capacity()))
    }

    fn resize_step(&self, max_sets: usize) -> usize {
        self.elastic.step(max_sets, |ep, prev, set| self.migrate_set(ep, prev, set))
    }

    fn resize_pending(&self) -> bool {
        self.elastic.resizing()
    }

    fn sweep_expired(&self, max_sets: usize) -> usize {
        if max_sets == 0 || !self.engine.ttl_active() {
            return 0;
        }
        let ep = self.elastic.snapshot();
        let num_sets = ep.geo.num_sets();
        let span = max_sets.min(num_sets);
        let start = self.engine.sweep_start(span, num_sets);
        let now_ms = lifetime::now_ms();
        let mut reclaimed = 0;
        for j in 0..span {
            for way in ep.table.set(ep.geo, (start + j) % num_sets) {
                let key = way.key.load(Ordering::Acquire);
                if key == EMPTY || key == RESERVED {
                    continue;
                }
                if !lifetime::is_expired(way.life.load(Ordering::Relaxed), now_ms) {
                    continue;
                }
                if self.engine.values_active() {
                    // Same claim-then-zero discipline as repair_weight.
                    if way
                        .key
                        .compare_exchange(key, RESERVED, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok()
                    {
                        let old = way.value.swap(0, Ordering::Relaxed);
                        self.engine.release_value(old);
                        way.key.store(EMPTY, Ordering::Release);
                        reclaimed += 1;
                    }
                } else if way
                    .key
                    .compare_exchange(key, EMPTY, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    reclaimed += 1;
                }
            }
        }
        reclaimed
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let ep = self.elastic.snapshot();
        let set = ep.table.set(ep.geo, ep.geo.set_of(key));
        self.engine.peek_victim_with(
            set.len(),
            |i| set[i].key.load(Ordering::Acquire),
            |i| set[i].meta.load(Ordering::Relaxed),
            |i| set[i].life.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn put_get_overwrite() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        assert_eq!(c.get(5), None);
        c.put(5, 50);
        assert_eq!(c.get(5), Some(50));
        c.put(5, 51);
        assert_eq!(c.get(5), Some(51));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_is_bounded() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        for key in 0..10_000u64 {
            c.put(key, key);
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        // Single-set cache: capacity 4, 4 ways.
        let c = KwWfa::new(4, 4, Policy::Lru);
        for key in 0..4u64 {
            c.put(key, key);
        }
        // Touch 0..3 except 2, then insert a new key: 2 must be evicted.
        c.get(0);
        c.get(1);
        c.get(3);
        c.put(100, 100);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(100), Some(100));
        for key in [0u64, 1, 3] {
            assert_eq!(c.get(key), Some(key), "key {key} should have survived");
        }
    }

    #[test]
    fn lfu_keeps_frequent() {
        let c = KwWfa::new(4, 4, Policy::Lfu);
        for key in 0..4u64 {
            c.put(key, key);
        }
        for _ in 0..10 {
            c.get(0);
            c.get(1);
            c.get(2);
        }
        c.put(100, 100); // victim must be 3 (count 1)
        assert_eq!(c.get(3), None);
        assert_eq!(c.get(0), Some(0));
    }

    #[test]
    fn all_policies_smoke() {
        for p in Policy::ALL {
            let c = KwWfa::new(256, 8, p);
            for key in 0..1000u64 {
                c.put(key, key * 2);
                assert_eq!(c.get(key), Some(key * 2), "policy {p:?}: fresh insert readable");
            }
            assert!(c.len() <= c.capacity());
        }
    }

    #[test]
    fn batched_get_matches_scalar() {
        let c = KwWfa::new(512, 8, Policy::Lru);
        for key in 0..400u64 {
            c.put(key, key + 7);
        }
        let keys: Vec<u64> = (0..800u64).collect(); // half hits, half misses
        let mut batched = Vec::new();
        c.get_batch(&keys, &mut batched);
        assert_eq!(batched.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(batched[i], c.get(key), "key {key}");
        }
    }

    #[test]
    fn batched_put_then_get() {
        // 300 keys over 512 sets: far below any set's 8 ways, so nothing
        // the assertion depends on can be evicted.
        let c = KwWfa::new(4096, 8, Policy::Lfu);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k * 3)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn expired_entries_probe_as_misses() {
        let c = KwWfa::new(64, 4, Policy::Lru);
        c.put_with(1, 10, EntryOpts::ttl(Duration::ZERO));
        assert_eq!(c.get(1), None, "a zero-TTL entry is born expired");
        c.put_with(2, 20, EntryOpts::ttl(Duration::from_secs(3600)));
        assert_eq!(c.get(2), Some(20), "a live TTL entry is readable");
        // Overwriting an expired key revives it.
        c.put(1, 11);
        assert_eq!(c.get(1), Some(11));
    }

    #[test]
    fn expired_line_is_victim_of_first_resort() {
        // Single set, LRU. Fill with 3 immortals + 1 expired; the next
        // insert must displace the expired line, not the LRU minimum.
        let c = KwWfa::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::ttl(Duration::ZERO));
        for key in 1..4u64 {
            c.put(key, key);
        }
        c.put(100, 100);
        for key in 1..4u64 {
            assert_eq!(c.get(key), Some(key), "immortal {key} must survive");
        }
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn weighted_insert_respects_set_budget() {
        // Single set of 4 ways = budget 4. A weight-3 entry plus two
        // unit entries fit exactly; adding one more unit entry must
        // shrink the set back to the budget.
        let c = KwWfa::new(4, 4, Policy::Lru);
        c.put_with(0, 0, EntryOpts::weight(3));
        c.put(1, 1);
        assert_eq!(c.max_set_weight(), 4, "3 + 1 fits the budget exactly");
        assert_eq!(c.weight(), 4);
        // Weight 3+1+1 = 5 > 4: the put of key 2 must repair on insert.
        c.put(2, 2);
        let resident: Vec<u64> = (0..3u64).filter(|&k| c.get(k).is_some()).collect();
        let total: u64 = resident.iter().map(|&k| if k == 0 { 3 } else { 1 }).sum();
        assert!(total <= 4, "resident weight {total} exceeds the budget");
        assert!(c.max_set_weight() <= 4);
        assert!(c.get(2).is_some(), "the inserting key is spared by its own repair");
    }

    #[test]
    fn oversized_entries_are_dropped() {
        let c = KwWfa::new(4, 4, Policy::Lru);
        c.put_with(7, 70, EntryOpts::weight(5)); // budget is 4
        assert_eq!(c.get(7), None, "an entry heavier than a set can never fit");
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn sweep_reclaims_expired_lines() {
        // 20 keys over 512 sets of 8 ways: no set overflows, so nothing
        // is evicted before the sweep (same bound the batch tests use).
        let c = KwWfa::new(4096, 8, Policy::Lru);
        for key in 0..10u64 {
            c.put_with(key, key, EntryOpts::ttl(Duration::ZERO));
        }
        for key in 10..20u64 {
            c.put(key, key);
        }
        assert_eq!(c.len(), 20, "lazy expiration leaves dead lines in place");
        let reclaimed = c.sweep_expired(c.geometry().num_sets());
        assert_eq!(reclaimed, 10, "sweep must reclaim exactly the expired lines");
        assert_eq!(c.len(), 10);
        for key in 10..20u64 {
            assert_eq!(c.get(key), Some(key), "immortal {key} survives the sweep");
        }
    }

    #[test]
    fn grow_keeps_every_entry_readable() {
        // 100 keys over 256 sets: no set can overflow its 8 ways, so a
        // missing key is a resize bug, not an eviction.
        let c = KwWfa::new(2048, 8, Policy::Lru);
        for key in 0..100u64 {
            c.put(key, key + 9);
        }
        assert!(c.resize(4096));
        assert!(c.resize_pending());
        // Mid-migration reads fall through to the old table.
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key + 9), "key {key} lost mid-resize");
        }
        while c.resize_pending() {
            c.resize_step(16);
        }
        assert_eq!(c.geometry().num_sets(), 512);
        assert_eq!(c.capacity(), 4096);
        for key in 0..100u64 {
            assert_eq!(c.get(key), Some(key + 9), "key {key} lost after migration");
        }
    }

    #[test]
    fn concurrent_put_get_no_phantoms() {
        // Values always equal keys; any get must return its own key.
        let c = Arc::new(KwWfa::new(1024, 8, Policy::Lru));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(t);
                for _ in 0..20_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key, "phantom value for key {key}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity());
    }

    #[test]
    fn byte_values_roundtrip_and_recycle() {
        // Word caches refuse the byte API outright.
        let c = KwWfa::new(64, 4, Policy::Lru);
        assert!(!c.supports_values());
        assert!(!c.put_bytes(1, b"nope"));
        assert_eq!(c.get_bytes(1), None);

        let c = KwWfa::with_value_store(64, 4, Policy::Lru, 1 << 22);
        assert!(c.supports_values());
        assert!(c.put_bytes(1, b"hello slab"));
        assert_eq!(c.get_bytes(1).as_deref(), Some(&b"hello slab"[..]));
        let store = c.value_store().unwrap();
        assert_eq!(store.used_bytes(), 64, "10 bytes occupy one 64-byte item");
        // An overwrite recycles the displaced item: ledger swaps to the
        // new size instead of accumulating.
        assert!(c.put_bytes(1, &[7u8; 300]));
        assert_eq!(c.get_bytes(1).unwrap(), vec![7u8; 300]);
        assert_eq!(store.used_bytes(), 320, "300 bytes land in the 320-byte class");
        assert_eq!(c.value_bytes(), 320);
        // The word-path tombstone (put 0) frees the blob too.
        c.put(1, 0);
        assert_eq!(c.get_bytes(1), None);
        assert_eq!(store.used_bytes(), 0, "tombstoned blob recycled");
    }

    #[test]
    fn byte_eviction_recycles_items() {
        // Single set of 4 ways: inserting 40 distinct keys forces ~36
        // evictions; every displaced handle must come back to the free
        // list (ledger == live residents only).
        let c = KwWfa::with_value_store(4, 4, Policy::Lru, 1 << 20);
        for key in 0..40u64 {
            c.put_bytes(key, &[key as u8; 100]);
        }
        let store = c.value_store().unwrap();
        let live = (0..40u64).filter(|&k| c.get_bytes(k).is_some()).count() as u64;
        assert!(live <= 4);
        assert_eq!(store.used_bytes(), live * 128, "only residents hold items");
        let stats = store.stats();
        for cl in &stats.classes {
            assert_eq!(cl.carved, cl.live + cl.free, "free-list ledger balances");
        }
    }

    #[test]
    fn property_single_thread_model() {
        // Against a naive model: any key the model knows MUST come back
        // with the right value or not at all (never a wrong value), and a
        // get right after its put must hit (single-threaded).
        check("wfa-model", 20, |rng| {
            let c = KwWfa::new(128, 8, Policy::Lru);
            let mut model = std::collections::HashMap::new();
            for _ in 0..2000 {
                let key = rng.below(512);
                if rng.chance(0.6) {
                    let value = rng.next_u64() >> 1;
                    c.put(key, value);
                    model.insert(key, value);
                    assert_eq!(c.get(key), Some(value));
                } else if let Some(v) = c.get(key) {
                    assert_eq!(Some(&v), model.get(&key));
                }
            }
        });
    }
}
