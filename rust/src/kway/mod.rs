//! K-way set-associative concurrent caches — the paper's contribution.
//!
//! Three concurrency flavours, mirroring Section 3 / Algorithms 1–9:
//!
//! * [`KwWfa`] — *K-Way Wait-Free Array*: array-of-structs; each way's
//!   (key, value, meta) words sit together, a put replaces the victim with
//!   a CAS on the key word. Scans stride across ways (the rust analogue of
//!   Java's `AtomicReferenceArray<Node>` pointer chase).
//! * [`KwWfsc`] — *K-Way Wait-Free Separate Counters*: structure-of-arrays;
//!   fingerprints and counters live in their own contiguous arrays so a
//!   probe or victim scan touches one or two cache lines for k ≤ 8. A
//!   replacement costs three atomic stores plus one CAS — the trade-off
//!   the paper measures against WFA.
//! * [`KwLs`] — *K-Way Lock Set*: one stamped read/write lock per set with
//!   Java-`StampedLock`-style read→write upgrade; the set payload is plain
//!   (non-atomic) memory.
//!
//! All three share [`Geometry`] (power-of-two set count, `hash(key) &
//! (num_sets-1)` set indexing via xxh64, like the paper) and the policy
//! metadata semantics from [`crate::policy`]. The probe loops, victim
//! scans, touch semantics and the batched access driver live once in the
//! internal `engine` module (DESIGN.md §Set engine); the three variants
//! are storage adapters over it, each contributing only its layout and
//! claim/publish protocol. Every variant also exposes the engine's
//! advisory victim preview (`Cache::peek_victim`) — the per-set hook the
//! concurrent TinyLFU admission layer ([`crate::tinylfu::TlfuCache`])
//! composes on, which is exactly the "limited associativity TinyLFU"
//! the paper promotes.
//!
//! Geometry is **elastic**: all three variants support online resizing
//! (`Cache::resize` / `Cache::resize_step`) by linear-hash set
//! splitting — the engine's epoch machinery stamps every operation with
//! a consistent (geometry, table, watermark) snapshot, reads fall
//! through old→new mid-migration, and writes drain their key's source
//! set before inserting (DESIGN.md §Elastic resizing).
//!
//! Values are **bytes-capable**: attaching a [`slab`] store
//! (`with_value_store` on any variant) turns the u64 value word into a
//! generation-stamped handle into slab-class item memory, enabling
//! `Cache::put_bytes` / `Cache::get_bytes` with real byte-based weight
//! accounting (DESIGN.md §Value store). Word-valued caches are
//! bit-identical to before: no store attached, no handle decode, no
//! extra atomics on the hot path.

mod alloc;
mod engine;
mod geometry;
mod ls;
pub mod simd;
pub mod slab;
mod stamped;
mod wfa;
mod wfsc;

pub use alloc::{hugepages_enabled, set_hugepages};
pub use geometry::Geometry;
pub use ls::KwLs;
pub use slab::{SlabConfig, SlabStats, SlabStore};
pub use stamped::StampedLock;
pub use wfa::KwWfa;
pub use wfsc::KwWfsc;

use crate::policy::Policy;
use crate::Cache;

/// Which concurrent implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// [`KwWfa`] — wait-free array-of-structs.
    Wfa,
    /// [`KwWfsc`] — wait-free structure-of-arrays with separate counters.
    Wfsc,
    /// [`KwLs`] — lock-per-set with plain storage.
    Ls,
}

impl Variant {
    /// All variants, for sweeps.
    pub const ALL: [Variant; 3] = [Variant::Wfa, Variant::Wfsc, Variant::Ls];

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "wfa" | "kw-wfa" => Some(Variant::Wfa),
            "wfsc" | "kw-wfsc" => Some(Variant::Wfsc),
            "ls" | "kw-ls" => Some(Variant::Ls),
            _ => None,
        }
    }

    /// Canonical implementation label (inverse of [`Variant::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Wfa => "KW-WFA",
            Variant::Wfsc => "KW-WFSC",
            Variant::Ls => "KW-LS",
        }
    }
}

/// Construct a k-way cache of the given variant behind the common trait.
pub fn build(variant: Variant, capacity: usize, ways: usize, policy: Policy) -> Box<dyn Cache> {
    match variant {
        Variant::Wfa => Box::new(KwWfa::new(capacity, ways, policy)),
        Variant::Wfsc => Box::new(KwWfsc::new(capacity, ways, policy)),
        Variant::Ls => Box::new(KwLs::new(capacity, ways, policy)),
    }
}

/// Construct a byte-value k-way cache of the given variant: `capacity`
/// entry slots backed by (about) `value_bytes` of slab value memory
/// (DESIGN.md §Value store). The word API keeps working unchanged;
/// `put_bytes`/`get_bytes` become live.
pub fn build_with_values(
    variant: Variant,
    capacity: usize,
    ways: usize,
    policy: Policy,
    value_bytes: usize,
) -> Box<dyn Cache> {
    match variant {
        Variant::Wfa => Box::new(KwWfa::with_value_store(capacity, ways, policy, value_bytes)),
        Variant::Wfsc => Box::new(KwWfsc::with_value_store(capacity, ways, policy, value_bytes)),
        Variant::Ls => Box::new(KwLs::with_value_store(capacity, ways, policy, value_bytes)),
    }
}

/// Per-thread RNG used for the Random policy and for de-synchronizing
/// retries; seeded once per thread from a global counter so tests stay
/// deterministic under single-threaded use.
pub(crate) fn with_thread_rng<T>(f: impl FnOnce(&mut crate::util::rng::Rng) -> T) -> T {
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT_SEED: AtomicU64 = AtomicU64::new(0xA11CE);
    thread_local! {
        static RNG: RefCell<crate::util::rng::Rng> = RefCell::new(
            crate::util::rng::Rng::new(NEXT_SEED.fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed)));
    }
    RNG.with(|rng| f(&mut rng.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("kw-wfsc"), Some(Variant::Wfsc));
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn build_all_variants() {
        for v in Variant::ALL {
            let c = build(v, 1024, 8, Policy::Lru);
            c.put(1, 10);
            assert_eq!(c.get(1), Some(10));
            assert_eq!(c.capacity(), 1024);
        }
    }
}
