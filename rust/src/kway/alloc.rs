//! Cache-line-aligned table storage for the k-way variants.
//!
//! The paper's §3 locality argument — a limited-associativity probe
//! touches one contiguous set line — only holds if a set's slice of a
//! flat array never *straddles* cache lines it did not have to. `Vec`
//! (and `Box<[T]>` built from an iterator) aligns to `align_of::<T>()`,
//! which for `AtomicU64` is 8: a 64-byte set (8 ways × 8 bytes) can start
//! anywhere in a line and span two. [`AlignedSlice`] allocates the whole
//! table at [`CACHE_LINE`] alignment instead, so for any power-of-two way
//! count a set's `ways * size_of::<T>()` bytes begin at a multiple of
//! their own span, and a k ≤ 8 fingerprint scan is guaranteed to be a
//! single-line — and, for the SIMD probe, a single aligned-vector —
//! access.

use super::geometry::CACHE_LINE;
use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::Deref;
use std::ptr::NonNull;

/// A heap slice of `T` whose base address is [`CACHE_LINE`]-aligned.
///
/// Functionally a `Box<[T]>` (derefs to `[T]`, frees on drop) with a
/// stronger alignment guarantee and zero-fill construction. Used for the
/// WFSC structure-of-arrays slices and the WFA way array.
pub(crate) struct AlignedSlice<T> {
    ptr: NonNull<T>,
    len: usize,
}

// SAFETY: AlignedSlice owns its allocation exclusively (same aliasing
// story as Box<[T]>), so Send/Sync reduce to T's.
unsafe impl<T: Send> Send for AlignedSlice<T> {}
unsafe impl<T: Sync> Sync for AlignedSlice<T> {}

impl<T> AlignedSlice<T> {
    /// Allocate `len` zero-initialized `T`s at cache-line alignment.
    ///
    /// # Safety
    ///
    /// The all-zero bit pattern must be a valid `T`, and `T` must not
    /// need `Drop` (elements are deallocated without being dropped).
    /// Both hold for the atomic table words (`AtomicU64` zero = the
    /// `EMPTY` sentinel) and for the WFA `Way` quadruple.
    pub unsafe fn new_zeroed(len: usize) -> Self {
        debug_assert!(!std::mem::needs_drop::<T>());
        if len == 0 {
            return Self { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        let raw = unsafe { alloc_zeroed(layout) } as *mut T;
        let Some(ptr) = NonNull::new(raw) else { handle_alloc_error(layout) };
        Self { ptr, len }
    }

    fn layout(len: usize) -> Layout {
        let size = len.checked_mul(std::mem::size_of::<T>()).expect("table size overflow");
        let align = CACHE_LINE.max(std::mem::align_of::<T>());
        Layout::from_size_align(size, align).expect("bad table layout")
    }
}

impl<T> Deref for AlignedSlice<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        // SAFETY: `ptr` covers `len` initialized (zeroed, valid-by-the
        // constructor-contract) elements for as long as `self` lives.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T> Drop for AlignedSlice<T> {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `new_zeroed` with exactly this layout;
            // the constructor contract says T needs no drop.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn base_is_cache_line_aligned_and_zeroed() {
        for len in [1usize, 7, 8, 64, 1000, 1 << 16] {
            let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(len) };
            assert_eq!(s.as_ptr() as usize % CACHE_LINE, 0, "len {len}");
            assert_eq!(s.len(), len);
            assert!(s.iter().all(|w| w.load(Ordering::Relaxed) == 0));
            // Writable through the usual atomic API.
            s[len / 2].store(42, Ordering::Relaxed);
            assert_eq!(s[len / 2].load(Ordering::Relaxed), 42);
        }
    }

    #[test]
    fn empty_slice_is_fine() {
        let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(0) };
        assert!(s.is_empty());
    }

    #[test]
    fn no_set_straddles_a_line_for_power_of_two_ways() {
        // The invariant the WFSC probe (and its SIMD path) leans on: with
        // a 64-aligned base, a set of w ≤ 8 ways (w a power of two) lies
        // inside one cache line; wider sets span whole lines exactly.
        let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(1 << 10) };
        let base = s.as_ptr() as usize;
        for ways in [1usize, 2, 4, 8, 16] {
            let span = ways * 8;
            for set in 0..(s.len() / ways) {
                let start = base + set * span;
                let lines = (start + span - 1) / CACHE_LINE - start / CACHE_LINE + 1;
                assert_eq!(lines, span.div_ceil(CACHE_LINE), "ways {ways} set {set}");
            }
        }
    }
}
