//! Cache-line-aligned table storage for the k-way variants.
//!
//! The paper's §3 locality argument — a limited-associativity probe
//! touches one contiguous set line — only holds if a set's slice of a
//! flat array never *straddles* cache lines it did not have to. `Vec`
//! (and `Box<[T]>` built from an iterator) aligns to `align_of::<T>()`,
//! which for `AtomicU64` is 8: a 64-byte set (8 ways × 8 bytes) can start
//! anywhere in a line and span two. [`AlignedSlice`] allocates the whole
//! table at [`CACHE_LINE`] alignment instead, so for any power-of-two way
//! count a set's `ways * size_of::<T>()` bytes begin at a multiple of
//! their own span, and a k ≤ 8 fingerprint scan is guaranteed to be a
//! single-line — and, for the SIMD probe, a single aligned-vector —
//! access.
//!
//! With [`set_hugepages`] enabled (the `--hugepages` CLI flag), each
//! allocation is additionally advised to the kernel as
//! `madvise(MADV_HUGEPAGE)` so transparent huge pages can back the
//! tables: a multi-MiB table spanning 2 MiB pages instead of 4 KiB ones
//! cuts dTLB misses on the random-set probe path. Advisory only — if
//! THP is unavailable the call fails silently and 4 KiB pages are used.

use super::geometry::CACHE_LINE;
use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::Deref;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide switch: when set, subsequent [`AlignedSlice`] allocations
/// are `madvise(MADV_HUGEPAGE)`-advised. Flipped once at startup by the
/// `--hugepages` CLI flag, before any cache is built.
static HUGEPAGES: AtomicBool = AtomicBool::new(false);

/// Ask for transparent-huge-page backing on all future table
/// allocations (advisory; a no-op off Linux/x86_64).
pub fn set_hugepages(enabled: bool) {
    HUGEPAGES.store(enabled, Ordering::Relaxed);
}

/// Whether [`set_hugepages`] is currently on — bench artifacts record
/// this so numbers with different page backing are never conflated.
pub fn hugepages_enabled() -> bool {
    HUGEPAGES.load(Ordering::Relaxed)
}

/// Advise the kernel to back `[addr, addr+len)` with transparent huge
/// pages. `madvise` demands page-aligned addresses, and table
/// allocations are only [`CACHE_LINE`]-aligned, so the range is rounded
/// *inward* to 4 KiB page boundaries; a range that rounds to nothing
/// (small tables) is skipped. Errors are deliberately ignored: THP is a
/// performance hint, never a correctness requirement.
fn advise_hugepages(addr: usize, len: usize) {
    const PAGE: usize = 4096;
    let start = addr.next_multiple_of(PAGE);
    let end = (addr + len) & !(PAGE - 1);
    if end > start {
        imp::madvise_hugepage(start, end - start);
    }
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod imp {
    const SYS_MADVISE: u64 = 28;
    const MADV_HUGEPAGE: u64 = 14;

    /// `madvise(start, len, MADV_HUGEPAGE)` by raw syscall (the crate
    /// links no libc), in the style of `util/affinity.rs`. The return
    /// value is ignored by the caller; see [`super::advise_hugepages`].
    pub(super) fn madvise_hugepage(start: usize, len: usize) {
        let mut ret: i64;
        // SAFETY: madvise reads no user memory and MADV_HUGEPAGE only
        // tags the VMA; the range lies inside an allocation we own.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") SYS_MADVISE as i64 => ret,
                in("rdi") start,
                in("rsi") len,
                in("rdx") MADV_HUGEPAGE,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        let _ = ret;
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod imp {
    /// No-op off Linux/x86_64: huge pages stay a Linux-only hint.
    pub(super) fn madvise_hugepage(_start: usize, _len: usize) {}
}

/// A heap slice of `T` whose base address is [`CACHE_LINE`]-aligned.
///
/// Functionally a `Box<[T]>` (derefs to `[T]`, frees on drop) with a
/// stronger alignment guarantee and zero-fill construction. Used for the
/// WFSC structure-of-arrays slices and the WFA way array.
pub(crate) struct AlignedSlice<T> {
    ptr: NonNull<T>,
    len: usize,
}

// SAFETY: AlignedSlice owns its allocation exclusively (same aliasing
// story as Box<[T]>), so Send/Sync reduce to T's.
unsafe impl<T: Send> Send for AlignedSlice<T> {}
unsafe impl<T: Sync> Sync for AlignedSlice<T> {}

impl<T> AlignedSlice<T> {
    /// Allocate `len` zero-initialized `T`s at cache-line alignment.
    ///
    /// # Safety
    ///
    /// The all-zero bit pattern must be a valid `T`, and `T` must not
    /// need `Drop` (elements are deallocated without being dropped).
    /// Both hold for the atomic table words (`AtomicU64` zero = the
    /// `EMPTY` sentinel) and for the WFA `Way` quadruple.
    pub unsafe fn new_zeroed(len: usize) -> Self {
        debug_assert!(!std::mem::needs_drop::<T>());
        if len == 0 {
            return Self { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        let raw = unsafe { alloc_zeroed(layout) } as *mut T;
        let Some(ptr) = NonNull::new(raw) else { handle_alloc_error(layout) };
        if hugepages_enabled() {
            advise_hugepages(raw as usize, layout.size());
        }
        Self { ptr, len }
    }

    fn layout(len: usize) -> Layout {
        let size = len.checked_mul(std::mem::size_of::<T>()).expect("table size overflow");
        let align = CACHE_LINE.max(std::mem::align_of::<T>());
        Layout::from_size_align(size, align).expect("bad table layout")
    }
}

impl<T> Deref for AlignedSlice<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        // SAFETY: `ptr` covers `len` initialized (zeroed, valid-by-the
        // constructor-contract) elements for as long as `self` lives.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T> Drop for AlignedSlice<T> {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `new_zeroed` with exactly this layout;
            // the constructor contract says T needs no drop.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn base_is_cache_line_aligned_and_zeroed() {
        for len in [1usize, 7, 8, 64, 1000, 1 << 16] {
            let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(len) };
            assert_eq!(s.as_ptr() as usize % CACHE_LINE, 0, "len {len}");
            assert_eq!(s.len(), len);
            assert!(s.iter().all(|w| w.load(Ordering::Relaxed) == 0));
            // Writable through the usual atomic API.
            s[len / 2].store(42, Ordering::Relaxed);
            assert_eq!(s[len / 2].load(Ordering::Relaxed), 42);
        }
    }

    #[test]
    fn empty_slice_is_fine() {
        let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(0) };
        assert!(s.is_empty());
    }

    #[test]
    fn hugepage_advice_is_harmless() {
        // With the switch on, allocations of every size — including ones
        // whose inward-rounded page range is empty — must still come back
        // aligned, zeroed and writable (madvise is advisory; failure or
        // skipping must never surface). Restore the global afterwards so
        // test order cannot leak the setting.
        set_hugepages(true);
        for len in [1usize, 100, 1 << 12, 1 << 20] {
            let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(len) };
            assert_eq!(s.as_ptr() as usize % CACHE_LINE, 0);
            assert!(s.iter().all(|w| w.load(Ordering::Relaxed) == 0));
            s[0].store(7, Ordering::Relaxed);
            assert_eq!(s[0].load(Ordering::Relaxed), 7);
        }
        assert!(hugepages_enabled());
        set_hugepages(false);
        assert!(!hugepages_enabled());
    }

    #[test]
    fn no_set_straddles_a_line_for_power_of_two_ways() {
        // The invariant the WFSC probe (and its SIMD path) leans on: with
        // a 64-aligned base, a set of w ≤ 8 ways (w a power of two) lies
        // inside one cache line; wider sets span whole lines exactly.
        let s: AlignedSlice<AtomicU64> = unsafe { AlignedSlice::new_zeroed(1 << 10) };
        let base = s.as_ptr() as usize;
        for ways in [1usize, 2, 4, 8, 16] {
            let span = ways * 8;
            for set in 0..(s.len() / ways) {
                let start = base + set * span;
                let lines = (start + span - 1) / CACHE_LINE - start / CACHE_LINE + 1;
                assert_eq!(lines, span.div_ceil(CACHE_LINE), "ways {ways} set {set}");
            }
        }
    }
}
